import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb harness: hypothesis -> change -> re-lower -> compare.

Each experiment is (cell, name, hypothesis, {rules_extra | cfg}) applied
on top of the cell's baseline; the harness re-runs the probe lowerings and
prints the before/after roofline terms so every iteration lands in
EXPERIMENTS.md §Perf with its prediction and verdict.

Usage:
  python -m repro.launch.hillclimb --list
  python -m repro.launch.hillclimb --run smollm_seqpar ...
  python -m repro.launch.hillclimb --cell smollm-135m:train_4k \
      --set causal_kv_trim=True --name trim
"""
import argparse
import json
from typing import Any, Dict, Optional

from repro.launch import dryrun as D

# name -> (arch, shape, hypothesis, rules_extra, cfg_overrides)
EXPERIMENTS: Dict[str, tuple] = {
    # -- smollm train_4k: memory-dominated (non-flash attention scores) --
    "smollm_trim": (
        "smollm-135m", "train_4k",
        "causal KV-trim halves score-matrix FLOPs+traffic (upper-triangle "
        "blocks never computed): memory_s ~ -45%",
        None, {"causal_kv_trim": True}),
    "smollm_seqpar": (
        "smollm-135m", "train_4k",
        "9 heads don't shard on model=16; shard the query-sequence axis "
        "instead (context parallelism): score buffers /16 -> memory_s way "
        "down at the cost of K/V all-gathers",
        {"seq": "model"}, None),
    "smollm_seqpar_trim": (
        "smollm-135m", "train_4k",
        "compose seqpar + trim",
        {"seq": "model"}, {"causal_kv_trim": True}),
    "smollm_chunk512": (
        "smollm-135m", "train_4k",
        "smaller q-chunk (512) halves the live score buffer; traffic "
        "roughly unchanged -> memory_s flat, temp_gib down",
        None, {"attn_chunk": 512}),

    # -- kimi train_4k: the paper-representative MoE cell --
    "kimi_cf1": (
        "kimi-k2-1t-a32b", "train_4k",
        "capacity_factor 1.25->1.0 cuts expert-FFN FLOPs and dispatch "
        "buffers by 20% at the cost of more dropped tokens",
        None, {"moe": None}),  # placeholder — filled in code below
    "kimi_nofsdp": (
        "kimi-k2-1t-a32b", "train_4k",
        "un-FSDP the weights (d_model unsharded at rest): kills the "
        "per-layer all-gathers -> collective_s down, memory/chip up 16x",
        {"d_model": None}, None),
    "kimi_trim": (
        "kimi-k2-1t-a32b", "train_4k",
        "causal KV-trim on the 64-head attention",
        None, {"causal_kv_trim": True}),
    "kimi_trim_mb8": (
        "kimi-k2-1t-a32b", "train_4k",
        "8 gradient-accumulation microbatches divide activation temps ~8x "
        "(full-compile memory_analysis only; per-step costs unchanged): "
        "190.9 -> ~25-35 GiB/chip, the fits-prescription measured",
        None, {"causal_kv_trim": True}),

    "kimi_bf16norm": (
        "kimi-k2-1t-a32b", "train_4k",
        "the HLO shows activation all-reduces executing in fp32 (the "
        "norm's upcast fuses across the partitioner's AR). bf16-io norms "
        "keep AR operands bf16: collective_s ~ -45%",
        None, {"norm_bf16_io": True}),
    "kimi_bf16norm_cf1": (
        "kimi-k2-1t-a32b", "train_4k",
        "compose bf16-io norms + capacity 1.0",
        None, {"norm_bf16_io": True, "moe": "CF1"}),

    # -- olmo train_4k: most collective-bound (X = 5.6x C) --
    "olmo_bf16norm": (
        "olmo-1b", "train_4k",
        "same fp32-AR finding on a dense arch: bf16-io norms halve "
        "activation-AR bytes",
        None, {"norm_bf16_io": True}),
    "olmo_nofsdp": (
        "olmo-1b", "train_4k",
        "1.3B params easily fit replicated-over-data: dropping FSDP "
        "removes per-layer weight all-gathers; gradient AR remains",
        {"d_model": None}, None),
    "olmo_bf16norm_nofsdp": (
        "olmo-1b", "train_4k",
        "compose bf16-io norms + no-FSDP",
        {"d_model": None}, {"norm_bf16_io": True}),
    "olmo_puredp": (
        "olmo-1b", "train_4k",
        "bf16norm/nofsdp refuted -> the X term is per-layer TP activation "
        "all-reduces. At 1.3B params TP buys nothing: go pure-DP-256 "
        "(batch over data AND model, no head/ffn/vocab sharding, FSDP "
        "keeps params sharded): activation ARs vanish, only the gradient "
        "reduction remains. Predict X 1.54s -> <0.2s",
        {"batch": ("data", "model"), "heads": None, "kv_heads": None,
         "ffn": None, "vocab": None}, None),

    # -- extensions beyond the three required cells --
    "qwen2vl_trim": (
        "qwen2-vl-72b", "train_4k",
        "best big dense cell (43.1%): causal KV-trim should push the "
        "memory term down ~25% and the fraction past 50%",
        None, {"causal_kv_trim": True}),
    "gemma2_trim": (
        "gemma2-27b", "train_4k",
        "gemma2's local layers already bound their KV span; trim only "
        "helps the global half -> expect ~12% off M",
        None, {"causal_kv_trim": True}),

    # -- deepseek decode_32k: MLA absorbed decode --
    "dsv3_decode_seqcache": (
        "deepseek-v3-671b", "decode_32k",
        "shard the 32k latent-cache sequence axis over model (context "
        "parallelism): cache reads /16 -> memory_s down; adds a score "
        "all-reduce per layer",
        {"kv_seq": "model"}, None),
}


def _resolve(name):
    arch, shape, hyp, rules_extra, cfg_over = EXPERIMENTS[name]
    import dataclasses
    from repro import configs
    if name == "kimi_cf1":
        base = configs.get_config(arch)
        cfg_over = {"moe": dataclasses.replace(base.moe,
                                               capacity_factor=1.0)}
    elif cfg_over and cfg_over.get("moe") == "CF1":
        base = configs.get_config(arch)
        cfg_over = dict(cfg_over)
        cfg_over["moe"] = dataclasses.replace(base.moe, capacity_factor=1.0)
    return arch, shape, hyp, rules_extra, cfg_over


def run_experiment(name: str, out_path: str):
    arch, shape, hyp, rules_extra, cfg_over = _resolve(name)
    mb = 1
    if "_mb" in name:
        mb = int(name.rsplit("_mb", 1)[1])
    print(f"=== {name}: {arch} x {shape}", flush=True)
    print(f"    hypothesis: {hyp}", flush=True)
    res = D.run_cell(arch, shape, multi_pod=False, rules_extra=rules_extra,
                     cfg_overrides=cfg_over, microbatches=mb,
                     skip_probes=(mb > 1))
    res["experiment"] = name
    res["hypothesis"] = hyp
    with open(out_path, "a") as f:
        f.write(json.dumps(res) + "\n")
    if res["status"] == "ok":
        rf = res["roofline"]
        print(f"    C={rf['compute_s']:.4f}s M={rf['memory_s']:.4f}s "
              f"X={rf['collective_s']:.4f}s dom={rf['dominant']} "
              f"mem/chip={res['memory']['per_chip_gib']:.2f}GiB "
              f"roofline={rf['roofline_fraction']*100:.1f}%")
    else:
        print("    ERROR:", res["error"][:160])
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--run", nargs="+", default=[])
    ap.add_argument("--out", default="benchmarks/results_hillclimb.jsonl")
    args = ap.parse_args()
    if args.list:
        for k, v in EXPERIMENTS.items():
            print(f"{k:24s} {v[0]} x {v[1]}")
        return
    import jax
    for name in args.run:
        run_experiment(name, args.out)
        jax.clear_caches()


if __name__ == "__main__":
    main()
