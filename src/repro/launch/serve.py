"""Serving launcher: batched requests through the ServeEngine with PMT
J/token accounting.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --reduced --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

import repro.core as pmt
from repro import configs
from repro.models import model as model_mod
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=list(configs.ARCH_NAMES))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, reduced=args.reduced)
    params, _ = model_mod.init_params(jax.random.PRNGKey(args.seed), cfg)
    # One shared session: every wave is a region whose close is an O(1)
    # enqueue; energy resolves on the background resolver thread and
    # lands in the MemoryExporter — the serving thread never waits.
    session = pmt.Session(["cpuutil", "tpu"])
    energy = session.add_exporter(pmt.MemoryExporter())
    engine = ServeEngine(cfg, params, batch_size=args.batch,
                         max_len=args.max_len, session=session)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(2, 9)).tolist(),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    done = engine.generate(reqs)
    n_tokens = sum(len(r.out) for r in done)
    for i, r in enumerate(done[:4]):
        print(f"req{i}: prompt={r.prompt} -> {r.out}")
    session.flush()              # settle any waves still in flight
    j = energy.total_joules()    # across all attached backends
    print(f"served {len(done)} requests, {n_tokens} tokens, "
          f"{j:.2f} J total, {j / max(n_tokens, 1):.4f} J/token "
          f"(stats: {session.stats()})")
    session.close()


if __name__ == "__main__":
    main()
