"""Serving launcher: continuous-batching ServeEngine with PMT J/token
accounting — aggregate and per-request — plus the energy control plane:
live HTTP/SSE telemetry and power-capped scheduling.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --reduced --requests 8 --max-new 16 [--mode wave]

  # hold the run under 120 W and watch it live:
  PYTHONPATH=src python -m repro.launch.serve --reduced \
      --power-cap-watts 120 --telemetry-port 8321
  curl -N http://127.0.0.1:8321/stream        # live SSE record feed
  curl http://127.0.0.1:8321/timeline         # power series
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

import repro.core as pmt
from repro import configs
from repro.core.backends.dummy import DummySensor
from repro.core.supervisor import SensorSupervisor
from repro.models import model as model_mod
from repro.serve.engine import Request, ServeEngine, stall_p95
from repro.serve.governor import PowerGovernor
from repro.telemetry import PowerRecorder, TelemetryServer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=list(configs.ARCH_NAMES))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "wave"])
    ap.add_argument("--decode-attn-impl", default="auto",
                    choices=["auto", "dense", "flash"],
                    help="decode attention path: flash = length-aware "
                         "kernels/decode_attention (Pallas on TPU, "
                         "masked-lax sweep elsewhere); auto = flash on "
                         "TPU only")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill chunk size (tokens per "
                         "admission slice interleaved with decode); 0 = "
                         "blocking bucketed prefill baseline; default "
                         "resolves PMT_PREFILL_CHUNK then "
                         "cfg.prefill_chunk")
    ap.add_argument("--kv-layout", default="contiguous",
                    choices=["contiguous", "paged"],
                    help="KV cache layout: paged = block page pools + "
                         "per-request page tables + radix prefix reuse "
                         "(continuous mode only); contiguous = the "
                         "per-slot baseline")
    ap.add_argument("--cache-dtype", default="bfloat16",
                    choices=["bfloat16", "float32", "int8", "fp8_e4m3"],
                    help="KV cache storage: bfloat16/float32 store raw "
                         "values; int8/fp8_e4m3 store quantized codes + "
                         "per-row scales, dequantized in-register by the "
                         "attention kernels (~2x smaller cache, bounded "
                         "logit drift — see BENCH_quant.json)")
    ap.add_argument("--kv-page-size", type=int, default=None,
                    help="tokens per KV page (paged layout); default "
                         "cfg.kv_page_size")
    ap.add_argument("--kv-pool-blocks", type=int, default=None,
                    help="total pages in the shared pool (paged layout); "
                         "default batch * ceil(max_len / page_size). "
                         "Smaller pools trade admission waits for cache "
                         "memory; prefix-tree pages are evicted LRU "
                         "under pressure")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="radix-tree prefix reuse across requests "
                         "(paged layout; default on)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false")
    ap.add_argument("--pool-reserve-frac", type=float, default=0.0,
                    help="governor admission veto when the page pool's "
                         "free fraction drops below this reserve "
                         "(paged layout + governor only; 0 disables)")
    ap.add_argument("--power-cap-watts", type=float, default=None,
                    help="hold measured window power under this budget "
                         "via the PowerGovernor (admission gating, "
                         "prefill-chunk pacing, decode duty-cycling); "
                         "continuous mode only")
    ap.add_argument("--tenant-quota", type=float, default=None,
                    help="per-tenant joules quota: requests round-robin "
                         "over synthetic tenants, and an over-quota "
                         "tenant yields admission priority to in-quota "
                         "ones (soft — never starved)")
    ap.add_argument("--request-deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline (from "
                         "submission): requests still waiting or "
                         "mid-generation past it finish with reason "
                         "'timeout', keeping partial output; continuous "
                         "mode only")
    ap.add_argument("--signal-ttl-s", type=float, default=None,
                    help="governor power-signal freshness budget: when "
                         "the newest watts sample is older than this the "
                         "signal is stale and the governor degrades per "
                         "--governor-fail-mode")
    ap.add_argument("--governor-fail-mode", default="closed",
                    choices=["closed", "open"],
                    help="stale-signal policy: closed = stop admitting / "
                         "zero the prefill budget until the signal "
                         "recovers (protects the power budget); open = "
                         "run unthrottled (protects availability)")
    ap.add_argument("--supervise", action="store_true",
                    help="wrap each backend in a SensorSupervisor with a "
                         "fail-safe dummy fallback: reads get deadline/"
                         "retry/circuit-breaker protection and fail over "
                         "instead of killing the sampler thread")
    ap.add_argument("--telemetry-port", type=int, default=None,
                    help="serve live telemetry on this HTTP port "
                         "(/timeline /requests /stats /stream SSE); "
                         "0 = ephemeral (port printed at startup)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for decode; 0 (default) "
                         "= greedy argmax")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, reduced=args.reduced)
    params, _ = model_mod.init_params(jax.random.PRNGKey(args.seed), cfg)
    # One shared session: the aggregate batch region and every request's
    # flat serve/req<N> span are O(1) enqueues; energy resolves on the
    # background resolver thread into the MemoryExporter — the serving
    # thread never waits.
    backends = ["cpuutil", "tpu"]
    if args.supervise:
        # Fail-safe chain per backend: the real sensor first, a 0 W dummy
        # last so a dead backend degrades measurements instead of the run.
        backends = [SensorSupervisor([pmt.create(name),
                                      DummySensor(watts=0.0)],
                                     deadline_s=0.25)
                    for name in backends]
    session = pmt.Session(backends)
    energy = session.add_exporter(pmt.MemoryExporter())

    # Control plane: recorder aggregates records + watts timelines; the
    # governor (if capped) reads its smoothed window from it; the HTTP
    # server (if requested) serves both live.
    recorder = PowerRecorder().attach(session, exporter=energy)
    governor = None
    if (args.power_cap_watts is not None or args.tenant_quota is not None) \
            and args.mode == "continuous":
        governor = PowerGovernor(recorder,
                                 cap_watts=args.power_cap_watts,
                                 tenant_quota_j=args.tenant_quota,
                                 signal_ttl_s=args.signal_ttl_s,
                                 fail_mode=args.governor_fail_mode,
                                 pool_reserve_frac=args.pool_reserve_frac)
    server = None
    if args.telemetry_port is not None:
        server = TelemetryServer(recorder, port=args.telemetry_port).start()
        print(f"telemetry: {server.url} "
              f"(/timeline /requests /stats /stream)")

    engine = ServeEngine(cfg, params, batch_size=args.batch,
                         max_len=args.max_len, session=session,
                         mode=args.mode,
                         decode_attn_impl=args.decode_attn_impl,
                         prefill_chunk=args.prefill_chunk,
                         governor=governor,
                         kv_layout=args.kv_layout,
                         kv_page_size=args.kv_page_size,
                         kv_pool_pages=args.kv_pool_blocks,
                         prefix_cache=args.prefix_cache,
                         cache_dtype=args.cache_dtype,
                         greedy=args.temperature <= 0.0,
                         temperature=args.temperature or 1.0,
                         seed=args.seed)
    recorder.attach_engine(engine)

    rng = np.random.default_rng(args.seed)
    # heterogeneous lengths: the workload continuous batching is for
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(2, 9)).tolist(),
                    max_new_tokens=int(rng.integers(2, args.max_new + 1)),
                    tenant=(f"tenant{i % 2}" if args.tenant_quota is not None
                            else None),
                    deadline_s=(args.request_deadline_s
                                if args.mode == "continuous" else None))
            for i in range(args.requests)]
    done = engine.generate(reqs)
    n_tokens = sum(len(r.out) for r in done)
    for i, r in enumerate(done[:4]):
        print(f"req{i}: prompt={r.prompt} -> {r.out}")
    session.flush()              # settle any spans still in flight
    recorder.poll_once()         # final watts tail into the timeline
    per_req = [r for r in energy.records if r.path.startswith("serve/req")]
    agg = [r for r in energy.records
           if not r.path.startswith(("serve/req", "serve/governor"))]
    agg_j = sum(r.joules for r in agg)
    print(f"served {len(done)} requests, {n_tokens} tokens "
          f"[{args.mode}], {agg_j:.2f} J aggregate, "
          f"{agg_j / max(n_tokens, 1):.4f} J/token "
          f"(stats: {session.stats()})")
    if per_req:
        by_req = {}
        for r in per_req:
            path, _, phase = r.path.partition("serve/")[2].partition("/")
            d = by_req.setdefault(f"serve/{path}",
                                  {"joules": 0.0, "tokens": 0,
                                   "prefill": 0.0, "decode": 0.0})
            if phase:
                d[phase] += r.joules
            else:
                d["joules"] += r.joules
                d["tokens"] = r.tokens
        worst = max(by_req.items(),
                    key=lambda kv: kv[1]["joules"] / max(kv[1]["tokens"], 1))
        print(f"per-request spans: {len(by_req)} "
              f"(token sum {sum(d['tokens'] for d in by_req.values())}); "
              f"costliest {worst[0]}: "
              f"{worst[1]['joules'] / max(worst[1]['tokens'], 1):.4f} J/token "
              f"({worst[1]['prefill']:.2f} J prefill / "
              f"{worst[1]['decode']:.2f} J decode)")

    # end-of-run scheduler report: stalls, retraces, throttle decisions
    st = engine.stats()
    report = (f"scheduler: {st['stall_events']} decode stalls "
              f"(p95 {st['stall_p95_s'] * 1e3:.2f} ms"
              f"{', each bounded by one chunk' if engine.prefill_chunk else ''}"
              f"), compiles {st['compile_counts']}")
    if args.request_deadline_s is not None:
        report += f", {st['requests_timed_out']} timed out"
    if governor is not None:
        g = st["governor"]
        watts = recorder.mean_watts(governor.window_s)
        report += (f"; governor: {g['throttle_decisions']} throttle "
                   f"decisions {g['throttle_actions']}, "
                   f"{g['pause_total_s'] * 1e3:.1f} ms paused, "
                   f"window {watts if watts is None else round(watts, 1)} W "
                   f"vs cap {g['cap_watts']} W")
        if g["tenant_joules"]:
            report += f", tenant J {g['tenant_joules']}"
    print(report)
    kc = st["kv_cache"]
    print(f"kv cache: {kc['cache_dtype']}, "
          f"{kc['bytes_per_token']:.1f} B/token")
    if args.kv_layout == "paged":
        line = (f"kv pool: {kc['pages_used']}/{kc['pages_total']} pages "
                f"held ({kc['pages_free']} free, {kc['page_size']} "
                f"tokens/page, {kc['pool_wait_events']} pool waits)")
        if kc["prefix_cache"]:
            line += (f"; prefix cache: {kc['prefix_hits']}/"
                     f"{kc['prefix_lookups']} hits, "
                     f"{kc['prefix_hit_tokens']} prompt tokens reused, "
                     f"{kc['prefix_evictions']} evictions, "
                     f"~{kc['saved_prefill_joules']:.2f} J prefill saved")
        print(line)
    if args.supervise:
        health = recorder.health()
        print(f"measurement plane: {health['state']} "
              f"({health['health_events']} health transitions)")

    if server is not None:
        server.close()
    if governor is not None:
        governor.close()
    recorder.close()
    session.close()


if __name__ == "__main__":
    main()
