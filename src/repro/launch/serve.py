"""Serving launcher: continuous-batching ServeEngine with PMT J/token
accounting — aggregate and per-request.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --reduced --requests 8 --max-new 16 [--mode wave]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

import repro.core as pmt
from repro import configs
from repro.models import model as model_mod
from repro.serve.engine import Request, ServeEngine, stall_p95


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=list(configs.ARCH_NAMES))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "wave"])
    ap.add_argument("--decode-attn-impl", default="auto",
                    choices=["auto", "dense", "flash"],
                    help="decode attention path: flash = length-aware "
                         "kernels/decode_attention (Pallas on TPU, "
                         "masked-lax sweep elsewhere); auto = flash on "
                         "TPU only")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill chunk size (tokens per "
                         "admission slice interleaved with decode); 0 = "
                         "blocking bucketed prefill baseline; default "
                         "resolves PMT_PREFILL_CHUNK then "
                         "cfg.prefill_chunk")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for decode; 0 (default) "
                         "= greedy argmax")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, reduced=args.reduced)
    params, _ = model_mod.init_params(jax.random.PRNGKey(args.seed), cfg)
    # One shared session: the aggregate batch region and every request's
    # flat serve/req<N> span are O(1) enqueues; energy resolves on the
    # background resolver thread into the MemoryExporter — the serving
    # thread never waits.
    session = pmt.Session(["cpuutil", "tpu"])
    energy = session.add_exporter(pmt.MemoryExporter())
    engine = ServeEngine(cfg, params, batch_size=args.batch,
                         max_len=args.max_len, session=session,
                         mode=args.mode,
                         decode_attn_impl=args.decode_attn_impl,
                         prefill_chunk=args.prefill_chunk,
                         greedy=args.temperature <= 0.0,
                         temperature=args.temperature or 1.0,
                         seed=args.seed)

    rng = np.random.default_rng(args.seed)
    # heterogeneous lengths: the workload continuous batching is for
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(2, 9)).tolist(),
                    max_new_tokens=int(rng.integers(2, args.max_new + 1)))
            for _ in range(args.requests)]
    done = engine.generate(reqs)
    n_tokens = sum(len(r.out) for r in done)
    for i, r in enumerate(done[:4]):
        print(f"req{i}: prompt={r.prompt} -> {r.out}")
    session.flush()              # settle any spans still in flight
    per_req = [r for r in energy.records if r.path.startswith("serve/req")]
    agg = [r for r in energy.records if not r.path.startswith("serve/req")]
    agg_j = sum(r.joules for r in agg)
    print(f"served {len(done)} requests, {n_tokens} tokens "
          f"[{args.mode}], {agg_j:.2f} J aggregate, "
          f"{agg_j / max(n_tokens, 1):.4f} J/token "
          f"(stats: {session.stats()})")
    if per_req:
        by_req = {}
        for r in per_req:
            path, _, phase = r.path.partition("serve/")[2].partition("/")
            d = by_req.setdefault(f"serve/{path}",
                                  {"joules": 0.0, "tokens": 0,
                                   "prefill": 0.0, "decode": 0.0})
            if phase:
                d[phase] += r.joules
            else:
                d["joules"] += r.joules
                d["tokens"] = r.tokens
        worst = max(by_req.items(),
                    key=lambda kv: kv[1]["joules"] / max(kv[1]["tokens"], 1))
        print(f"per-request spans: {len(by_req)} "
              f"(token sum {sum(d['tokens'] for d in by_req.values())}); "
              f"costliest {worst[0]}: "
              f"{worst[1]['joules'] / max(worst[1]['tokens'], 1):.4f} J/token "
              f"({worst[1]['prefill']:.2f} J prefill / "
              f"{worst[1]['decode']:.2f} J decode)")
    if engine.stall_events:
        unit = "one chunk" if engine.prefill_chunk else "a whole prompt"
        print(f"decode stalls: {len(engine.stall_events)} prefill "
              f"dispatches while decoding, p95 "
              f"{stall_p95(engine.stall_events) * 1e3:.2f} ms (each "
              f"bounded by {unit})")
    session.close()


if __name__ == "__main__":
    main()
