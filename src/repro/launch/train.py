"""Production training launcher.

Wires every subsystem together: config registry -> mesh + sharding rules
-> pjit train step -> synthetic sharded data -> PMT PowerMonitor (per-step
energy, CSV log, cumulative accounting) -> atomic async checkpoints with
energy metadata -> restart-exact resume (params, optimizer, data cursor,
joules) -> power-based straggler detection hooks.

On this CPU container it runs real (small) configs on the 1-device smoke
mesh; on a pod it is the same code with ``--mesh prod``.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as pmt
from repro import configs
from repro.checkpoint.manager import (CheckpointManager, CheckpointMeta,
                                      latest_step, restore)
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.launch.mesh import base_rules, make_production_mesh, \
    make_smoke_mesh
from repro.optim.optimizers import OptimizerConfig
from repro.sharding.specs import axis_rules
from repro.train.steps import (init_train_state, make_measured_train_step,
                               make_train_step)


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=list(configs.ARCH_NAMES))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["smoke", "prod", "prod2"],
                    default="smoke")
    ap.add_argument("--energy-log", default="")
    ap.add_argument("--energy-jsonl", default="",
                    help="structured per-region JSONL export path")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    cfg = configs.get_config(args.arch, reduced=args.reduced)
    ocfg = OptimizerConfig(name=cfg.optimizer, lr=args.lr,
                           warmup_steps=min(20, args.steps // 5 + 1),
                           decay_steps=args.steps)

    if args.mesh == "smoke":
        mesh = make_smoke_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "prod2"))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = base_rules(multi_pod=(args.mesh == "prod2"))

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    ds = SyntheticLMDataset(dcfg)

    # One shared measurement session for the whole run: the monitor, any
    # serve engine, and ad-hoc regions all resolve off the same background
    # sampler per backend (drawn from the process-wide pool).
    session = pmt.Session(["cpuutil", "tpu"])
    if args.energy_jsonl:
        session.add_exporter(pmt.JsonlExporter(args.energy_jsonl))
    monitor = pmt.PowerMonitor(log_path=args.energy_log or None,
                               session=session)
    mgr = (CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
           if args.ckpt_dir else None)

    with mesh, axis_rules(rules, sizes):
        state, _ = init_train_state(jax.random.PRNGKey(args.seed), cfg,
                                    ocfg)
        start_step = 0
        if mgr and latest_step(args.ckpt_dir) is not None:
            state, meta = restore(args.ckpt_dir, state)
            start_step = meta.data_step
            monitor = pmt.PowerMonitor(
                log_path=args.energy_log or None,
                initial_joules=meta.cumulative_joules, session=session)
            print(f"resumed step={meta.step} "
                  f"joules={meta.cumulative_joules:.1f}")

        step_fn = jax.jit(make_train_step(cfg, ocfg,
                                          microbatches=args.microbatches))
        tokens_per_step = args.batch * args.seq
        measured_step = make_measured_train_step(
            step_fn, monitor, tokens_per_step=tokens_per_step)
        t_start = time.time()
        for s in range(start_step + 1, args.steps + 1):
            batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
            state, metrics, box = measured_step(state, batch, s)
            if mgr:
                sd = monitor.state_dict()
                mgr.maybe_save(s, state, CheckpointMeta(
                    step=s, data_step=s,
                    cumulative_joules=sd["cumulative_joules"],
                    joules_per_step_ema=sd["joules_per_step_ema"]))
            if s % args.log_every == 0 or s == args.steps:
                r = box.records[0]
                print(f"step {s:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"J/step={r.joules:.3f} "
                      f"tok/s={tokens_per_step / max(r.seconds, 1e-9):.0f}",
                      flush=True)
        if mgr:
            mgr.finalize()
    dt = time.time() - t_start
    print(f"done: {args.steps - start_step} steps in {dt:.1f}s, "
          f"total energy {monitor.cumulative_joules:.1f} J "
          f"(cpuutil measured + tpu modeled)")
    monitor.close()
    session.close()
    return state


if __name__ == "__main__":
    main()
