import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 host placeholder devices.

Per cell this produces:
  1. the FULL compile (scan over layer units) on the requested mesh —
     ``memory_analysis()`` proves the step fits, and the compile itself
     proves the sharding is coherent (no GSPMD errors, all collectives
     lower);
  2. on the single-pod mesh, two PROBE compiles (1 and 2 layer-units,
     Python-unrolled) whose per-chip cost_analysis + HLO collective bytes
     are combined into exact step totals (scan bodies are cost-counted
     once by XLA, hence the probes — see repro.roofline.terms);
  3. a RooflineReport (three terms, dominant bottleneck, useful ratio).

Results are appended as JSON to --out so the sweep is restartable.

Usage:
  python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only] [--out f.json]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import InputShape, ModelConfig, SHAPES, \
    shape_applicable
from repro.launch.mesh import cell_rules, make_production_mesh
from repro.models import model as model_mod
from repro.models.xlstm import slstm_recurrent_flops
from repro.optim.optimizers import OptimizerConfig, opt_state_logical_axes
from repro.roofline.terms import (CellCosts, combine_costs,
                                  costs_from_compiled, roofline_report)
from repro.sharding.specs import axis_rules, logical_to_spec, param_sharding
from repro.train.steps import TrainState, init_train_state, make_train_step


def opt_config(cfg: ModelConfig) -> OptimizerConfig:
    return OptimizerConfig(name=cfg.optimizer)


# -- probe config construction ----------------------------------------------------

def probe_configs(cfg: ModelConfig, shape: Optional[InputShape] = None):
    """(base_cfg, [(probe_cfg, unit_count), ...]) for unrolled cost probes.

    Probes Python-unroll every inner time-chunk loop so each chunk's cost
    lands in the HLO; to keep probe tracing tractable the mamba chunk size
    is raised so a probe unrolls at most 8 chunks (per-chunk cost is
    shape-identical, so totals are unchanged up to the associative-scan
    depth term — noted in EXPERIMENTS.md §Roofline).
    """
    lay = model_mod.unit_layout(cfg)
    common = dict(scan_layers=False, unroll_time_chunks=True)
    if cfg.mamba is not None and shape is not None and \
            shape.kind != "decode":
        common["ssm_chunk"] = max(cfg.ssm_chunk, shape.seq_len // 8)
    base_layers = lay.prefix_len + lay.unit_len
    base_kw = dict(num_layers=base_layers, **common)
    probes = []
    if cfg.is_encoder_decoder:
        base_kw["encoder_layers"] = 1
        base = dataclasses.replace(cfg, **base_kw)
        if lay.n_units > 1:
            probes.append((dataclasses.replace(
                cfg, num_layers=lay.prefix_len + 2 * lay.unit_len,
                encoder_layers=1, **common), lay.n_units))
        if lay.enc_units > 1:
            probes.append((dataclasses.replace(
                cfg, num_layers=base_layers, encoder_layers=2, **common),
                lay.enc_units))
        return base, probes
    base = dataclasses.replace(cfg, **base_kw)
    if lay.n_units > 1:
        probes.append((dataclasses.replace(
            cfg, num_layers=lay.prefix_len + 2 * lay.unit_len, **common),
            lay.n_units))
    return base, probes


def slstm_correction(cfg: ModelConfig, shape: InputShape,
                     chips: int) -> Optional[CellCosts]:
    """Analytic per-chip FLOPs for sLSTM recurrent matvecs (scan over time
    is cost-counted once; DESIGN.md §9.2)."""
    if cfg.family != "ssm" or shape.kind == "decode":
        return None
    n_s = sum(1 for i in range(cfg.num_layers)
              if cfg.xlstm.pattern[i % len(cfg.xlstm.pattern)] == "s")
    if not n_s:
        return None
    f = slstm_recurrent_flops(cfg, shape.global_batch, shape.seq_len) * n_s
    if shape.kind == "train":
        pass  # slstm_recurrent_flops already counts fwd+bwd (3x)
    else:
        f /= 3.0
    # pure-DP xlstm: work is replicated over the model axis, so per-chip
    # flops are global / data_shards — approximate with /32 (pod*data)
    return CellCosts(flops=f / max(1, chips // 16), hbm_bytes=0.0,
                     coll_bytes=0.0)


# -- sharding helpers ---------------------------------------------------------------

def batch_shardings(mesh, rules, batch_specs):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def sh(spec):
        if spec.shape == ():
            return NamedSharding(mesh, P())
        axes = ["batch"] + [None] * (len(spec.shape) - 1)
        return NamedSharding(mesh, logical_to_spec(
            axes, rules, shape=spec.shape, mesh_sizes=sizes))

    return jax.tree.map(sh, batch_specs)


def state_shardings(cfg, mesh, rules, state_struct, axes_tree):
    ocfg = opt_config(cfg)
    p_sh = param_sharding(axes_tree, mesh, rules, like=state_struct.params)
    inner_axes = opt_state_logical_axes(state_struct.params, axes_tree, ocfg)
    inner_sh = param_sharding(inner_axes, mesh, rules,
                              like=state_struct.opt.inner)
    from repro.optim.optimizers import OptState
    return TrainState(params=p_sh,
                      opt=OptState(step=NamedSharding(mesh, P()),
                                   inner=inner_sh))


def _eval_shape_with_axes(fn):
    """eval_shape a (values, axes) initializer: abstract the array values,
    capture the static logical-axes tree as a trace-time side effect."""
    captured = []

    def wrapped():
        values, ax = fn()
        captured.append(ax)
        return values

    struct = jax.eval_shape(wrapped)
    return struct, captured[0]


# -- lowering one cell ----------------------------------------------------------------

def lower_cell(cfg: ModelConfig, shape: InputShape, mesh, rules,
               compile_opts: Optional[Dict[str, Any]] = None,
               microbatches: int = 1):
    """Lower + compile one step for one cell. Returns (lowered, compiled)."""
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    with mesh, axis_rules(rules, mesh_sizes):
        if shape.kind == "train":
            ocfg = opt_config(cfg)
            state_struct, axes = _eval_shape_with_axes(
                lambda: init_train_state(jax.random.PRNGKey(0), cfg, ocfg))
            st_sh = state_shardings(cfg, mesh, rules, state_struct, axes)
            batch = configs.input_specs(cfg, shape)
            b_sh = batch_shardings(mesh, rules, batch)
            step = make_train_step(cfg, ocfg, microbatches=microbatches)
            lowered = jax.jit(step, in_shardings=(st_sh, b_sh),
                              out_shardings=(st_sh, None),
                              donate_argnums=(0,)).lower(state_struct, batch)
        elif shape.kind == "prefill":
            params_struct, axes = _eval_shape_with_axes(
                lambda: model_mod.init_params(jax.random.PRNGKey(0), cfg))
            p_sh = param_sharding(axes, mesh, rules, like=params_struct)
            batch = configs.input_specs(cfg, shape)
            b_sh = batch_shardings(mesh, rules, batch)
            # constrain the cache outputs — left unspecified the compiler
            # replicates them (387 GiB/chip on kimi before this)
            caches = jax.eval_shape(
                lambda: model_mod.init_caches(cfg, shape.global_batch,
                                              shape.seq_len))
            c_sh = param_sharding(model_mod.cache_logical_axes(cfg), mesh,
                                  rules, like=caches)
            prefill = model_mod.make_serve_fns(cfg).prefill
            fn = lambda p, b: prefill(p, b, shape.seq_len)
            lowered = jax.jit(fn, in_shardings=(p_sh, b_sh),
                              out_shardings=(None, c_sh)).lower(
                params_struct, batch)
        else:  # decode
            params_struct, axes = _eval_shape_with_axes(
                lambda: model_mod.init_params(jax.random.PRNGKey(0), cfg))
            p_sh = param_sharding(axes, mesh, rules, like=params_struct)
            caches = jax.eval_shape(
                lambda: model_mod.init_caches(cfg, shape.global_batch,
                                              shape.seq_len))
            cache_ax = model_mod.cache_logical_axes(cfg)
            c_sh = param_sharding(cache_ax, mesh, rules, like=caches)
            tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            cur = jax.ShapeDtypeStruct((), jnp.int32)
            decode = model_mod.make_serve_fns(cfg).decode
            lowered = jax.jit(
                decode,
                in_shardings=(p_sh, c_sh, batch_shardings(mesh, rules, tok),
                              NamedSharding(mesh, P())),
                out_shardings=(None, c_sh),
                donate_argnums=(1,)).lower(params_struct, caches, tok, cur)
        compiled = lowered.compile()
    return lowered, compiled


# -- one full cell run -----------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules_extra=None, cfg_overrides=None,
             skip_probes: bool = False,
             microbatches: int = 1) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    cfg = configs.get_config(arch, **(cfg_overrides or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    rules = cell_rules(arch, shape_name, multi_pod, rules_extra)

    out: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "ok",
                           "microbatches": microbatches}
    t0 = time.time()
    _, compiled = lower_cell(cfg, shape, mesh, rules,
                             microbatches=microbatches)
    out["compile_s"] = round(time.time() - t0, 1)
    ma = compiled.memory_analysis()
    out["memory"] = {
        "argument_gib": ma.argument_size_in_bytes / 2 ** 30,
        "output_gib": ma.output_size_in_bytes / 2 ** 30,
        "temp_gib": ma.temp_size_in_bytes / 2 ** 30,
        "alias_gib": ma.alias_size_in_bytes / 2 ** 30,
    }
    out["memory"]["per_chip_gib"] = (
        out["memory"]["argument_gib"] + out["memory"]["temp_gib"]
        - out["memory"]["alias_gib"])
    full_costs = costs_from_compiled(compiled)
    out["full_compile_costs"] = dataclasses.asdict(full_costs)
    del compiled

    if multi_pod or skip_probes:
        return out

    # -- probes (single-pod roofline) --
    base_cfg, probes = probe_configs(cfg, shape)
    _, c_base = lower_cell(base_cfg, shape, mesh, rules)
    base_costs = costs_from_compiled(c_base)
    del c_base
    deltas = []
    for pcfg, count in probes:
        _, c_p = lower_cell(pcfg, shape, mesh, rules)
        deltas.append((costs_from_compiled(c_p), count))
        del c_p
    corr = slstm_correction(cfg, shape, chips)
    total = combine_costs(base_costs, deltas, corrections=corr)
    rep = roofline_report(arch, shape, mesh_name, chips, total, cfg)
    out["roofline"] = {
        "flops_per_chip": total.flops,
        "hbm_bytes_per_chip": total.hbm_bytes,
        "bytes_accessed_per_chip": total.bytes_accessed,
        "coll_bytes_per_chip": total.coll_bytes,
        "coll_by_kind": total.coll_by_kind,
        "compute_s": rep.compute_s,
        "memory_s": rep.memory_s,
        "collective_s": rep.collective_s,
        "dominant": rep.dominant,
        "step_s": rep.step_s,
        "model_flops": rep.model_flops,
        "useful_ratio": rep.useful_ratio,
        "roofline_fraction": rep.roofline_fraction,
    }
    return out


def cells(only_arch=None, only_shape=None):
    for arch in configs.ARCH_NAMES:
        if only_arch and arch != only_arch:
            continue
        cfg = configs.get_config(arch)
        for shape_name in SHAPES:
            if only_shape and shape_name != only_shape:
                continue
            if not shape_applicable(cfg, shape_name):
                continue
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--skip-probes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    args = ap.parse_args()

    todo = list(cells(args.arch, args.shape))
    if not todo:
        raise SystemExit("no cells selected")

    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") == "ok":
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    meshes = []
    if not args.multi_pod:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    with open(args.out, "a") as f:
        for arch, shape_name in todo:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape_name, mesh_name) in done:
                    print(f"skip {arch} {shape_name} {mesh_name} (done)")
                    continue
                print(f"=== {arch} {shape_name} {mesh_name}", flush=True)
                try:
                    res = run_cell(arch, shape_name, mp,
                                   skip_probes=args.skip_probes)
                except Exception as e:  # record failures, keep sweeping
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(res["error"], flush=True)
                f.write(json.dumps(res) + "\n")
                f.flush()
                jax.clear_caches()
                if res["status"] == "ok":
                    print(f"    compile={res.get('compile_s')}s "
                          f"mem/chip={res['memory']['per_chip_gib']:.2f}GiB"
                          + (f" dom={res['roofline']['dominant']}"
                             if "roofline" in res else ""), flush=True)


if __name__ == "__main__":
    main()
