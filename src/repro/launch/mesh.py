"""Production meshes + per-arch sharding rules.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state): (16, 16) (data, model) single-pod, or
(2, 16, 16) (pod, data, model) for the 2-pod = 512-chip dry-run.

Sharding strategy (DESIGN.md §6), expressed as logical-axis rules:

  * activations: batch -> (pod, data); heads/ffn/vocab/experts -> model (TP/EP)
  * weights-at-rest: the "d_model" rule maps to (pod, data) — weight
    matrices carry a d_model dimension, so they are FSDP-sharded across
    the data axes *at rest* and all-gathered per layer by GSPMD.
    Activations are untouched because their batch dim claims (pod, data)
    first and a mesh axis is never assigned twice within one tensor.
  * per-arch overrides: xlstm is pure-DP at baseline (4-head mLSTM
    tensor-parallelism is a §Perf hillclimb, not a default); long-context
    decode shards the KV-cache sequence axis instead of heads.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax

from repro.sharding.specs import DEFAULT_RULES, MeshAxes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 512 if multi_pod else 256
    devices = jax.devices()[:ndev]
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for the production mesh, have "
            f"{len(devices)} — dryrun.py sets "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            f"importing jax")
    import numpy as np
    dev_array = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


# -- rules ---------------------------------------------------------------------

def base_rules(multi_pod: bool) -> Dict[str, MeshAxes]:
    rules = dict(DEFAULT_RULES)
    rules["batch"] = ("pod", "data") if multi_pod else ("data",)
    # FSDP-at-rest for weight matrices (see module docstring)
    rules["d_model"] = ("pod", "data") if multi_pod else ("data",)
    return rules


ARCH_RULE_OVERRIDES: Dict[str, Dict[str, MeshAxes]] = {
    # xlstm: 4 heads / small dims — TP pays one all-reduce per layer on a
    # (B, nh, Qc, S) tensor for no memory win at 1.3B. Baseline is DP-only
    # + FSDP; head-sharding is explored in §Perf.
    "xlstm-1.3b": {"lstm_inner": None, "ffn": None, "vocab": None,
                   "heads": None, "kv_heads": None},
}

SHAPE_RULE_OVERRIDES: Dict[str, Dict[str, MeshAxes]] = {
    # long-context decode: one sequence, 500k-token caches — shard the
    # cache sequence axis over the model axis (context parallelism).
    "long_500k": {"kv_seq": "model"},
}


def cell_rules(arch: str, shape_name: str,
               multi_pod: bool,
               extra: Optional[Dict[str, MeshAxes]] = None
               ) -> Dict[str, MeshAxes]:
    rules = base_rules(multi_pod)
    rules.update(ARCH_RULE_OVERRIDES.get(arch, {}))
    rules.update(SHAPE_RULE_OVERRIDES.get(shape_name, {}))
    if extra:
        rules.update(extra)
    return rules
