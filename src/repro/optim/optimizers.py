"""Optimizers: AdamW (fp32 moments) and Adafactor (factored second moment).

Built from scratch in JAX (no optax dependency).  Adafactor matters at
assigned-architecture scale: a 1T-param model's Adam moments (8 TB fp32)
cannot fit 512 v5e chips, while Adafactor's factored statistics add only
O(rows+cols) per matrix — the ≥100B configs default to it (DESIGN.md §6).

The optimizer state tree mirrors the param tree, so the logical-axes tree
used for parameter sharding shards the state identically (ZeRO-3-style
sharding falls out of the same rules table).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"              # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    factored_min_dim: int = 128


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: jnp.ndarray
    inner: Any                       # optimizer-specific tree


# -- schedule -----------------------------------------------------------------

def wsd_schedule(cfg: OptimizerConfig, step):
    """Warmup-stable-decay (linear warmup, cosine decay to min_lr_frac)."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1.0, cfg.warmup_steps))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * decay


# -- grad clip ------------------------------------------------------------------

def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


# -- AdamW ----------------------------------------------------------------------

def adamw(cfg: OptimizerConfig):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner={"m": jax.tree.map(zeros, params),
                               "v": jax.tree.map(zeros, params)})

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr = wsd_schedule(cfg, step)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = cfg.b1 * m + (1 - cfg.b1) * g32
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
            mh, vh = m / bc1, v / bc2
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:   # decay matrices only (standard practice)
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.inner["m"], state.inner["v"],
                           params)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step=step, inner={"m": new_m, "v": new_v})

    return init, update


# -- Adafactor --------------------------------------------------------------------

def _factored(p, min_dim: int) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= min_dim and p.shape[-2] >= min_dim


def adafactor(cfg: OptimizerConfig):
    """Adafactor with momentum-free updates and factored second moments."""

    def init(params):
        def stat(p):
            if _factored(p, cfg.factored_min_dim):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros((*p.shape[:-2], p.shape[-1]),
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return OptState(step=jnp.zeros((), jnp.int32),
                        inner=jax.tree.map(stat, params,
                                           is_leaf=None))

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr = wsd_schedule(cfg, step)
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-cfg.decay_rate)

        def upd(g, s, p):
            g32 = jnp.square(g.astype(jnp.float32)) + 1e-30
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g32.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g32.mean(-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(vr.mean(-1, keepdims=True)[..., None],
                                  1e-30))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g32
                denom = jnp.sqrt(v)
                new_s = {"v": v}
            delta = g.astype(jnp.float32) / jnp.maximum(denom, 1e-30)
            # update clipping (Adafactor's RMS-1 rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + 1e-30)
            delta = delta / jnp.maximum(1.0, rms)
            if p.ndim >= 2:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = [s for s in _iter_states(state.inner, tdef)]
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_s = tdef.unflatten([o[1] for o in outs])
        return new_p, OptState(step=step, inner=new_s)

    return init, update


def _iter_states(inner, tdef):
    """Flatten the per-param stat dicts in param-tree order."""
    return tdef.flatten_up_to(inner)


# -- factory ----------------------------------------------------------------------

def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return adamw(cfg)
    if cfg.name == "adafactor":
        return adafactor(cfg)
    raise ValueError(f"unknown optimizer {cfg.name!r}")


def opt_state_logical_axes(params, axes_tree, opt_cfg: OptimizerConfig):
    """Logical-axes tree for ``OptState.inner``, mirroring the params.

    ``params`` may be arrays or ShapeDtypeStructs (shapes decide adafactor
    factoring).  The ``step`` counter is always replicated (axes ()).
    """
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    if opt_cfg.name == "adamw":
        return {"m": axes_tree, "v": axes_tree}

    def stat_axes(p, ax):
        if _factored(p, opt_cfg.factored_min_dim):
            return {"vr": tuple(ax[:-1]), "vc": (*ax[:-2], ax[-1])}
        return {"v": tuple(ax)}

    flat_p, tdef = jax.tree.flatten(params)
    flat_ax = tdef.flatten_up_to(axes_tree)
    del is_axes
    return tdef.unflatten([stat_axes(p, ax)
                           for p, ax in zip(flat_p, flat_ax)])
