from repro.optim.optimizers import (OptState, OptimizerConfig, adafactor,
                                    adamw, clip_by_global_norm, global_norm,
                                    make_optimizer, opt_state_logical_axes,
                                    wsd_schedule)
from repro.optim.compression import (compress_int8, decompress_int8,
                                     compressed_psum_bytes)

__all__ = ["OptimizerConfig", "OptState", "adamw", "adafactor",
           "make_optimizer", "clip_by_global_norm", "wsd_schedule",
           "compress_int8", "decompress_int8", "compressed_psum_bytes"]
