"""int8 gradient compression with stochastic rounding.

Distributed-optimization trick for the cross-pod gradient reduction: the
"pod" mesh axis crosses the slow inter-pod links (DCN or long ICI hops),
so its all-reduce is compressed 4x: per-tensor absmax scale -> int8 with
stochastic rounding (unbiased) -> psum over the pod axis -> rescale.

Used by train.make_train_step when ``pod_grad_compression=True``; the
reduction over the fast in-pod "data" axis stays full-precision, so the
compression error enters once per step, not per hop.  Stochastic rounding
keeps the quantizer unbiased, which is what lets SGD-type methods tolerate
it (gradient noise >> quantization noise at int8).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jnp.ndarray, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (any float) -> (int8 codes, fp32 scale). Unbiased."""
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    y = x32 / scale
    noise = jax.random.uniform(key, x.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127)
    return q.astype(jnp.int8), scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum_bytes(x: jnp.ndarray) -> int:
    """Bytes on the wire for the compressed reduction (codes + scale)."""
    return x.size + 4


def psum_compressed(x: jnp.ndarray, axis_name: str, key) -> jnp.ndarray:
    """Unbiased compressed psum over ``axis_name`` (shard_map context).

    The int8 codes are summed in int32 (no overflow for <= 2**23 members),
    scales are max-reduced; the result is the decompressed sum.  Relative
    to a float psum this moves ~4x fewer bytes over the axis.
    """
    q, scale = compress_int8(x, key)
    scale_max = jax.lax.pmax(scale, axis_name)
    # renormalize codes to the shared scale so the int sum is coherent
    q = jnp.clip(jnp.round(q.astype(jnp.float32) * (scale / scale_max)),
                 -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale_max
