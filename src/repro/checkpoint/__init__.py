from repro.checkpoint.manager import (CheckpointManager, CheckpointMeta,
                                      latest_step, restore, save)

__all__ = ["CheckpointManager", "CheckpointMeta", "save", "restore",
           "latest_step"]
