"""Fault-tolerant checkpointing: atomic, async, elastic, energy-aware.

Production properties (DESIGN.md §6):

  * **Atomic**: write to ``step_<n>.tmp/``, fsync, write a manifest with
    per-leaf checksums, then ``rename`` — a crash mid-save never corrupts
    the latest valid checkpoint; restore always picks the newest manifest
    that validates.
  * **Async**: ``save(..., blocking=False)`` snapshots to host memory
    (device_get) and writes on a background thread, so the train loop
    stalls only for the device->host copy, not the disk write.
  * **Elastic**: leaves are stored *unsharded* (host-gathered numpy), so a
    restore can re-shard onto ANY mesh shape — the restart does not need
    the same number of hosts/chips (elastic scaling).
  * **Energy-aware** (the PMT integration): the manifest embeds the
    PowerMonitor's cumulative joules, so a restarted run continues its
    energy accounting — energy is part of fault-tolerant state, the same
    way the data-pipeline step counter is.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class CheckpointMeta:
    step: int
    cumulative_joules: float = 0.0
    joules_per_step_ema: float = 0.0
    data_step: int = 0
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, tdef = jax.tree.flatten(tree)
    return [np.asarray(jax.device_get(l)) for l in leaves], tdef


def _leaf_path(d: str, i: int) -> str:
    return os.path.join(d, f"leaf_{i:05d}.npy")


def save(directory: str, step: int, tree, meta: CheckpointMeta,
         blocking: bool = True) -> Optional[threading.Thread]:
    """Write one checkpoint. Returns the writer thread when async."""
    leaves, tdef = _flatten(tree)

    def write():
        tmp = os.path.join(directory, f"step_{step:08d}.tmp")
        final = os.path.join(directory, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        checksums = []
        for i, leaf in enumerate(leaves):
            with open(_leaf_path(tmp, i), "wb") as f:
                np.save(f, leaf)
                f.flush()
                os.fsync(f.fileno())
            checksums.append(zlib.crc32(leaf.tobytes()))
        manifest = {
            "step": step,
            "num_leaves": len(leaves),
            "checksums": checksums,
            "treedef": str(tdef),
            "meta": dataclasses.asdict(meta),
        }
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)            # atomic publish

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def _valid_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            m = os.path.join(directory, name, "manifest.json")
            if os.path.exists(m):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = _valid_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, like_tree, step: Optional[int] = None,
            shard_fn: Optional[Callable[[np.ndarray, int], Any]] = None
            ) -> Tuple[Any, CheckpointMeta]:
    """Restore the newest (or given) valid checkpoint.

    ``like_tree`` supplies the treedef (shapes may live on any mesh — pass
    ``shard_fn(leaf_np, leaf_index) -> jax.Array`` to place each leaf with
    the *current* run's shardings; this is the elastic-reshard path).
    Corrupt checkpoints (checksum mismatch) are skipped, falling back to
    the previous one.
    """
    steps = _valid_steps(directory)
    if step is not None:
        steps = [s for s in steps if s == step]
    if not steps:
        raise FileNotFoundError(f"no valid checkpoint under {directory}")

    _, tdef = jax.tree.flatten(like_tree)
    for s in reversed(steps):
        d = os.path.join(directory, f"step_{s:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            leaves = []
            ok = True
            for i in range(manifest["num_leaves"]):
                leaf = np.load(_leaf_path(d, i))
                if zlib.crc32(leaf.tobytes()) != manifest["checksums"][i]:
                    ok = False
                    break
                leaves.append(leaf)
            if not ok:
                continue
            if shard_fn is not None:
                leaves = [shard_fn(l, i) for i, l in enumerate(leaves)]
            meta = CheckpointMeta(**manifest["meta"])
            return tdef.unflatten(leaves), meta
        except (OSError, ValueError, KeyError):
            continue
    raise IOError(f"all checkpoints under {directory} failed validation")


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints, saves every ``every`` steps,
    one in-flight async save at a time (back-pressure, not a queue)."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.every = every
        self.keep = keep
        self.async_save = async_save
        self._inflight: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, tree, meta: CheckpointMeta) -> bool:
        if step % self.every:
            return False
        if self._inflight is not None:
            self._inflight.join()       # back-pressure
        self._inflight = save(self.directory, step, tree, meta,
                              blocking=not self.async_save)
        self._gc()
        return True

    def finalize(self):
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None
        self._gc()   # the last async save published after its gc pass

    def _gc(self):
        steps = _valid_steps(self.directory)
        for s in steps[:-self.keep]:
            d = os.path.join(self.directory, f"step_{s:08d}")
            for name in os.listdir(d):
                os.remove(os.path.join(d, name))
            os.rmdir(d)
