from repro.train.steps import (TrainState, init_train_state,
                               make_measured_train_step, make_train_step)

__all__ = ["TrainState", "make_train_step", "make_measured_train_step",
           "init_train_state"]
