"""Train step: loss -> grads -> clip -> optimizer, with microbatching.

The step is a pure function (params, opt_state, batch) -> (params,
opt_state, metrics) designed for pjit: model code carries logical-axis
sharding constraints, the launcher supplies in/out shardings, and GSPMD
inserts the gradient reduce-scatter/all-reduce over the (pod, data) axes.

Microbatch accumulation (``microbatches > 1``) is a Python-unrolled loop
(not lax.scan) for two reasons: XLA overlaps each microbatch's gradient
reduction with the next microbatch's compute (async collectives), and the
roofline accounting stays exact (scan bodies are cost-counted once).

Energy measurement goes through a shared ``pmt.Session``
(:func:`make_measured_train_step`): the step runs inside a session
region fenced by ``block_until_ready``; region exit enqueues the span
O(1) and per-step energy resolves on the session's background resolver
thread off the same sampler the serve engine and any monitors use — no
sensor reads or resolution work interleaved with dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod
from repro.optim.optimizers import (OptState, OptimizerConfig,
                                    clip_by_global_norm, make_optimizer,
                                    wsd_schedule)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: OptState


def init_train_state(key, cfg: ModelConfig, opt_cfg: OptimizerConfig
                     ) -> Tuple[TrainState, Any]:
    """Returns (state, logical-axes tree for params)."""
    params, axes = model_mod.init_params(key, cfg)
    init_opt, _ = make_optimizer(opt_cfg)
    return TrainState(params=params, opt=init_opt(params)), axes


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    def sp(x):
        b = x.shape[0]
        if b % n:
            raise ValueError(f"batch {b} not divisible by {n} microbatches")
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(sp, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_f = model_mod.loss_fn(cfg)
    _, update = make_optimizer(opt_cfg)
    grad_f = jax.value_and_grad(loss_f, has_aux=True)

    def train_step(state: TrainState, batch):
        if microbatches == 1:
            (_, metrics), grads = grad_f(state.params, batch)
        else:
            mb = _split_microbatches(batch, microbatches)
            grads = None
            metrics = None
            for i in range(microbatches):
                bi = jax.tree.map(lambda x: x[i], mb)
                (_, m), g = grad_f(state.params, bi)
                scale = 1.0 / microbatches
                g = jax.tree.map(
                    lambda a: (a.astype(jnp.float32) * scale), g)
                grads = g if grads is None else jax.tree.map(
                    jnp.add, grads, g)
                metrics = m if metrics is None else jax.tree.map(
                    jnp.add, metrics, m)
            metrics = jax.tree.map(lambda x: x / microbatches, metrics)

        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt = update(grads, state.opt, state.params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = wsd_schedule(opt_cfg, opt.step)
        return TrainState(params=params, opt=opt), metrics

    return train_step


def make_measured_train_step(step_fn: Callable, monitor,
                             tokens_per_step: Optional[int] = None,
                             flops_per_step: Optional[float] = None,
                             fence_key: str = "loss",
                             blocking: bool = False):
    """Wrap a (jitted) train step with fenced PMT measurement.

    ``monitor`` is a :class:`repro.core.PowerMonitor`; its session region
    brackets the step, and ``metrics[fence_key]`` is blocked on before
    the region exits so asynchronous dispatch can't leak a step's tail
    into its successor.

    Measurement is non-blocking by default: region exit is an O(1) span
    enqueue, the step's energy resolves on the session's background
    resolver thread, and the monitor's cumulative accounting / CSV log
    update as spans resolve.  No per-step measurement dict is built on
    the training thread.  Returns ``measured(state, batch, step) ->
    (state, metrics, box)`` where ``box.records`` is future-style: it
    materialises the step's :class:`StepEnergy` rows on first access
    (resolving synchronously if the resolver has not got there yet), so
    a loop that logs every Nth step only pays resolution on those steps.
    Pass ``blocking=True`` to restore eager per-step materialisation.
    """

    def measured(state, batch, step: int):
        with monitor.measure_step(step, flops=flops_per_step,
                                  tokens=tokens_per_step,
                                  blocking=blocking) as box:
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics[fence_key])
        return state, metrics, box

    return measured
