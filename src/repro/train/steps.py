"""Train step: loss -> grads -> clip -> optimizer, with microbatching.

The step is a pure function (params, opt_state, batch) -> (params,
opt_state, metrics) designed for pjit: model code carries logical-axis
sharding constraints, the launcher supplies in/out shardings, and GSPMD
inserts the gradient reduce-scatter/all-reduce over the (pod, data) axes.

Microbatch accumulation (``microbatches > 1``) is a Python-unrolled loop
(not lax.scan) for two reasons: XLA overlaps each microbatch's gradient
reduction with the next microbatch's compute (async collectives), and the
roofline accounting stays exact (scan bodies are cost-counted once).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod
from repro.optim.optimizers import (OptState, OptimizerConfig,
                                    clip_by_global_norm, make_optimizer,
                                    wsd_schedule)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: OptState


def init_train_state(key, cfg: ModelConfig, opt_cfg: OptimizerConfig
                     ) -> Tuple[TrainState, Any]:
    """Returns (state, logical-axes tree for params)."""
    params, axes = model_mod.init_params(key, cfg)
    init_opt, _ = make_optimizer(opt_cfg)
    return TrainState(params=params, opt=init_opt(params)), axes


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    def sp(x):
        b = x.shape[0]
        if b % n:
            raise ValueError(f"batch {b} not divisible by {n} microbatches")
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(sp, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_f = model_mod.loss_fn(cfg)
    _, update = make_optimizer(opt_cfg)
    grad_f = jax.value_and_grad(loss_f, has_aux=True)

    def train_step(state: TrainState, batch):
        if microbatches == 1:
            (_, metrics), grads = grad_f(state.params, batch)
        else:
            mb = _split_microbatches(batch, microbatches)
            grads = None
            metrics = None
            for i in range(microbatches):
                bi = jax.tree.map(lambda x: x[i], mb)
                (_, m), g = grad_f(state.params, bi)
                scale = 1.0 / microbatches
                g = jax.tree.map(
                    lambda a: (a.astype(jnp.float32) * scale), g)
                grads = g if grads is None else jax.tree.map(
                    jnp.add, grads, g)
                metrics = m if metrics is None else jax.tree.map(
                    jnp.add, metrics, m)
            metrics = jax.tree.map(lambda x: x / microbatches, metrics)

        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt = update(grads, state.opt, state.params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = wsd_schedule(opt_cfg, opt.step)
        return TrainState(params=params, opt=opt), metrics

    return train_step
