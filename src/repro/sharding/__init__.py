from repro.sharding.specs import (axis_rules, current_rules, logical_to_spec,
                                  param_sharding, shard, split_params,
                                  DEFAULT_RULES)

__all__ = ["axis_rules", "current_rules", "logical_to_spec", "shard",
           "param_sharding", "split_params", "DEFAULT_RULES"]
