"""Logical-axis sharding rules (MaxText-style) for the whole framework.

Models annotate every parameter and key activation with *logical* axis
names ("batch", "heads", "ffn", "experts", ...).  A rules table maps
logical names to physical mesh axes; changing a parallelism strategy is a
rules edit, not a model edit — which is exactly what the §Perf hillclimb
iterates on.

Outside a rules context (plain CPU tests) every helper degrades to a
no-op, so models run unmodified on one device.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# Baseline rules for the production mesh (data, model) / (pod, data, model).
# "pod" composes with "data" for pure data parallelism across pods.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),       # token batch
    "seq": None,                    # sequence (unsharded by default)
    "kv_seq": None,                 # KV-cache sequence axis
    "d_model": None,                # residual stream
    "heads": "model",               # attention heads (TP)
    "kv_heads": "model",            # grouped KV heads (TP)
    "head_dim": None,
    "ffn": "model",                 # MLP hidden (TP)
    "vocab": "model",               # embedding/lm-head vocab (TP)
    "experts": "model",             # MoE experts (EP)
    "expert_cap": None,
    "layers": None,                 # scanned layer stacks
    "mamba_inner": "model",
    "lstm_inner": "model",
    "q_rank": None,                 # MLA low-rank axes
    "kv_rank": None,
    # long-context decode: shard the cache sequence axis instead of heads
    # (activated by the serve path for long_500k cells via rule override).
}

_current: contextvars.ContextVar[Optional[Tuple[Dict[str, MeshAxes],
                                                Optional[Dict[str, int]]]]] \
    = contextvars.ContextVar("pmt_axis_rules", default=None)


@contextlib.contextmanager
def axis_rules(rules: Optional[Dict[str, MeshAxes]],
               mesh_sizes: Optional[Dict[str, int]] = None):
    """Activate a logical→mesh mapping for the enclosed region.

    ``mesh_sizes`` ({mesh axis: size}) enables divisibility pruning: a
    tensor dimension is only sharded by the longest prefix of its mapped
    mesh axes whose product divides the dimension (GQA archs have e.g.
    3 kv heads on a 16-way model axis — those stay replicated).
    """
    token = _current.set(
        (dict(rules), dict(mesh_sizes) if mesh_sizes else None)
        if rules is not None else None)
    try:
        yield
    finally:
        _current.reset(token)


def current_rules() -> Optional[Dict[str, MeshAxes]]:
    cur = _current.get()
    return cur[0] if cur is not None else None


def current_mesh_sizes() -> Optional[Dict[str, int]]:
    cur = _current.get()
    return cur[1] if cur is not None else None


def _divisible_prefix(axes_tuple: Tuple[str, ...], dim: Optional[int],
                      mesh_sizes: Optional[Dict[str, int]]
                      ) -> Tuple[str, ...]:
    if dim is None or mesh_sizes is None:
        return axes_tuple
    out = []
    prod = 1
    for a in axes_tuple:
        prod *= mesh_sizes.get(a, 1)
        if dim % prod:
            break
        out.append(a)
    return tuple(out)


def logical_to_spec(axes: Sequence[Optional[str]],
                    rules: Optional[Dict[str, MeshAxes]] = None,
                    shape: Optional[Sequence[int]] = None,
                    mesh_sizes: Optional[Dict[str, int]] = None) -> P:
    """Translate logical axis names to a PartitionSpec under ``rules``.

    A mesh axis may be claimed by at most one tensor dimension; later
    claims degrade to replication (standard logical-rules semantics).
    With ``shape``+``mesh_sizes``, non-divisible dims degrade too.
    """
    rules = rules if rules is not None else (current_rules() or {})
    mesh_sizes = mesh_sizes if mesh_sizes is not None \
        else current_mesh_sizes()
    used = set()
    spec = []
    for i, ax in enumerate(axes):
        mapped = rules.get(ax) if ax is not None else None
        if mapped is None:
            spec.append(None)
            continue
        axes_tuple = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        free = tuple(a for a in axes_tuple if a not in used)
        dim = shape[i] if shape is not None else None
        free = _divisible_prefix(free, dim, mesh_sizes)
        if not free:
            spec.append(None)
            continue
        used.update(free)
        spec.append(free if len(free) > 1 else free[0])
    return P(*spec)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a with_sharding_constraint from logical axes (no-op w/o rules)."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, logical_to_spec(axes, rules, shape=x.shape))


# ---------------------------------------------------------------------------
# Parameter annotation: init code returns leaves of (array, logical_axes);
# split_params separates value tree from axes tree.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Annotated:
    value: Any
    axes: Tuple[Optional[str], ...]


def annotate(value, *axes: Optional[str]) -> Annotated:
    if hasattr(value, "ndim") and value.ndim != len(axes):
        raise ValueError(f"axes {axes} rank-mismatch value {value.shape}")
    return Annotated(value, tuple(axes))


def split_params(tree):
    """(values_tree, axes_tree) from a tree with Annotated leaves."""
    is_leaf = lambda x: isinstance(x, Annotated)
    values = jax.tree.map(lambda a: a.value, tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda a: a.axes, tree, is_leaf=is_leaf)
    return values, axes


def param_sharding(axes_tree, mesh, rules: Optional[Dict[str, MeshAxes]] = None,
                   like=None):
    """NamedSharding tree for params given their logical-axes tree.

    ``like``: matching tree of arrays/ShapeDtypeStructs enabling
    divisibility pruning per leaf.
    """
    rules = rules if rules is not None else DEFAULT_RULES
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    if like is None:
        return jax.tree.map(
            lambda ax: NamedSharding(
                mesh, logical_to_spec(ax, rules, mesh_sizes=sizes)),
            axes_tree, is_leaf=is_axes)
    flat_like, tdef = jax.tree.flatten(like)
    flat_ax = tdef.flatten_up_to(axes_tree)
    out = [NamedSharding(mesh, logical_to_spec(ax, rules, shape=l.shape,
                                               mesh_sizes=sizes))
           for l, ax in zip(flat_like, flat_ax)]
    return tdef.unflatten(out)
