"""repro — PMT (Power Measurement Toolkit) + a multi-pod JAX framework.

``repro.core`` is the PMT library itself (import it as ``pmt``);
sibling subpackages are the training/serving framework it instruments.
"""
from repro import core as pmt  # noqa: F401

__all__ = ["pmt"]
