"""Collective-byte accounting from partitioned HLO text.

``compiled.as_text()`` (post-SPMD) lists every collective with its result
shape and replica groups, e.g.::

  %all-reduce.2 = f32[32,512]{1,0} all-reduce(%dot.1), channel_id=1,
      replica_groups=[2,4]<=[8], ...

We sum *operand* bytes per the brief's convention:

  all-reduce / all-to-all / collective-permute : operand == result
  all-gather                                   : operand == result / group
  reduce-scatter                               : operand == result * group

Tuple-shaped results (variadic collectives, -start ops) are handled by
summing every tensor in the tuple; ``*-done`` ops are skipped so async
pairs are not double counted.

The probe lowerings that feed the roofline are compiled with
``scan_layers=False`` so the text contains no while loops — a flat sum
over the module is exact (see launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")

# one tensor shape: f32[1,2,3] (layout braces optional)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# an instruction line: %name = <shape or tuple> <opcode>(
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].split("{")[-1]
        return max(1, len([t for t in first.split(",") if t.strip() != ""]))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


# ops whose result is a genuine HBM round-trip even under aggressive
# (TPU-grade) fusion: contraction/reduction/data-movement roots.
# Elementwise/layout ops (convert/broadcast/add/transpose/...) are treated
# as fused into their consumers — the CPU backend leaves them top-level,
# a TPU compile would not.
_MAJOR_OPS = {
    "dot", "convolution", "fusion", "custom-call", "scatter", "gather",
    "sort", "reduce", "reduce-window", "concatenate", "pad",
    "dynamic-slice", "dynamic-update-slice", "copy", "while", "conditional",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "rng", "rng-bit-generator", "select-and-scatter",
    "cholesky", "triangular-solve", "fft",
}

_ENTRY_RE = re.compile(r"^ENTRY\b")
_TOP_INSTR_RE = re.compile(
    r"^\s{2}(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")


def buffer_traffic_bytes(hlo_text: str) -> float:
    """Idealized-fusion HBM traffic of the optimized module.

    Sums result-buffer bytes (x2: write + downstream read) of the
    top-level ENTRY instructions whose opcode is a *major* buffer producer
    (``_MAJOR_OPS``).  Elementwise chains are assumed fused (VMEM-resident)
    as a TPU compile would do; the CPU backend's partially-fused HLO would
    otherwise overcount them ~10x.  This is a lower-bound traffic model;
    XLA's unfused ``bytes accessed`` (also recorded) is the upper bound.
    """
    total = 0.0
    in_entry = False
    for line in hlo_text.splitlines():
        if _ENTRY_RE.match(line):
            in_entry = True
            continue
        if in_entry and line.startswith("}"):
            in_entry = False
            continue
        if not in_entry:
            continue
        m = _TOP_INSTR_RE.match(line)
        if not m:
            continue
        shape_text, op = m.group(1), m.group(2)
        base = op[:-6] if op.endswith("-start") else op
        if base not in _MAJOR_OPS:
            continue
        total += 2.0 * _shape_bytes(shape_text)
    return total


def collective_bytes(hlo_text: str) -> CollectiveStats:
    by_kind = {k: 0.0 for k in _KINDS}
    counts = {k: 0 for k in _KINDS}
    for line in hlo_text.splitlines():
        # fast reject
        if "channel_id" not in line and "replica_groups" not in line:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_text, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        base = None
        for k in _KINDS:
            if op == k or op == k + "-start":
                base = k
                break
        if base is None:
            continue
        rb = _shape_bytes(shape_text)
        g = _group_size(line)
        if base == "all-gather":
            rb = rb / max(1, g)
        elif base == "reduce-scatter":
            rb = rb * g
        by_kind[base] += rb
        counts[base] += 1
    return CollectiveStats(by_kind, counts)
