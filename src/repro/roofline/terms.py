"""Roofline terms from compiled artifacts (see EXPERIMENTS.md §Roofline).

All three terms are *per-chip seconds* on TPU v5e constants:

  compute_s    = flops_per_chip / 197e12
  memory_s     = bytes_accessed_per_chip / 819e9
  collective_s = collective_bytes_per_chip / 50e9   (1 ICI link, worst case)

``cost_analysis()`` on a partitioned compile reports per-chip numbers
(SPMD = one program per chip), which is what we want.

Scan bodies are cost-counted once by XLA, so totals are assembled from
unrolled *probe* compiles (launch/dryrun.py): a base compile with one
unit per stack and one with two; per-unit delta x unit count + base =
exact post-optimization totals.  ``combine_costs`` implements that.

``model_flops`` is the brief's useful-work definition (6·N·D train /
2·N·D inference, N = active params), used for the usefulness ratio
MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.configs.base import InputShape, ModelConfig
from repro.core.energy_model import TPU_V5E, HardwareSpec
from repro.roofline.hlo import buffer_traffic_bytes, collective_bytes


@dataclasses.dataclass
class CellCosts:
    """Per-chip costs of one compiled step.

    ``hbm_bytes`` is the buffer-traffic model (top-level result buffers of
    the optimized HLO, write+read — see roofline.hlo); ``bytes_accessed``
    is XLA's unfused upper bound, kept for reference.
    """
    flops: float
    hbm_bytes: float
    coll_bytes: float
    bytes_accessed: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __add__(self, other: "CellCosts") -> "CellCosts":
        kinds = set(self.coll_by_kind) | set(other.coll_by_kind)
        return CellCosts(
            self.flops + other.flops,
            self.hbm_bytes + other.hbm_bytes,
            self.coll_bytes + other.coll_bytes,
            self.bytes_accessed + other.bytes_accessed,
            {k: self.coll_by_kind.get(k, 0) + other.coll_by_kind.get(k, 0)
             for k in kinds})

    def scaled(self, a: float) -> "CellCosts":
        return CellCosts(self.flops * a, self.hbm_bytes * a,
                         self.coll_bytes * a, self.bytes_accessed * a,
                         {k: v * a for k, v in self.coll_by_kind.items()})


def costs_from_compiled(compiled) -> CellCosts:
    ca = compiled.cost_analysis() or {}
    # Older jax returns a one-element list of dicts (per device kind);
    # newer jax returns the dict directly.  Normalize to the dict.
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    text = compiled.as_text()
    stats = collective_bytes(text)
    return CellCosts(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=buffer_traffic_bytes(text),
        coll_bytes=stats.total_bytes,
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll_by_kind=dict(stats.bytes_by_kind))


def combine_costs(base: CellCosts,
                  deltas: List[Tuple[CellCosts, int]],
                  corrections: Optional[CellCosts] = None) -> CellCosts:
    """base + sum((probe2 - base) * (count - 1)) + analytic corrections."""
    total = base
    for probe2, count in deltas:
        delta = CellCosts(
            max(0.0, probe2.flops - base.flops),
            max(0.0, probe2.hbm_bytes - base.hbm_bytes),
            max(0.0, probe2.coll_bytes - base.coll_bytes),
            max(0.0, probe2.bytes_accessed - base.bytes_accessed),
            {k: max(0.0, v - base.coll_by_kind.get(k, 0.0))
             for k, v in probe2.coll_by_kind.items()})
        total = total + delta.scaled(count - 1)
    if corrections is not None:
        total = total + corrections
    return total


# -- useful-work model -----------------------------------------------------------

def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference), D = tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch            # one new token per row
    return 2.0 * n * tokens


# -- report ------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    costs: CellCosts
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    step_s: float                     # max of the three (no-overlap bound)
    model_flops: float
    useful_ratio: float               # MODEL_FLOPS / global HLO flops
    roofline_fraction: float          # compute_s / step_s
    note: str = ""

    def row(self) -> str:
        return (f"{self.arch:18s} {self.shape:12s} {self.mesh:10s} "
                f"C={self.compute_s:9.4f}s M={self.memory_s:9.4f}s "
                f"X={self.collective_s:9.4f}s dom={self.dominant:10s} "
                f"useful={self.useful_ratio:6.3f} "
                f"roofline={self.roofline_fraction:6.3f}")


def roofline_report(arch: str, shape: InputShape, mesh_name: str,
                    chips: int, costs: CellCosts, cfg: ModelConfig,
                    hw: HardwareSpec = TPU_V5E, note: str = ""
                    ) -> RooflineReport:
    compute_s = costs.flops / hw.peak_flops
    memory_s = costs.hbm_bytes / hw.hbm_bw
    collective_s = costs.coll_bytes / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf = model_flops(cfg, shape)
    global_flops = costs.flops * chips
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        costs=costs, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant, step_s=step_s,
        model_flops=mf,
        useful_ratio=mf / global_flops if global_flops else 0.0,
        roofline_fraction=compute_s / step_s if step_s else 0.0,
        note=note)
