from repro.roofline.hlo import CollectiveStats, collective_bytes
from repro.roofline.terms import (CellCosts, RooflineReport, combine_costs,
                                  costs_from_compiled, model_flops,
                                  roofline_report)

__all__ = ["CollectiveStats", "collective_bytes", "CellCosts",
           "combine_costs", "costs_from_compiled", "RooflineReport",
           "roofline_report", "model_flops"]
