"""Top-level model: init / forward / loss / serve for all assigned archs.

Layer stacks are organized as *units*: the repeating pattern of the
architecture (1 layer for dense archs, the local/global pair for gemma2,
the 8-layer Mamba/attention block for jamba, the mLSTM/sLSTM pattern for
xlstm).  Unit parameters are stacked on a leading "layers" axis and the
stack runs under ``lax.scan`` (``cfg.scan_layers=False`` switches to a
Python loop — used by the roofline probe lowerings so every unit's FLOPs
appear in the HLO, and by the serve prefill which collects per-layer KV).

Entry points:
  init_params(key, cfg)             -> (param values, logical-axes tree)
  build_forward(cfg)                -> hidden-state forward fn
  loss_fn(cfg)                      -> (loss, metrics) fn  (chunked xent)
  make_serve_fns(cfg)               -> ServeFns(prefill, decode, prefill_chunk)
  init_caches / cache_layout        -> decode caches (+ dry-run specs)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, layers
from repro.sharding.specs import Annotated, annotate, shard, split_params


# -- unit layout -----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UnitLayout:
    prefix: Tuple[int, ...]          # absolute indices of unscanned layers
    unit_len: int
    n_units: int
    enc_units: int = 0               # whisper encoder stack (unit_len 1)

    @property
    def prefix_len(self) -> int:
        return len(self.prefix)


def unit_layout(cfg: ModelConfig) -> UnitLayout:
    enc = cfg.encoder_layers if cfg.is_encoder_decoder else 0
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        pre = cfg.moe.first_dense_layers
        return UnitLayout(tuple(range(pre)), 1, cfg.num_layers - pre, enc)
    if cfg.family == "hybrid":
        ul = len(cfg.hybrid_pattern)
    elif cfg.family == "ssm":
        ul = len(cfg.xlstm.pattern)
    elif cfg.layer_pattern:
        ul = len(cfg.layer_pattern)
    else:
        ul = 1
    if cfg.num_layers % ul:
        raise ValueError(f"{cfg.name}: {cfg.num_layers} layers not divisible "
                         f"by unit pattern length {ul}")
    return UnitLayout((), ul, cfg.num_layers // ul, enc)


def _stack_units(unit_trees: List[Any]):
    """Stack a list of Annotated param trees on a leading 'layers' axis."""
    is_leaf = lambda x: isinstance(x, Annotated)

    def stack(*leaves):
        return Annotated(jnp.stack([l.value for l in leaves]),
                         ("layers", *leaves[0].axes))

    return jax.tree.map(stack, *unit_trees, is_leaf=is_leaf)


# -- init ------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    """Returns (values_tree, logical_axes_tree)."""
    lay = unit_layout(cfg)
    keys = jax.random.split(key, 8 + lay.n_units + lay.enc_units)
    p: Dict[str, Any] = {
        "embed": layers.init_embedding(keys[0], cfg),
        "final_norm": layers.init_norm(keys[1], cfg),
    }
    if lay.prefix:
        kp = jax.random.split(keys[2], len(lay.prefix))
        p["prefix"] = {f"l{i}": blocks.init_block(kp[j], cfg, i)
                       for j, i in enumerate(lay.prefix)}
    units = []
    for u in range(lay.n_units):
        ku = jax.random.split(keys[3 + u], lay.unit_len)
        units.append({f"r{r}": blocks.init_block(
            ku[r], cfg, lay.prefix_len + u * lay.unit_len + r)
            for r in range(lay.unit_len)})
    p["units"] = _stack_units(units) if lay.n_units > 1 else units[0]

    if cfg.is_encoder_decoder:
        enc = []
        for u in range(lay.enc_units):
            enc.append({"r0": blocks.init_block(
                keys[3 + lay.n_units + u], cfg, u, encoder=True)})
        p["enc_units"] = _stack_units(enc) if lay.enc_units > 1 else enc[0]
        p["enc_final_norm"] = layers.init_norm(keys[2], cfg)

    if cfg.mtp:
        km = jax.random.split(keys[-1], 3)
        p["mtp"] = {
            "proj": annotate(layers.dense_init(
                km[0], (2 * cfg.d_model, cfg.d_model)), None, "d_model"),
            "block": blocks.init_block(km[1], cfg, 0),
            "norm": layers.init_norm(km[2], cfg),
        }
    values, axes = split_params(p)
    if cfg.param_dtype != "float32":
        values = jax.tree.map(lambda v: v.astype(cfg.param_dtype), values)
    return values, axes


# -- positions / input embedding ----------------------------------------------------

def _positions(cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    tokens = batch["tokens"]
    b, s = tokens.shape
    if cfg.m_rope:
        patch = batch["patch_embeds"].shape[1] if "patch_embeds" in batch \
            else 0
        return layers.mrope_positions(b, s, patch)
    return layers.default_positions(b, s)


def _input_embed(cfg: ModelConfig, params, batch):
    x = layers.embed_tokens(cfg, params["embed"], batch["tokens"])
    if "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
        x = shard(x, "batch", "seq", "d_model")
    if cfg.pos_embed == "sinusoidal":
        # whisper decoder: absolute sinusoidal positions
        s, d = x.shape[1], x.shape[2]
        x = x + layers.sinusoidal_embedding(s, d, x.dtype)[None]
    return x


# -- encoder (whisper) ----------------------------------------------------------------

def _run_encoder(cfg: ModelConfig, params, frame_embeds):
    b, s, d = frame_embeds.shape
    x = frame_embeds.astype(cfg.dtype) \
        + layers.sinusoidal_embedding(s, d, cfg.dtype)[None]
    x = shard(x, "batch", "seq", "d_model")
    pos = layers.default_positions(b, s)
    lay = unit_layout(cfg)

    def unit(x, up):
        x, _, _ = blocks.block_forward(cfg, up["r0"], x, pos, 0,
                                       encoder=True)
        return x

    x = _run_units(cfg, params["enc_units"], lay.enc_units, unit, x)
    return layers.apply_norm(cfg, params["enc_final_norm"], x)


def _run_units(cfg: ModelConfig, unit_params, n_units: int, unit_fn, x,
               aux0=None):
    """Scan or loop ``unit_fn`` over stacked unit params.

    unit_fn(x, unit_param_tree) -> x  (or (x, aux) when aux0 is given).
    """
    with_aux = aux0 is not None
    if n_units == 1:
        out = unit_fn(x, unit_params)
        return out if not with_aux else (out[0], aux0 + out[1])

    fn = unit_fn
    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.checkpoint_dots
                  if cfg.remat == "dots" else None)
        fn = jax.checkpoint(unit_fn, policy=policy)

    if cfg.scan_layers:
        def body(carry, up):
            if with_aux:
                xx, aux = carry
                xx, a = fn(xx, up)
                return (xx, aux + a), None
            return fn(carry, up), None

        carry0 = (x, aux0) if with_aux else x
        carry, _ = jax.lax.scan(body, carry0, unit_params)
        return carry

    aux = aux0
    for u in range(n_units):
        up = jax.tree.map(lambda a: a[u], unit_params)
        if with_aux:
            x, a = fn(x, up)
            aux = aux + a
        else:
            x = fn(x, up)
    return (x, aux) if with_aux else x


# -- forward -------------------------------------------------------------------------

def build_forward(cfg: ModelConfig):
    """Returns forward(params, batch) -> (hidden (B,S,d), aux_loss)."""
    lay = unit_layout(cfg)

    def forward(params, batch):
        x = _input_embed(cfg, params, batch)
        pos = _positions(cfg, batch)
        aux = jnp.zeros((), jnp.float32)
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = _run_encoder(cfg, params, batch["frame_embeds"])

        for i in lay.prefix:
            x, a, _ = blocks.block_forward(cfg, params["prefix"][f"l{i}"],
                                           x, pos, i, enc_out=enc_out)
            aux = aux + a

        def unit(xx, up):
            a_sum = jnp.zeros((), jnp.float32)
            for r in range(lay.unit_len):
                xx, a, _ = blocks.block_forward(
                    cfg, up[f"r{r}"], xx, pos, lay.prefix_len + r,
                    enc_out=enc_out)
                a_sum = a_sum + a
            return xx, a_sum

        x, aux = _run_units(cfg, params["units"], lay.n_units, unit, x,
                            aux0=aux)
        x = layers.apply_norm(cfg, params["final_norm"], x)
        return x, aux

    return forward


# -- loss ---------------------------------------------------------------------------

def _xent_chunk(cfg: ModelConfig, embed_params, h, targets):
    """Mean-sum NLL over one chunk. h: (B,C,d), targets: (B,C) (-1 pad)."""
    logits = layers.logits_from_hidden(cfg, embed_params, h)   # fp32
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.maximum(targets, 0)
    picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    valid = (targets >= 0).astype(jnp.float32)
    nll = (lse - picked) * valid
    return nll.sum(), valid.sum()


def _chunked_xent(cfg: ModelConfig, embed_params, hidden, targets):
    s = hidden.shape[1]
    ck = cfg.loss_chunk or s
    nb = math.ceil(s / ck)
    fn = _xent_chunk if nb == 1 or cfg.remat == "none" \
        else jax.checkpoint(_xent_chunk, static_argnums=(0,))
    total = jnp.zeros((), jnp.float32)
    count = jnp.zeros((), jnp.float32)
    for i in range(nb):
        t, c = fn(cfg, embed_params, hidden[:, i * ck:(i + 1) * ck],
                  targets[:, i * ck:(i + 1) * ck])
        total = total + t
        count = count + c
    return total / jnp.maximum(count, 1.0)


def loss_fn(cfg: ModelConfig):
    """Returns loss(params, batch) -> (scalar, metrics dict)."""
    forward = build_forward(cfg)

    def loss(params, batch):
        hidden, aux = forward(params, batch)
        targets = batch["targets"]
        nll = _chunked_xent(cfg, params["embed"], hidden, targets)
        metrics = {"nll": nll, "aux_loss": aux}
        total = nll
        if cfg.moe is not None:
            total = total + cfg.moe.router_aux_loss * aux
        if cfg.mtp:
            mtp_nll = _mtp_loss(cfg, params, hidden, batch)
            metrics["mtp_nll"] = mtp_nll
            total = total + cfg.mtp_loss_weight * mtp_nll
        metrics["loss"] = total
        return total, metrics

    return loss


def _mtp_loss(cfg: ModelConfig, params, hidden, batch):
    """DeepSeek multi-token prediction: predict t+2 from [h_t; emb(t+1)]."""
    mp = params["mtp"]
    tokens, targets = batch["tokens"], batch["targets"]
    b, s = tokens.shape
    dt = hidden.dtype
    h = layers.apply_norm(cfg, mp["norm"], hidden[:, :-1])
    nxt = layers.embed_tokens(cfg, params["embed"], tokens[:, 1:])
    z = jnp.concatenate([h, nxt.astype(dt)], axis=-1) @ mp["proj"].astype(dt)
    pos = layers.default_positions(b, s - 1)
    z, _, _ = blocks.block_forward(cfg, mp["block"], z, pos, 0)
    # target for position t is token t+2 == targets shifted left by one
    return _chunked_xent(cfg, params["embed"], z, targets[:, 1:])


# -- serve: caches ------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch_size: int, max_len: int,
                dtype=jnp.bfloat16):
    """Decode caches: {"prefix": {l<i>: cache}, "units": stacked cache}."""
    lay = unit_layout(cfg)
    caches: Dict[str, Any] = {}
    if lay.prefix:
        caches["prefix"] = {
            f"l{i}": blocks.init_block_cache(cfg, i, batch_size, max_len,
                                             dtype)
            for i in lay.prefix}
    unit_caches = []
    for u in range(lay.n_units):
        unit_caches.append({
            f"r{r}": blocks.init_block_cache(
                cfg, lay.prefix_len + r, batch_size, max_len, dtype)
            for r in range(lay.unit_len)})
    if lay.n_units > 1:
        caches["units"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                       *unit_caches)
    else:
        caches["units"] = unit_caches[0]
    return caches


def cache_logical_axes(cfg: ModelConfig):
    """Logical-axes tree matching init_caches output."""
    lay = unit_layout(cfg)
    axes: Dict[str, Any] = {}
    if lay.prefix:
        axes["prefix"] = {f"l{i}": blocks.cache_axes(cfg, i)
                          for i in lay.prefix}
    unit_axes = {f"r{r}": blocks.cache_axes(cfg, lay.prefix_len + r)
                 for r in range(lay.unit_len)}
    if lay.n_units > 1:
        unit_axes = jax.tree.map(
            lambda ax: ("layers", *ax), unit_axes,
            is_leaf=lambda x: isinstance(x, tuple))
    axes["units"] = unit_axes
    return axes


# -- serve: paged caches ----------------------------------------------------------------

def supports_paged(cfg: ModelConfig) -> bool:
    """Whether this arch can serve from paged KV pools.

    Paging covers position-indexed attention caches only: every layer
    must be kind "A" (self-attention or MLA).  State archs (mamba /
    xlstm / hybrid) carry O(1) recurrent state — there is nothing to
    page — and encoder-decoder archs need a one-shot whole-encoder
    cross cache plus blocking prefill.  Such archs keep serving from
    the contiguous layout.
    """
    if cfg.is_encoder_decoder or cfg.pos_embed == "sinusoidal":
        return False
    return all(blocks.layer_kind(cfg, i) == "A"
               for i in range(cfg.num_layers))


def init_paged_caches(cfg: ModelConfig, num_pages: int, page_size: int,
                      dtype=jnp.bfloat16):
    """Paged pools, same tree shape as :func:`init_caches` — leaves are
    (P, page_size, ...) physical pools instead of (B, C, ...) per-slot
    caches (stacked units gain the leading "layers" axis as usual).
    Every leaf shares one page-id space; page 0 is scratch."""
    if not supports_paged(cfg):
        raise ValueError(f"{cfg.name}: arch does not support paged KV "
                         "(needs all-attention layers, no encoder)")
    lay = unit_layout(cfg)
    caches: Dict[str, Any] = {}
    if lay.prefix:
        caches["prefix"] = {
            f"l{i}": blocks.init_paged_block_cache(cfg, i, num_pages,
                                                   page_size, dtype)
            for i in lay.prefix}
    unit_caches = []
    for u in range(lay.n_units):
        unit_caches.append({
            f"r{r}": blocks.init_paged_block_cache(
                cfg, lay.prefix_len + r, num_pages, page_size, dtype)
            for r in range(lay.unit_len)})
    if lay.n_units > 1:
        caches["units"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                       *unit_caches)
    else:
        caches["units"] = unit_caches[0]
    return caches


def paged_cache_logical_axes(cfg: ModelConfig):
    """Logical-axes tree matching init_paged_caches output."""
    lay = unit_layout(cfg)
    axes: Dict[str, Any] = {}
    if lay.prefix:
        axes["prefix"] = {f"l{i}": blocks.paged_cache_axes(cfg, i)
                          for i in lay.prefix}
    unit_axes = {f"r{r}": blocks.paged_cache_axes(cfg, lay.prefix_len + r)
                 for r in range(lay.unit_len)}
    if lay.n_units > 1:
        unit_axes = jax.tree.map(
            lambda ax: ("layers", *ax), unit_axes,
            is_leaf=lambda x: isinstance(x, tuple))
    axes["units"] = unit_axes
    return axes


# -- serve: prefill / decode -----------------------------------------------------------

class ServeFns(NamedTuple):
    """The three pjit-able serve steps (see :func:`make_serve_fns`)."""

    prefill: Any
    decode: Any
    prefill_chunk: Any


def make_serve_fns(cfg: ModelConfig, cache_dtype=jnp.bfloat16):
    """Returns ``ServeFns(prefill, decode, prefill_chunk)``.

    ``cache_dtype`` sets the KV/latent cache storage dtype the prefill
    builds (decode and prefill_chunk consume whatever they are given).
    bf16 is the serving default; fp32 buys exact-parity debugging at 2x
    cache bytes.

    prefill(params, batch, max_len) -> (last_logits (B,V), caches)
    decode(params, caches, tokens (B,1), cur_len) -> (logits, caches)
    prefill_chunk(params, caches, tokens (B,T), offset, last_idx)
        -> (logits (B,V) at ``last_idx``, caches)

    ``cur_len`` is a scalar (synchronized decode: every row at the same
    position) or a (B,) int32 vector of per-slot position counters
    (continuous batching: each row advances independently and its KV
    lands at its own cache offset via the cache_update scatter).

    ``prefill_chunk`` resumes prefill from a *partial* cache: the chunk
    tokens sit at absolute positions ``offset + i`` (``offset`` scalar
    or (B,) vector), attend the already-written cache prefix plus their
    own causal keys through ``kernels/prefill_attention``, and scatter
    their KV (or advance the mamba/xlstm scan carry) in place — so
    prefill compiles **once**, at one chunk shape, for any prompt
    length.  ``last_idx`` (traced scalar) marks the last *real* token
    of a right-padded final chunk: logits come from that position, pad
    KV is kept off ring caches, and pad tokens leave state caches
    untouched.  Not available for encoder-decoder archs (the cross-
    attention KV needs one whole-encoder pass — serve admission falls
    back to blocking prefill there).

    ``cfg.decode_attn_impl`` selects the decode attention path for every
    attention/MLA layer in the stack: "flash" = the length-aware
    ``kernels/decode_attention`` sweep that skips cache blocks beyond
    each row's ``cur_len``; "dense" = masked full-cache attend; "auto"
    = flash on TPU (see blocks.decode_attn_impl).
    """
    lay = unit_layout(cfg)

    def prefill(params, batch, max_len: int):
        x = _input_embed(cfg, params, batch)
        pos = _positions(cfg, batch)
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = _run_encoder(cfg, params, batch["frame_embeds"])

        caches: Dict[str, Any] = {}

        def run_layer(xx, bp, idx):
            xx, _, kv = blocks.block_forward(cfg, bp, xx, pos, idx,
                                             enc_out=enc_out, collect_kv=True)
            kind = blocks.layer_kind(cfg, idx)
            if kind in ("m", "s", "M"):
                return xx, kv          # kv already is the decode cache
            x_enc_kv = None
            if kind == "X":
                from repro.models import attention as attn_mod
                _, xk, xv = attn_mod.project_qkv(
                    cfg, bp["cross"], enc_out, None, kv_x=enc_out,
                    rope=False)
                x_enc_kv = (xk, xv)
            return xx, blocks.prefill_block_cache(cfg, idx, kv, max_len,
                                                  x_enc_kv=x_enc_kv,
                                                  dtype=cache_dtype)

        if lay.prefix:
            caches["prefix"] = {}
            for i in lay.prefix:
                x, c = run_layer(x, params["prefix"][f"l{i}"], i)
                caches["prefix"][f"l{i}"] = c

        unit_caches = []
        for u in range(lay.n_units):
            up = params["units"] if lay.n_units == 1 else \
                jax.tree.map(lambda a: a[u], params["units"])
            uc = {}
            for r in range(lay.unit_len):
                x, c = run_layer(x, up[f"r{r}"], lay.prefix_len + r)
                uc[f"r{r}"] = c
            unit_caches.append(uc)
        caches["units"] = unit_caches[0] if lay.n_units == 1 else \
            jax.tree.map(lambda *xs: jnp.stack(xs), *unit_caches)

        x = layers.apply_norm(cfg, params["final_norm"], x)
        logits = layers.logits_from_hidden(cfg, params["embed"], x[:, -1:])
        return logits[:, 0], caches

    def decode_step(params, caches, tokens, cur_len):
        x = layers.embed_tokens(cfg, params["embed"], tokens)
        if cfg.pos_embed == "sinusoidal":
            cur = jnp.asarray(cur_len, jnp.int32)
            row = layers.sinusoidal_row(cur, x.shape[-1], x.dtype)
            x = x + (row[:, None, :] if cur.ndim else row[None, None])
        if lay.prefix:
            for i in lay.prefix:
                x, c = blocks.block_decode(
                    cfg, params["prefix"][f"l{i}"], x,
                    caches["prefix"][f"l{i}"], cur_len, i)
                caches["prefix"][f"l{i}"] = c

        def unit(xx, up_uc):
            up, uc = up_uc
            new_uc = {}
            for r in range(lay.unit_len):
                xx, c = blocks.block_decode(cfg, up[f"r{r}"], xx,
                                            uc[f"r{r}"], cur_len,
                                            lay.prefix_len + r)
                new_uc[f"r{r}"] = c
            return xx, new_uc

        if lay.n_units == 1:
            x, caches["units"] = unit(x, (params["units"], caches["units"]))
        elif cfg.scan_layers:
            def body(xx, up_uc):
                return unit(xx, up_uc)

            x, caches["units"] = jax.lax.scan(
                body, x, (params["units"], caches["units"]))
        else:
            ucs = []
            for u in range(lay.n_units):
                sl = lambda a: a[u]
                x, uc = unit(x, (jax.tree.map(sl, params["units"]),
                                 jax.tree.map(sl, caches["units"])))
                ucs.append(uc)
            caches["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *ucs)

        x = layers.apply_norm(cfg, params["final_norm"], x)
        logits = layers.logits_from_hidden(cfg, params["embed"], x)
        return logits[:, 0], caches

    def prefill_chunk(params, caches, tokens, offset, last_idx):
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "chunked prefill is not available for encoder-decoder "
                "archs; use the whole-prompt prefill")
        last_idx = jnp.asarray(last_idx, jnp.int32)
        valid_len = last_idx + 1
        x = layers.embed_tokens(cfg, params["embed"], tokens)

        def run(xx, bp, c, idx):
            return blocks.block_prefill_chunk(cfg, bp, xx, c, offset,
                                              valid_len, idx)

        if lay.prefix:
            for i in lay.prefix:
                x, c = run(x, params["prefix"][f"l{i}"],
                           caches["prefix"][f"l{i}"], i)
                caches["prefix"][f"l{i}"] = c

        def unit(xx, up_uc):
            up, uc = up_uc
            new_uc = {}
            for r in range(lay.unit_len):
                xx, c = run(xx, up[f"r{r}"], uc[f"r{r}"],
                            lay.prefix_len + r)
                new_uc[f"r{r}"] = c
            return xx, new_uc

        if lay.n_units == 1:
            x, caches["units"] = unit(x, (params["units"], caches["units"]))
        elif cfg.scan_layers:
            x, caches["units"] = jax.lax.scan(
                lambda xx, up_uc: unit(xx, up_uc), x,
                (params["units"], caches["units"]))
        else:
            ucs = []
            for u in range(lay.n_units):
                sl = lambda a: a[u]
                x, uc = unit(x, (jax.tree.map(sl, params["units"]),
                                 jax.tree.map(sl, caches["units"])))
                ucs.append(uc)
            caches["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *ucs)

        x = layers.apply_norm(cfg, params["final_norm"], x)
        x_last = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
        logits = layers.logits_from_hidden(cfg, params["embed"], x_last)
        return logits[:, 0], caches

    return ServeFns(prefill, decode_step, prefill_chunk)


class PagedServeFns(NamedTuple):
    """The two pjit-able paged serve steps (see make_paged_serve_fns)."""

    decode: Any
    prefill_chunk: Any


def make_paged_serve_fns(cfg: ModelConfig):
    """Returns ``PagedServeFns(decode, prefill_chunk)``.

    decode(params, caches, tokens (B,1), cur_len (B,), page_table)
        -> (logits (B,V), caches)
    prefill_chunk(params, caches, tokens (B,T), offset (B,),
                  last_idx (B,), page_table) -> (logits (B,V), caches)

    ``caches`` are :func:`init_paged_caches` pools; ``page_table`` is
    (B, NB) int32 mapping each slot's logical blocks to physical pages
    (rows the scheduler masks to 0 touch only the scratch page).
    Paged serving is always continuous, so ``cur_len``/``offset`` are
    per-row vectors, and — unlike the contiguous ``prefill_chunk`` —
    ``last_idx`` is a (B,) vector too: the batched admission path runs
    several requests' chunks in ONE (B, T) dispatch, each row at its
    own offset with its own fill.  Rows with ``last_idx == -1`` are
    passengers (idle or decoding): their ``valid_len`` clamps to 0, so
    they write nothing, and their logits row is garbage the engine
    discards.
    """
    if not supports_paged(cfg):
        raise ValueError(f"{cfg.name}: arch does not support paged KV "
                         "(needs all-attention layers, no encoder)")
    lay = unit_layout(cfg)

    def _run_stack(params, caches, x, run):
        """Shared prefix + scanned-units sweep for both paged steps."""
        if lay.prefix:
            for i in lay.prefix:
                x, c = run(x, params["prefix"][f"l{i}"],
                           caches["prefix"][f"l{i}"], i)
                caches["prefix"][f"l{i}"] = c

        def unit(xx, up_uc):
            up, uc = up_uc
            new_uc = {}
            for r in range(lay.unit_len):
                xx, c = run(xx, up[f"r{r}"], uc[f"r{r}"],
                            lay.prefix_len + r)
                new_uc[f"r{r}"] = c
            return xx, new_uc

        if lay.n_units == 1:
            x, caches["units"] = unit(x, (params["units"], caches["units"]))
        elif cfg.scan_layers:
            x, caches["units"] = jax.lax.scan(
                lambda xx, up_uc: unit(xx, up_uc), x,
                (params["units"], caches["units"]))
        else:
            ucs = []
            for u in range(lay.n_units):
                sl = lambda a: a[u]
                x, uc = unit(x, (jax.tree.map(sl, params["units"]),
                                 jax.tree.map(sl, caches["units"])))
                ucs.append(uc)
            caches["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *ucs)
        return layers.apply_norm(cfg, params["final_norm"], x), caches

    def decode(params, caches, tokens, cur_len, page_table):
        x = layers.embed_tokens(cfg, params["embed"], tokens)

        def run(xx, bp, c, idx):
            return blocks.block_paged_decode(cfg, bp, xx, c, cur_len,
                                             page_table, idx)

        x, caches = _run_stack(params, caches, x, run)
        logits = layers.logits_from_hidden(cfg, params["embed"], x)
        return logits[:, 0], caches

    def prefill_chunk(params, caches, tokens, offset, last_idx, page_table):
        last_idx = jnp.asarray(last_idx, jnp.int32)
        valid_len = jnp.maximum(last_idx + 1, 0)
        x = layers.embed_tokens(cfg, params["embed"], tokens)

        def run(xx, bp, c, idx):
            return blocks.block_paged_prefill_chunk(
                cfg, bp, xx, c, offset, valid_len, page_table, idx)

        x, caches = _run_stack(params, caches, x, run)
        # per-row last real token (vector last_idx — batched admissions)
        idx = jnp.clip(last_idx, 0, x.shape[1] - 1)
        x_last = jnp.take_along_axis(
            x, idx[:, None, None].astype(jnp.int32), axis=1)   # (B,1,d)
        logits = layers.logits_from_hidden(cfg, params["embed"], x_last)
        return logits[:, 0], caches

    return PagedServeFns(decode, prefill_chunk)
