"""Attention: GQA with the assigned archs' variants, train/prefill/decode.

Covers:
  * grouped-query attention (all archs; MHA is the kv_heads==heads case),
  * qk-norm on per-head q/k (qwen3),
  * attention-logit soft-capping (gemma2),
  * sliding-window masking for local layers (gemma2),
  * RoPE / M-RoPE positions (applied here, built in layers.py),
  * cross-attention (whisper decoder),
  * a KV-cache decode path (one new token against a cache of seq_len).

Implementations (full-sequence ``attention``):
  * ``dense``   — materialises (B, H, Sq, Skv) scores; right for short seqs
                  and the smoke tests.
  * ``chunked`` — lax.scan over query blocks; bounds the live score tensor
                  to (B, H, chunk, Skv). This is the XLA path the dry-run
                  lowers for 32k prefill (flash-style memory behaviour
                  without a custom kernel).
  * ``pallas``  — the flash-attention Pallas kernel (kernels/flash_attention),
                  TPU-targeted, validated in interpret mode.  Self-attention
                  with contiguous-from-zero positions only: it derives the
                  causal/window mask from block indices, so calls carrying a
                  ``kv_valid`` mask or ``causal=False`` (cross-attention)
                  raise instead of silently dropping those constraints.

The choice is per-call (``impl=``); models pick dense for tiny smoke
configs and chunked for production shapes (see model.py).

Decode (``decode_self_attention``) has its own impl pair, selected by
``cfg.decode_attn_impl`` (resolved in blocks.py):
  * ``dense``   — masked attend over the whole (B, C) cache with an
                  explicit slot->position timeline (row-degenerate (1, C)
                  when every row is at the same position).
  * ``flash``   — the flash-decode kernel family
                  (kernels/decode_attention): online-softmax sweep over KV
                  blocks, per-row ``cur_len`` via scalar prefetch so cache
                  blocks beyond a row's valid prefix are never read from
                  HBM; ring-buffer slot arithmetic, GQA head packing, and
                  soft-capping happen in-kernel, so no (B, C)
                  position/validity tensors are built per decode step.
                  Dispatches to Pallas on TPU and a length-aware masked
                  lax sweep elsewhere.  Decode is memory-bound, so the
                  skipped HBM bytes are the J/token lever (see
                  benchmarks/bench_decode.py).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import quant
from repro.kernels.constants import NEG_INF
from repro.models import layers
from repro.sharding.specs import annotate, shard


# -- params -------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False):
    """GQA projection weights.

    q: (d, H, hd)   k,v: (d, KVH, hd)   o: (H, hd, d)
    qk-norm adds per-head-dim scales (qwen3 style, applied on the head dim).
    """
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": annotate(
            layers.dense_init(k1, (d, h, hd)), "d_model", "heads", "head_dim"),
        "wk": annotate(
            layers.dense_init(k2, (d, kvh, hd)), "d_model", "kv_heads",
            "head_dim"),
        "wv": annotate(
            layers.dense_init(k3, (d, kvh, hd)), "d_model", "kv_heads",
            "head_dim"),
        "wo": annotate(
            layers.dense_init(k4, (h, hd, d), in_axis=(0, 1)),
            "heads", "head_dim", "d_model"),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = annotate(jnp.ones((hd,), jnp.float32), "head_dim")
        p["k_norm"] = annotate(jnp.ones((hd,), jnp.float32), "head_dim")
    return p


def _rms_head(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# -- qkv projection -----------------------------------------------------------

def project_qkv(cfg: ModelConfig, p, x, positions,
                kv_x: Optional[jnp.ndarray] = None,
                rope: bool = True):
    """Project hidden states to (q, k, v) with RoPE applied.

    kv_x: source of k/v for cross-attention (defaults to x).
    Returns q (B,Sq,H,hd), k,v (B,Skv,KVH,hd).
    """
    dt = x.dtype
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(dt))
    if cfg.qk_norm and "q_norm" in p:
        q = _rms_head(q, p["q_norm"])
        k = _rms_head(k, p["k_norm"])
    if rope and positions is not None:
        sections = cfg.m_rope_sections if cfg.m_rope else None
        q = layers.apply_rope(q, positions, cfg.rope_theta, sections)
        k = layers.apply_rope(k, positions, cfg.rope_theta, sections)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "kv_seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "kv_seq", "kv_heads", "head_dim")
    return q, k, v


# -- masks -------------------------------------------------------------------

def make_mask(q_pos, kv_pos, causal: bool,
              window: Optional[int] = None,
              kv_valid: Optional[jnp.ndarray] = None):
    """Boolean (B, Sq, Skv) mask; True = attend.

    q_pos: (B, Sq) token positions of the queries.
    kv_pos: (B, Skv) positions of the keys (cache slots for decode).
    window: sliding-window size (attend iff 0 <= q-k < window).
    kv_valid: (B, Skv) validity of cache slots (decode ring buffers).
    """
    diff = q_pos[:, :, None] - kv_pos[:, None, :]     # (B, Sq, Skv)
    mask = jnp.ones(diff.shape, bool)
    if causal:
        mask &= diff >= 0
    if window is not None:
        mask &= diff < window
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    return mask


# -- core attention -----------------------------------------------------------

def _gqa_scores(q, k, softcap):
    """(B,Sq,KVH,G,hd) x (B,Skv,KVH,hd) -> fp32 (B,KVH,G,Sq,Skv)."""
    s = jnp.einsum("bqhgk,bshk->bhgqs", q, k).astype(jnp.float32)
    return layers.softcap(s, softcap)


def _attend_block(cfg: ModelConfig, q, k, v, mask,
                  scale: Optional[float] = None):
    """Dense attention for one (whole or chunked) query block.

    q: (B,Sq,H,hd) k,v: (B,Skv,KVH,hd) mask: (B,Sq,Skv) -> (B,Sq,H,hd)
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    if scale is None:
        scale = 1.0 / math.sqrt(cfg.query_pre_attn_scalar or cfg.head_dim)
    qg = q.reshape(b, sq, kvh, g, hd) * scale
    s = _gqa_scores(qg, k, cfg.attn_softcap)               # (B,KVH,G,Sq,Skv)
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqs,bshk->bqhgk", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, v.shape[-1])   # v head dim (MLA: != qk dim)


def attention(cfg: ModelConfig, q, k, v, *,
              q_pos, kv_pos, causal: bool = True,
              window: Optional[int] = None,
              kv_valid: Optional[jnp.ndarray] = None,
              impl: str = "dense", chunk: int = 1024,
              scale: Optional[float] = None, unroll: bool = False,
              causal_kv_trim: bool = False):
    """Multi-head attention over explicit q/k/v.

    impl="dense"   full score tensor.
    impl="chunked" query chunks of size ``chunk``: the live score tensor
                   is (B, H, chunk, Skv) and the chunk body is
                   jax.checkpoint'ed so backward recomputes scores instead
                   of saving a per-chunk stack (flash-style memory).
    impl="pallas"  flash-attention kernel (full-causal self-attn only).

    unroll=True replaces the chunk lax.scan with a Python loop (roofline
    probes — scan bodies are cost-counted once by XLA).
    causal_kv_trim=True (unrolled causal self-attention only) slices K/V
    per query chunk to the causally-visible prefix, skipping the fully
    masked upper-triangle blocks (~2x score FLOPs at long S).
    """
    if impl == "pallas":
        # The flash kernel reconstructs the mask from block indices; it
        # cannot honor a kv_valid mask (decode ring buffers, padded
        # cross-attention memories) or non-causal attention.  Refuse
        # loudly instead of returning wrong numbers with those args
        # silently dropped.
        if kv_valid is not None:
            raise ValueError(
                "attention(impl='pallas') cannot honor kv_valid masks — "
                "use impl='dense'/'chunked', or the flash-decode kernel "
                "(kernels/decode_attention) for single-token decode")
        if not causal:
            raise ValueError(
                "attention(impl='pallas') is causal self-attention only; "
                "cross-attention must use impl='dense' or 'chunked'")
        from repro.kernels.flash_attention import ops as fa_ops
        if scale is None:
            scale = 1.0 / math.sqrt(cfg.query_pre_attn_scalar
                                    or cfg.head_dim)
        return fa_ops.flash_attention(
            q, k, v, causal=causal, window=window,
            softcap=cfg.attn_softcap, scale=scale)
    if impl == "dense" or q.shape[1] <= chunk:
        mask = make_mask(q_pos, kv_pos, causal, window, kv_valid)
        return _attend_block(cfg, q, k, v, mask, scale)
    if impl != "chunked":
        raise ValueError(f"unknown attention impl {impl!r}")

    b, sq, h, hd = q.shape
    n_chunks, rem = divmod(sq, chunk)
    if rem:
        raise ValueError(f"seq {sq} not divisible by chunk {chunk}")

    def chunk_body(qc, qpc, kc, vc, kv_pos_c, kv_valid_c):
        mask = make_mask(qpc, kv_pos_c, causal, window, kv_valid_c)
        return _attend_block(cfg, qc, kc, vc, mask, scale)

    chunk_body = jax.checkpoint(chunk_body)

    if unroll:
        outs = []
        for i in range(n_chunks):
            sl = slice(i * chunk, (i + 1) * chunk)
            if causal_kv_trim and causal and kv_valid is None:
                kv_hi = (i + 1) * chunk
                outs.append(chunk_body(
                    q[:, sl], q_pos[:, sl], k[:, :kv_hi], v[:, :kv_hi],
                    kv_pos[:, :kv_hi], None))
            else:
                outs.append(chunk_body(q[:, sl], q_pos[:, sl], k, v,
                                       kv_pos, kv_valid))
        out = jnp.concatenate(outs, axis=1)
        return shard(out, "batch", "seq", "heads", "head_dim")

    qs = q.reshape(b, n_chunks, chunk, h, hd).swapaxes(0, 1)
    qp = q_pos.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def step(_, qc_qpc):
        qc, qpc = qc_qpc
        return None, chunk_body(qc, qpc, k, v, kv_pos, kv_valid)

    _, out = jax.lax.scan(step, None, (qs, qp))
    out = out.swapaxes(0, 1).reshape(b, sq, h, v.shape[-1])
    return shard(out, "batch", "seq", "heads", "head_dim")


def output_proj(p, o):
    dt = o.dtype
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return shard(out, "batch", "seq", "d_model")


# -- full self-attention block (no cache) ---------------------------------------

def self_attention(cfg: ModelConfig, p, x, positions, *,
                   causal: bool = True, window: Optional[int] = None,
                   impl: str = "dense", chunk: int = 1024):
    q, k, v = project_qkv(cfg, p, x, positions)
    pos1d = positions[..., 0] if positions.ndim == 3 else positions
    o = attention(cfg, q, k, v, q_pos=pos1d, kv_pos=pos1d, causal=causal,
                  window=window, impl=impl, chunk=chunk)
    return output_proj(p, o)


def cross_attention(cfg: ModelConfig, p, x, enc_out,
                    enc_valid: Optional[jnp.ndarray] = None,
                    impl: str = "dense", chunk: int = 1024):
    """Whisper-style cross attention (no RoPE, no causality)."""
    b, sq = x.shape[:2]
    skv = enc_out.shape[1]
    q, k, v = project_qkv(cfg, p, x, None, kv_x=enc_out, rope=False)
    q_pos = jnp.zeros((b, sq), jnp.int32)
    kv_pos = jnp.zeros((b, skv), jnp.int32)
    o = attention(cfg, q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=False,
                  kv_valid=enc_valid, impl=impl, chunk=chunk)
    return output_proj(p, o)


# -- KV cache -------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: Optional[int] = None,
                  dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Cache for one attention layer.

    Full layers: (B, max_len, KVH, hd) k/v. Sliding-window layers use a
    ring buffer of size ``window`` instead (gemma2 local layers) — decode
    memory stays O(window).

    ``cfg.kv_quant`` switches the layout to quantized codes (int8 /
    fp8_e4m3 — see ``kernels/quant``) plus per-(token, kv-head) float32
    absmax scales in ``k_scale``/``v_scale`` (B, size, KVH) leaves;
    ``dtype`` then only names the full-precision layout other engines
    would have used (the code dtype is fixed by the mode).
    """
    size = min(max_len, window) if window else max_len
    shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
    # k and v must be DISTINCT buffers: the serve engine donates cache
    # trees into jitted steps (chunked prefill, row insert), and a
    # buffer shared by two donated leaves gets handed out twice —
    # silent corruption once both outputs land in it.
    if cfg.kv_quant is not None:
        qdt = quant.quant_dtype(cfg.kv_quant)
        return {"k": jnp.zeros(shape, qdt), "v": jnp.zeros(shape, qdt),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_spec_axes() -> Tuple[Optional[str], ...]:
    return ("batch", "kv_seq", "kv_heads", "head_dim")


def scale_spec_axes() -> Tuple[Optional[str], ...]:
    """Logical axes of the quantized layouts' scale leaves."""
    return ("batch", "kv_seq", "kv_heads")


def decode_self_attention(cfg: ModelConfig, p, x, cache, cur_len, *,
                          window: Optional[int] = None,
                          cache_impl: str = "auto",
                          impl: str = "dense"):
    """One-token decode against a cache.

    x: (B, 1, d). cache: {"k","v"} (B, C, KVH, hd). cur_len: count of
    tokens already in the cache (== position of the new token) — either
    a scalar (synchronized decode, every row at the same position) or a
    (B,) vector (continuous batching, per-slot position counters; the
    new k/v land at a *different* cache offset per row via the
    ``kernels/cache_update`` scatter).

    impl: "dense" attends over the whole cache with an explicit masked
    timeline; "flash" routes through ``kernels/decode_attention`` —
    slot->position arithmetic moves in-kernel, no (B, C) position or
    validity tensors are built, the cache is consumed in its own dtype
    (no cache-wide upcast copy), and KV blocks beyond each row's valid
    prefix are never read.
    Returns (out (B,1,d), new_cache).
    """
    b = x.shape[0]
    cur = jnp.asarray(cur_len, jnp.int32)
    per_row = cur.ndim == 1
    positions = cur[:, None] if per_row else jnp.full((b, 1), cur, jnp.int32)
    if cfg.m_rope:
        positions = jnp.broadcast_to(positions[..., None], (b, 1, 3))
    q, k_new, v_new = project_qkv(cfg, p, x, positions, rope=cfg.use_rope)

    mode = cfg.kv_quant
    ks = vs = None
    cache_size = cache["k"].shape[1]
    if per_row:
        from repro.kernels.cache_update import ops as cu_ops
        slot_rows = (cur % cache_size) if window \
            else jnp.minimum(cur, cache_size - 1)
        if mode is not None:
            k, ks = cu_ops.quant_cache_update(
                cache["k"], cache["k_scale"], k_new, slot_rows, mode,
                impl=cache_impl)
            v, vs = cu_ops.quant_cache_update(
                cache["v"], cache["v_scale"], v_new, slot_rows, mode,
                impl=cache_impl)
        else:
            k = cu_ops.cache_update(cache["k"], k_new, slot_rows,
                                    impl=cache_impl)
            v = cu_ops.cache_update(cache["v"], v_new, slot_rows,
                                    impl=cache_impl)
    else:
        slot = (cur_len % cache_size) if window else cur_len
        if mode is not None:
            k_codes, k_sc = quant.quantize(k_new, mode)
            v_codes, v_sc = quant.quantize(v_new, mode)
            k = jax.lax.dynamic_update_slice(cache["k"], k_codes,
                                             (0, slot, 0, 0))
            v = jax.lax.dynamic_update_slice(cache["v"], v_codes,
                                             (0, slot, 0, 0))
            ks = jax.lax.dynamic_update_slice(cache["k_scale"], k_sc,
                                              (0, slot, 0))
            vs = jax.lax.dynamic_update_slice(cache["v_scale"], v_sc,
                                              (0, slot, 0))
        else:
            k = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    k = shard(k, *cache_spec_axes())
    v = shard(v, *cache_spec_axes())
    new_cache = {"k": k, "v": v}
    if mode is not None:
        ks = shard(ks, *scale_spec_axes())
        vs = shard(vs, *scale_spec_axes())
        new_cache["k_scale"], new_cache["v_scale"] = ks, vs

    if impl == "flash":
        from repro.kernels.decode_attention import ops as da_ops
        scale = 1.0 / math.sqrt(cfg.query_pre_attn_scalar or cfg.head_dim)
        o = da_ops.decode_attention(
            q, k, v, cur, ring=window is not None,
            softcap=cfg.attn_softcap, scale=scale, k_scale=ks, v_scale=vs)
        return output_proj(p, o), new_cache
    if impl != "dense":
        raise ValueError(f"unknown decode attention impl {impl!r}")

    # Per-slot timeline against the new token's position.  The row
    # dimension is degenerate — (1, C) — when cur_len is a scalar
    # (every row at the same position): the boolean mask broadcasts
    # inside attention, so the scalar path never materialises B copies
    # of the same timeline.
    slots = jnp.arange(cache_size, dtype=jnp.int32)[None]        # (1,C)
    cur_col = cur[:, None] if per_row else cur[None, None]   # (B,1)/(1,1)
    if window:
        # ring buffer: slot s holds the largest position p <= cur with
        # p % size == s, i.e. p = cur - ((cur - s) mod size); negative p
        # means the slot has never been written.
        kv_pos = cur_col - jnp.mod(cur_col - slots, cache_size)
        kv_valid = kv_pos >= 0
        kv_pos = jnp.maximum(kv_pos, 0)
    else:
        kv_pos = slots
        kv_valid = slots <= cur_col

    if mode is not None:
        k_att = quant.dequantize(k, ks).astype(q.dtype)
        v_att = quant.dequantize(v, vs).astype(q.dtype)
    else:
        k_att, v_att = k.astype(q.dtype), v.astype(q.dtype)
    o = attention(cfg, q, k_att, v_att,
                  q_pos=cur_col, kv_pos=kv_pos, causal=True, window=window,
                  kv_valid=kv_valid, impl="dense")
    return output_proj(p, o), new_cache


# -- paged KV cache (block pools + page-table indirection) --------------------

def init_paged_kv_pools(cfg: ModelConfig, num_pages: int, page_size: int,
                        dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Physical page pools for one attention layer.

    (P, page_size, KVH, hd) k/v — every layer's pool shares ONE page-id
    space: a request's single (NB,) page-table row addresses the same
    physical page index in every leaf, so the host allocator hands out
    one page id per ``page_size`` token positions across the whole
    stack.  Page 0 is the scratch page (all masked/pad writes land
    there; its content is undefined).  Sliding-window layers store
    their positions *unwrapped* (slot == position) with the window as
    an explicit attention mask — no ring arithmetic, so prefix pages
    are position-stable and shareable across requests.

    ``cfg.kv_quant`` pages the scale leaves exactly like their code
    leaves — (P, page_size, KVH) float32 through the same page tables —
    so a page's scales travel with it through prefix sharing, adoption,
    and eviction.
    """
    shape = (num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
    # distinct buffers — donated cache trees must not share (see
    # init_kv_cache)
    if cfg.kv_quant is not None:
        qdt = quant.quant_dtype(cfg.kv_quant)
        return {"k": jnp.zeros(shape, qdt), "v": jnp.zeros(shape, qdt),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def paged_decode_self_attention(cfg: ModelConfig, p, x, cache, cur_len,
                                page_table, *,
                                window: Optional[int] = None,
                                cache_impl: str = "auto"):
    """One-token decode against a *paged* cache.

    x: (B, 1, d).  cache: {"k","v"} (P, page_size, KVH, hd) pools.
    cur_len: (B,) per-row position counters (paged serving is always
    continuous).  page_table: (B, NB) int32 — rows the scheduler has
    masked to 0 (mid-prefill / dead slots) read and write only the
    scratch page, so their garbage decode tokens cannot touch a live
    request's pages.  Returns (out (B,1,d), new_cache).
    """
    from repro.kernels.cache_update import ops as cu_ops
    from repro.kernels.decode_attention import ops as da_ops
    b = x.shape[0]
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    positions = cur[:, None]
    if cfg.m_rope:
        positions = jnp.broadcast_to(positions[..., None], (b, 1, 3))
    q, k_new, v_new = project_qkv(cfg, p, x, positions, rope=cfg.use_rope)

    mode = cfg.kv_quant
    ks = vs = None
    ones = jnp.ones((b,), jnp.int32)
    if mode is not None:
        k, ks = cu_ops.quant_paged_cache_update(
            cache["k"], cache["k_scale"], k_new, page_table, cur, ones,
            mode, impl=cache_impl)
        v, vs = cu_ops.quant_paged_cache_update(
            cache["v"], cache["v_scale"], v_new, page_table, cur, ones,
            mode, impl=cache_impl)
    else:
        k = cu_ops.paged_cache_update(cache["k"], k_new, page_table, cur,
                                      ones, impl=cache_impl)
        v = cu_ops.paged_cache_update(cache["v"], v_new, page_table, cur,
                                      ones, impl=cache_impl)
    scale = 1.0 / math.sqrt(cfg.query_pre_attn_scalar or cfg.head_dim)
    o = da_ops.decode_attention_paged(
        q, k, v, page_table, cur, window=window,
        softcap=cfg.attn_softcap, scale=scale, k_scale=ks, v_scale=vs)
    new_cache = {"k": k, "v": v}
    if mode is not None:
        new_cache["k_scale"], new_cache["v_scale"] = ks, vs
    return output_proj(p, o), new_cache


def paged_prefill_chunk_self_attention(cfg: ModelConfig, p, x, cache,
                                       offset, valid_len, page_table, *,
                                       window: Optional[int] = None,
                                       cache_impl: str = "auto"):
    """One chunk of chunked prefill through one attention layer, paged.

    x: (B, T, d) at absolute positions ``offset[b] + i``; cache pools
    hold positions ``< offset[b]`` of every row through page_table
    (B, NB).  ``offset`` and ``valid_len`` are (B,) int32 — rows with
    ``valid_len == 0`` (slots decoding, or idle, during this batched
    admission dispatch) contribute garbage outputs the caller discards
    and write nothing (their scatter is fully masked to the scratch
    page).  Returns (out (B, T, d), new_cache).
    """
    from repro.kernels.cache_update import ops as cu_ops
    from repro.kernels.prefill_attention import ops as pf_ops
    b, t = x.shape[:2]
    off = jnp.broadcast_to(jnp.asarray(offset, jnp.int32), (b,))
    positions = off[:, None] + jnp.arange(t, dtype=jnp.int32)[None]
    if cfg.m_rope:
        positions = jnp.broadcast_to(positions[..., None], (b, t, 3))
    q, k_new, v_new = project_qkv(cfg, p, x, positions, rope=cfg.use_rope)

    mode = cfg.kv_quant
    scale = 1.0 / math.sqrt(cfg.query_pre_attn_scalar or cfg.head_dim)
    o = pf_ops.prefill_attention_paged(
        q, k_new, v_new, cache["k"], cache["v"], page_table, off,
        window=window, softcap=cfg.attn_softcap, scale=scale,
        k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"))
    valids = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))
    if mode is not None:
        k, ks = cu_ops.quant_paged_cache_update(
            cache["k"], cache["k_scale"], k_new, page_table, off, valids,
            mode, impl=cache_impl)
        v, vs = cu_ops.quant_paged_cache_update(
            cache["v"], cache["v_scale"], v_new, page_table, off, valids,
            mode, impl=cache_impl)
        return output_proj(p, o), {"k": k, "v": v,
                                   "k_scale": ks, "v_scale": vs}
    k = cu_ops.paged_cache_update(cache["k"], k_new, page_table, off,
                                  valids, impl=cache_impl)
    v = cu_ops.paged_cache_update(cache["v"], v_new, page_table, off,
                                  valids, impl=cache_impl)
    return output_proj(p, o), {"k": k, "v": v}


def chunk_kv_write(cache, new, offset, valid_len, *,
                   ring: bool = False):
    """Write a prefill chunk's KV into a cache: ``new[:, t]`` lands at
    position ``offset + t`` (slot ``(offset + t) % C`` when ``ring``)
    for every ``t < valid_len``.

    cache: (B, C, *rest).  new: (B, T, *rest).  offset: scalar or (B,)
    int32 (the chunk's first absolute position).  valid_len: traced
    scalar — tokens beyond it are the right-padding of a final partial
    chunk.

    The scalar-offset full cache takes the fast path: pads land at
    slots past the prompt, which stay invalid under every decode
    path's ``cur_len`` masking until a real decode token overwrites
    them, so the whole chunk lands in one ``dynamic_update_slice``.
    Everything else (ring caches — where a pad write would wrap onto a
    *valid* older position inside the window — and per-row offsets)
    goes through one vectorized gather+select over the C cache slots:
    per slot, the index of the last valid chunk token that maps there
    falls out of the ring arithmetic in closed form, so there is no
    per-token write loop to trace (chunk-sized HLO) or serialize at
    runtime, and a chunk longer than the ring degrades gracefully to
    its surviving tail.
    """
    b, t = new.shape[:2]
    c = cache.shape[1]
    offset = jnp.asarray(offset, jnp.int32)
    per_row = offset.ndim == 1
    new = new.astype(cache.dtype)
    if not ring and not per_row:
        starts = (0, offset) + (0,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(cache, new, starts)
    valid_len = jnp.asarray(valid_len, jnp.int32)
    slots = jnp.arange(c, dtype=jnp.int32)[None]           # (1, C)
    off = offset[:, None] if per_row else offset[None, None]
    if ring:
        # slot s's final occupant is the LAST valid chunk token at a
        # position == s (mod C): position p = last_valid - ((last_valid
        # - s) mod C), chunk index i = p - offset; i < 0 means no valid
        # token wrapped onto s — keep the old row.
        last_valid = off + valid_len - 1
        i = (valid_len - 1) - jnp.mod(last_valid - slots, c)
        keep_new = i >= 0
    else:
        i = slots - off
        keep_new = (i >= 0) & (i < valid_len)
    i = jnp.broadcast_to(jnp.clip(i, 0, t - 1), (b, c))
    expand = (...,) + (None,) * (cache.ndim - 2)
    gathered = jnp.take_along_axis(new, i[expand], axis=1)
    return jnp.where(jnp.broadcast_to(keep_new, (b, c))[expand],
                     gathered, cache)


def prefill_chunk_self_attention(cfg: ModelConfig, p, x, cache, offset,
                                 valid_len, *,
                                 window: Optional[int] = None):
    """One chunk of chunked prefill through one attention layer.

    x: (B, T, d) — the chunk's hidden states at absolute positions
    ``offset + i``.  cache: {"k","v"} (B, C, KVH, hd) holding positions
    ``< offset`` (the previous chunks).  offset: scalar or (B,) int32;
    valid_len: traced scalar — tokens ``>= valid_len`` are the final
    partial chunk's right-padding (their outputs are garbage the caller
    discards; their KV is masked out of ring caches and lands on
    never-valid slots of full ones).

    Attention runs through ``kernels/prefill_attention``: one online
    softmax over [cache prefix ++ causal in-chunk keys], with cache
    blocks beyond ``offset`` never read (Pallas on TPU, fused masked
    lax elsewhere — ``PMT_PREFILL_ATTENTION_DISPATCH`` overrides).
    Returns (out (B, T, d), new_cache).
    """
    from repro.kernels.prefill_attention import ops as pf_ops
    b, t = x.shape[:2]
    off = jnp.asarray(offset, jnp.int32)
    positions = (off[:, None] if off.ndim else off) \
        + jnp.arange(t, dtype=jnp.int32)[None]             # (B|1, T)
    positions = jnp.broadcast_to(positions, (b, t))
    if cfg.m_rope:
        positions = jnp.broadcast_to(positions[..., None], (b, t, 3))
    q, k_new, v_new = project_qkv(cfg, p, x, positions, rope=cfg.use_rope)

    mode = cfg.kv_quant
    ring = window is not None
    scale = 1.0 / math.sqrt(cfg.query_pre_attn_scalar or cfg.head_dim)
    o = pf_ops.prefill_attention(
        q, k_new, v_new, cache["k"], cache["v"], off,
        ring=ring, window=window, softcap=cfg.attn_softcap, scale=scale,
        k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"))
    if mode is not None:
        # quantize the whole chunk once; codes and scales then ride the
        # same masked ring write (scales are just (B, T, KVH) "rows")
        k_new, k_sc = quant.quantize(k_new, mode)
        v_new, v_sc = quant.quantize(v_new, mode)
    k = chunk_kv_write(cache["k"], k_new, off, valid_len, ring=ring)
    v = chunk_kv_write(cache["v"], v_new, off, valid_len, ring=ring)
    k = shard(k, *cache_spec_axes())
    v = shard(v, *cache_spec_axes())
    new_cache = {"k": k, "v": v}
    if mode is not None:
        ks = chunk_kv_write(cache["k_scale"], k_sc, off, valid_len,
                            ring=ring)
        vs = chunk_kv_write(cache["v_scale"], v_sc, off, valid_len,
                            ring=ring)
        new_cache["k_scale"] = shard(ks, *scale_spec_axes())
        new_cache["v_scale"] = shard(vs, *scale_spec_axes())
    return output_proj(p, o), new_cache


def prefill_kv_cache(cfg: ModelConfig, k, v, max_len: int,
                     window: Optional[int] = None, dtype=jnp.bfloat16):
    """Build a cache from prefill-computed k/v (B, S, KVH, hd).

    ``cfg.kv_quant`` quantizes the whole prefill K/V once and applies
    the identical tail/roll/slice logic to codes and scales — per-row
    quantization commutes with any position-axis shuffle."""
    b, s = k.shape[:2]
    cache = init_kv_cache(cfg, b, max_len, window=window, dtype=dtype)
    size = cache["k"].shape[1]
    if cfg.kv_quant is not None:
        kc, ksc = quant.quantize(k, cfg.kv_quant)
        vc, vsc = quant.quantize(v, cfg.kv_quant)
        leaves = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
    else:
        leaves = {"k": k.astype(dtype), "v": v.astype(dtype)}
    if window and s > size:
        # keep the last `size` positions, ring-aligned so that position p
        # lives at slot p % size.
        start = s - size
        shift = start % size
        return {name: jnp.roll(x[:, start:], shift, axis=1)
                for name, x in leaves.items()}
    return {name: jax.lax.dynamic_update_slice(
                cache[name], x, (0,) * cache[name].ndim)
            for name, x in leaves.items()}
