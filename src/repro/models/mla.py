"""Multi-head Latent Attention (DeepSeek-V3).

Train/prefill path materializes per-head k/v from the compressed latent;
the decode path uses the *absorbed-weights* formulation so the KV cache
holds only (kv_lora_rank + qk_rope_head_dim) floats per token:

  q_lat  = q_nope @ W_UK            (query moved into latent space)
  score  = q_lat . c_kv + q_rope . k_rope
  ctx    = softmax(score) @ c_kv    (context in latent space)
  out    = (ctx @ W_UV) @ W_O

This is DeepSeek's decode trick: the cache is 576 floats/token instead of
H * (192 + 128) = 40960, which is what makes 32k/128-batch decode feasible.

Cache layout: one (B, C, kv_lora_rank + qk_rope_head_dim) tensor holding
``[latent | rope key]`` concatenated per token.  The concatenated row is
exactly the decode key (``[q_lat | q_rope] . [c_kv | k_rope]`` is the
score), its ``kv_lora_rank`` prefix is exactly the decode value, and one
``cache_update`` scatter per step replaces the two the split layout
needed.  The flash decode path (``impl="flash"``) feeds the kernel the
cache as both K and V with ``v_width=kv_lora_rank`` — zero reshuffling,
and KV blocks beyond each row's prefix are never read.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import quant
from repro.kernels.constants import NEG_INF
from repro.models import layers
from repro.models.attention import attention
from repro.sharding.specs import annotate, shard


def _rms(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# -- params -------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    di = layers.dense_init
    return {
        "wq_a": annotate(di(ks[0], (d, m.q_lora_rank)), "d_model", "q_rank"),
        "q_norm": annotate(jnp.ones((m.q_lora_rank,), jnp.float32), "q_rank"),
        "wq_b": annotate(di(ks[1], (m.q_lora_rank, h, qk_hd)),
                         "q_rank", "heads", "head_dim"),
        # kv down-projection also produces the shared rope key
        "wkv_a": annotate(di(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim)),
                          "d_model", "kv_rank"),
        "kv_norm": annotate(jnp.ones((m.kv_lora_rank,), jnp.float32),
                            "kv_rank"),
        "wk_b": annotate(di(ks[3], (m.kv_lora_rank, h, m.qk_nope_head_dim)),
                         "kv_rank", "heads", "head_dim"),
        "wv_b": annotate(di(ks[4], (m.kv_lora_rank, h, m.v_head_dim)),
                         "kv_rank", "heads", "head_dim"),
        "wo": annotate(di(ks[5], (h, m.v_head_dim, d), in_axis=(0, 1)),
                       "heads", "head_dim", "d_model"),
    }


def _project_q(cfg: ModelConfig, p, x, positions):
    """(B,S,d) -> q_nope (B,S,H,nope), q_rope (B,S,H,rope) (rope applied)."""
    m = cfg.mla
    dt = x.dtype
    ql = _rms(x @ p["wq_a"].astype(dt), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", ql, p["wq_b"].astype(dt))
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = q[..., m.qk_nope_head_dim:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(cfg: ModelConfig, p, x, positions):
    """(B,S,d) -> normed latent (B,S,r), roped shared key (B,S,rope)."""
    m = cfg.mla
    dt = x.dtype
    kv = x @ p["wkv_a"].astype(dt)
    latent = _rms(kv[..., :m.kv_lora_rank], p["kv_norm"])
    k_rope = kv[..., m.kv_lora_rank:]
    k_rope = layers.apply_rope(k_rope[:, :, None, :], positions,
                               cfg.rope_theta)[:, :, 0, :]
    return latent, k_rope


# -- train / prefill -----------------------------------------------------------

def mla_self_attention(cfg: ModelConfig, p, x, positions, *,
                       impl: str = "dense", chunk: int = 1024):
    """Full-sequence causal MLA. Returns (out, (latent, k_rope)) so the
    serve path can build the latent cache from prefill."""
    m = cfg.mla
    dt = x.dtype
    b, s, _ = x.shape
    q_nope, q_rope = _project_q(cfg, p, x, positions)
    latent, k_rope = _project_kv_latent(cfg, p, x, positions)

    k_nope = jnp.einsum("bsr,rhk->bshk", latent, p["wk_b"].astype(dt))
    v = jnp.einsum("bsr,rhk->bshk", latent, p["wv_b"].astype(dt))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_nope.shape[:3], m.qk_rope_head_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "kv_seq", "heads", "head_dim")
    v = shard(v, "batch", "kv_seq", "heads", "head_dim")

    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    o = attention(cfg, q, k, v, q_pos=positions, kv_pos=positions,
                  causal=True, impl=impl, chunk=chunk,
                  scale=1.0 / math.sqrt(qk_hd),
                  unroll=cfg.unroll_time_chunks,
                  causal_kv_trim=cfg.causal_kv_trim)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return shard(out, "batch", "seq", "d_model"), (latent, k_rope)


# -- decode (absorbed weights, latent cache) -------------------------------------

def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """``cfg.kv_quant`` quantizes the concatenated ``[latent | rope]``
    row ONCE — it is both the decode key and (prefix-sliced) value, so
    one (B, C) float32 ``kv_scale`` leaf serves as ``k_scale`` and
    ``v_scale`` alike (per-row scaling commutes with the ``v_width``
    prefix slice)."""
    m = cfg.mla
    width = m.kv_lora_rank + m.qk_rope_head_dim
    if cfg.kv_quant is not None:
        qdt = quant.quant_dtype(cfg.kv_quant)
        return {"kv": jnp.zeros((batch, max_len, width), qdt),
                "kv_scale": jnp.zeros((batch, max_len), jnp.float32)}
    return {"kv": jnp.zeros((batch, max_len, width), dtype)}


def mla_cache_axes(cfg: ModelConfig = None) -> Dict[str, Tuple]:
    ax = {"kv": ("batch", "kv_seq", "kv_rank")}
    if cfg is not None and cfg.kv_quant is not None:
        ax["kv_scale"] = ("batch", "kv_seq")
    return ax


def prefill_mla_cache(cfg: ModelConfig, latent, k_rope, max_len: int,
                      dtype=jnp.bfloat16):
    cache = init_mla_cache(cfg, latent.shape[0], max_len, dtype)
    kv = jnp.concatenate([latent, k_rope], axis=-1)
    if cfg.kv_quant is not None:
        kv, sc = quant.quantize(kv, cfg.kv_quant)
        cache["kv_scale"] = jax.lax.dynamic_update_slice(
            cache["kv_scale"], sc, (0, 0))
    else:
        kv = kv.astype(dtype)
    cache["kv"] = jax.lax.dynamic_update_slice(cache["kv"], kv, (0, 0, 0))
    return cache


def mla_prefill_chunk(cfg: ModelConfig, p, x, cache, offset, valid_len):
    """One chunk of chunked prefill through one MLA layer (absorbed).

    x: (B, T, d) at absolute positions ``offset + i``; cache: the
    latent ``{"kv"}`` tensor holding positions ``< offset``.  The
    absorbed-weights trick extends from decode verbatim: the query
    moves into latent space (``q_lat = q_nope @ W_UK``), the
    concatenated ``[latent | rope]`` row *is* the key — both for the
    cache prefix and for the chunk's own (not yet written) rows — and
    its latent prefix is the value (``v_width``), so the chunk attends
    through ``kernels/prefill_attention`` with zero reshuffling and no
    per-head K/V materialisation.  Tokens ``>= valid_len`` (final
    partial chunk's right-padding) land on never-valid slots.
    Returns (out (B, T, d), new_cache).
    """
    from repro.kernels.prefill_attention import ops as pf_ops
    from repro.models.attention import chunk_kv_write
    m = cfg.mla
    dt = x.dtype
    b, t = x.shape[:2]
    off = jnp.asarray(offset, jnp.int32)
    positions = (off[:, None] if off.ndim else off) \
        + jnp.arange(t, dtype=jnp.int32)[None]
    positions = jnp.broadcast_to(positions, (b, t))

    q_nope, q_rope = _project_q(cfg, p, x, positions)          # (B,T,H,*)
    latent_new, k_rope_new = _project_kv_latent(cfg, p, x, positions)
    kv_new = jnp.concatenate([latent_new, k_rope_new], axis=-1)  # (B,T,r+rr)

    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["wk_b"].astype(dt))
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)          # (B,T,H,r+rr)
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    mode = cfg.kv_quant
    kv_sc = cache["kv_scale"][:, :, None] if mode is not None else None
    kvx = kv_new[:, :, None, :]                                # (B,T,1,r+rr)
    kvc = cache["kv"][:, :, None, :]                           # (B,C,1,r+rr)
    ctx = pf_ops.prefill_attention(
        q_eff, kvx, kvx, kvc, kvc, off, scale=1.0 / math.sqrt(qk_hd),
        v_width=m.kv_lora_rank, k_scale=kv_sc).astype(dt)      # (B,T,H,r)

    if mode is not None:
        kv_new, sc_new = quant.quantize(kv_new, mode)
        sc = chunk_kv_write(cache["kv_scale"], sc_new, off, valid_len)
        sc = shard(sc, "batch", "kv_seq")
    kv = chunk_kv_write(cache["kv"], kv_new, off, valid_len)
    kv = shard(kv, "batch", "kv_seq", "kv_rank")
    o = jnp.einsum("bqhr,rhk->bqhk", ctx, p["wv_b"].astype(dt))
    out = jnp.einsum("bqhk,hkd->bqd", o, p["wo"].astype(dt))
    out = shard(out, "batch", "seq", "d_model")
    return out, ({"kv": kv, "kv_scale": sc} if mode is not None
                 else {"kv": kv})


# -- paged (block pools + page-table indirection) ------------------------------

def init_paged_mla_pool(cfg: ModelConfig, num_pages: int, page_size: int,
                        dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Physical page pool for one MLA layer: (P, page_size, r + rope)
    ``[latent | rope key]`` rows.  Same one-page-id-per-position space
    as ``attention.init_paged_kv_pools`` (page 0 = scratch)."""
    m = cfg.mla
    width = m.kv_lora_rank + m.qk_rope_head_dim
    if cfg.kv_quant is not None:
        qdt = quant.quant_dtype(cfg.kv_quant)
        return {"kv": jnp.zeros((num_pages, page_size, width), qdt),
                "kv_scale": jnp.zeros((num_pages, page_size), jnp.float32)}
    return {"kv": jnp.zeros((num_pages, page_size, width), dtype)}


def mla_paged_decode_attention(cfg: ModelConfig, p, x, cache, cur_len,
                               page_table, cache_impl: str = "auto"):
    """One-token absorbed-MLA decode against a *paged* latent cache.

    x: (B, 1, d); cache: {"kv"} (P, page_size, r + rope) pool;
    cur_len: (B,); page_table: (B, NB) int32 (masked rows touch only
    the scratch page).  The absorbed trick carries over unchanged: the
    pool row is both key and (``v_width``-truncated) value, viewed as
    (P, page_size, 1, r + rope) for the kernels' KVH axis.
    """
    from repro.kernels.cache_update import ops as cu_ops
    from repro.kernels.decode_attention import ops as da_ops
    m = cfg.mla
    dt = x.dtype
    b = x.shape[0]
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    positions = cur[:, None]

    q_nope, q_rope = _project_q(cfg, p, x, positions)          # (B,1,H,*)
    latent_new, k_rope_new = _project_kv_latent(cfg, p, x, positions)
    kv_new = jnp.concatenate([latent_new, k_rope_new], axis=-1)  # (B,1,r+rr)

    mode = cfg.kv_quant
    sc = None
    ones = jnp.ones((b,), jnp.int32)
    if mode is not None:
        kv, sc = cu_ops.quant_paged_cache_update(
            cache["kv"], cache["kv_scale"], kv_new, page_table, cur, ones,
            mode, impl=cache_impl)
    else:
        kv = cu_ops.paged_cache_update(cache["kv"], kv_new, page_table, cur,
                                       ones, impl=cache_impl)

    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["wk_b"].astype(dt))
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)          # (B,1,H,r+rr)
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    kv4 = kv[:, :, None, :]                                    # (P,ps,1,r+rr)
    ctx = da_ops.decode_attention_paged(
        q_eff, kv4, kv4, page_table, cur, scale=1.0 / math.sqrt(qk_hd),
        v_width=m.kv_lora_rank,
        k_scale=sc[:, :, None] if mode is not None else None
    ).astype(dt)                                               # (B,1,H,r)

    o = jnp.einsum("bqhr,rhk->bqhk", ctx, p["wv_b"].astype(dt))
    out = jnp.einsum("bqhk,hkd->bqd", o, p["wo"].astype(dt))
    out = shard(out, "batch", "seq", "d_model")
    return out, ({"kv": kv, "kv_scale": sc} if mode is not None
                 else {"kv": kv})


def mla_paged_prefill_chunk(cfg: ModelConfig, p, x, cache, offset, valid_len,
                            page_table, cache_impl: str = "auto"):
    """One chunk of chunked prefill through one MLA layer, paged.

    Mirrors ``mla_prefill_chunk`` with the pool view in place of the
    per-slot cache: offset/valid_len are (B,) (rows with
    ``valid_len == 0`` are masked to the scratch page and discarded by
    the caller).  Attend first — the chunk's own rows arrive as
    separate operands — then scatter.
    """
    from repro.kernels.cache_update import ops as cu_ops
    from repro.kernels.prefill_attention import ops as pf_ops
    m = cfg.mla
    dt = x.dtype
    b, t = x.shape[:2]
    off = jnp.broadcast_to(jnp.asarray(offset, jnp.int32), (b,))
    positions = off[:, None] + jnp.arange(t, dtype=jnp.int32)[None]

    q_nope, q_rope = _project_q(cfg, p, x, positions)          # (B,T,H,*)
    latent_new, k_rope_new = _project_kv_latent(cfg, p, x, positions)
    kv_new = jnp.concatenate([latent_new, k_rope_new], axis=-1)  # (B,T,r+rr)

    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["wk_b"].astype(dt))
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)          # (B,T,H,r+rr)
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    mode = cfg.kv_quant
    kvx = kv_new[:, :, None, :]                                # (B,T,1,r+rr)
    kvp = cache["kv"][:, :, None, :]                           # (P,ps,1,r+rr)
    ctx = pf_ops.prefill_attention_paged(
        q_eff, kvx, kvx, kvp, kvp, page_table, off,
        scale=1.0 / math.sqrt(qk_hd), v_width=m.kv_lora_rank,
        k_scale=(cache["kv_scale"][:, :, None] if mode is not None
                 else None)).astype(dt)                        # (B,T,H,r)

    valids = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))
    if mode is not None:
        kv, sc = cu_ops.quant_paged_cache_update(
            cache["kv"], cache["kv_scale"], kv_new, page_table, off,
            valids, mode, impl=cache_impl)
    else:
        kv = cu_ops.paged_cache_update(cache["kv"], kv_new, page_table, off,
                                       valids, impl=cache_impl)
    o = jnp.einsum("bqhr,rhk->bqhk", ctx, p["wv_b"].astype(dt))
    out = jnp.einsum("bqhk,hkd->bqd", o, p["wo"].astype(dt))
    out = shard(out, "batch", "seq", "d_model")
    return out, ({"kv": kv, "kv_scale": sc} if mode is not None
                 else {"kv": kv})


def mla_decode_attention(cfg: ModelConfig, p, x, cache, cur_len,
                         cache_impl: str = "auto", impl: str = "dense"):
    """One-token absorbed-MLA decode. x: (B,1,d).

    ``cur_len`` is a scalar (synchronized decode) or a (B,) vector of
    per-slot positions (continuous batching); the vector path scatters
    each row's ``[latent | rope]`` row at its own offset via one
    ``kernels/cache_update`` call.

    impl: "dense" materialises the (B, H, 1, C) score tensor over the
    whole cache; "flash" runs ``kernels/decode_attention`` with the
    concatenated cache as both K and V (``v_width`` keeps the value
    read to the latent prefix) — blocks beyond each row's prefix are
    never read.
    """
    m = cfg.mla
    dt = x.dtype
    b = x.shape[0]
    cur = jnp.asarray(cur_len, jnp.int32)
    per_row = cur.ndim == 1
    positions = cur[:, None] if per_row else jnp.full((b, 1), cur, jnp.int32)

    q_nope, q_rope = _project_q(cfg, p, x, positions)          # (B,1,H,*)
    latent_new, k_rope_new = _project_kv_latent(cfg, p, x, positions)
    kv_new = jnp.concatenate([latent_new, k_rope_new], axis=-1)  # (B,1,r+rr)

    mode = cfg.kv_quant
    sc = None
    if per_row:
        from repro.kernels.cache_update import ops as cu_ops
        slot_rows = jnp.minimum(cur, cache["kv"].shape[1] - 1)
        if mode is not None:
            kv, sc = cu_ops.quant_cache_update(
                cache["kv"], cache["kv_scale"], kv_new, slot_rows, mode,
                impl=cache_impl)
        else:
            kv = cu_ops.cache_update(cache["kv"], kv_new, slot_rows,
                                     impl=cache_impl)
    elif mode is not None:
        kv_codes, sc_new = quant.quantize(kv_new, mode)
        kv = jax.lax.dynamic_update_slice(cache["kv"], kv_codes,
                                          (0, cur_len, 0))
        sc = jax.lax.dynamic_update_slice(cache["kv_scale"], sc_new,
                                          (0, cur_len))
    else:
        kv = jax.lax.dynamic_update_slice(
            cache["kv"], kv_new.astype(cache["kv"].dtype), (0, cur_len, 0))
    kv = shard(kv, "batch", "kv_seq", "kv_rank")
    if mode is not None:
        sc = shard(sc, "batch", "kv_seq")
    new_cache = {"kv": kv, "kv_scale": sc} if mode is not None \
        else {"kv": kv}

    # absorb W_UK into the query: (B,1,H,nope) @ (r,H,nope) -> (B,1,H,r)
    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["wk_b"].astype(dt))

    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = 1.0 / math.sqrt(qk_hd)
    if impl == "flash":
        from repro.kernels.decode_attention import ops as da_ops
        # [q_lat | q_rope] . [latent | rope] is the absorbed score, so
        # the concatenated cache row *is* the key; its latent prefix is
        # the value (KVH=1, G=H — every query head shares the latent).
        q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)   # (B,1,H,r+rr)
        kv4 = kv[:, :, None, :]                             # (B,C,1,r+rr)
        ctx = da_ops.decode_attention(
            q_eff, kv4, kv4, cur, scale=scale, v_width=m.kv_lora_rank,
            k_scale=sc[:, :, None] if mode is not None else None
        ).astype(dt)                                        # (B,1,H,r)
    elif impl == "dense":
        kv_f = quant.dequantize(kv, sc) if mode is not None else kv
        latent = kv_f[..., :m.kv_lora_rank]
        k_rope = kv_f[..., m.kv_lora_rank:]
        s_lat = jnp.einsum("bqhr,bsr->bhqs", q_lat, latent.astype(dt))
        s_rope = jnp.einsum("bqhk,bsk->bhqs", q_rope, k_rope.astype(dt))
        scores = (s_lat + s_rope).astype(jnp.float32) * scale

        cache_len = kv.shape[1]
        # per-slot validity against each row's own position counter; the
        # row dim is degenerate (1,1,1,C) when cur is a scalar.
        cur_col = cur[:, None] if per_row else cur[None, None]
        valid = jnp.arange(cache_len)[None, None, None, :] \
            <= cur_col[:, None, None, :]         # (B|1,1,1,C) over (B,H,1,C)
        scores = jnp.where(valid, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqs,bsr->bqhr", probs.astype(dt),
                         latent.astype(dt))
    else:
        raise ValueError(f"unknown decode attention impl {impl!r}")

    o = jnp.einsum("bqhr,rhk->bqhk", ctx, p["wv_b"].astype(dt))
    out = jnp.einsum("bqhk,hkd->bqd", o, p["wo"].astype(dt))
    out = shard(out, "batch", "seq", "d_model")
    return out, new_cache
