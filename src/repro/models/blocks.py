"""Per-layer block assembly for every assigned architecture.

A "block" is one layer of the stack. Its kind is a function of the layer
index and the config:

  "A"  attention + FFN (dense MLP or MoE)   — all transformer archs
  "M"  mamba + FFN (dense MLP or MoE)       — jamba's SSM layers
  "m"  mLSTM block (self-contained)         — xlstm
  "s"  sLSTM block (self-contained)         — xlstm
  "E"  bidirectional encoder block          — whisper encoder
  "X"  decoder block with cross-attention   — whisper decoder

Blocks expose four entry points with a uniform signature so model.py can
scan over homogeneous stacks: init, forward (full sequence), decode (one
token against a cache), and cache init.
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers, mamba, mla, moe, xlstm
from repro.sharding.specs import annotate


# -- layer-kind layout ----------------------------------------------------------

def layer_kind(cfg: ModelConfig, idx: int, encoder: bool = False) -> str:
    if encoder:
        return "E"
    if cfg.is_encoder_decoder:
        return "X"
    if cfg.family == "ssm":
        pat = cfg.xlstm.pattern
        return pat[idx % len(pat)]
    if cfg.family == "hybrid":
        return cfg.hybrid_pattern[idx % len(cfg.hybrid_pattern)]
    return "A"


def layer_window(cfg: ModelConfig, idx: int) -> Optional[int]:
    """Sliding-window size for this layer (gemma2 local/global pattern)."""
    if cfg.layer_pattern and cfg.sliding_window:
        kind = cfg.layer_pattern[idx % len(cfg.layer_pattern)]
        return cfg.sliding_window if kind == "L" else None
    return cfg.sliding_window


def attn_impl(cfg: ModelConfig, seq_len: int) -> str:
    if cfg.attn_impl != "auto":
        return cfg.attn_impl
    if seq_len <= cfg.attn_chunk or seq_len % cfg.attn_chunk:
        return "dense"   # short or non-chunk-aligned (whisper's 1500)
    return "chunked"


def decode_attn_impl(cfg: ModelConfig) -> str:
    """Resolve ``cfg.decode_attn_impl`` for this process.

    "auto" defers to the ``PMT_DECODE_ATTN_IMPL`` env var (values:
    dense / flash; A/B experiments), then picks "flash" — the
    length-aware ``kernels/decode_attention`` path — iff the default
    backend is TPU, where its Pallas kernel compiles; elsewhere "dense"
    keeps the decode step a single fused XLA region.  Both
    self-attention KV caches and the MLA latent cache honor the knob;
    explicit "flash" off-TPU runs the kernel's masked-lax twin.  (How
    "flash" then dispatches between Pallas and the lax twin is the
    separate ops-layer knob ``PMT_DECODE_ATTENTION_DISPATCH``.)
    """
    impl = cfg.decode_attn_impl
    if impl == "auto":
        impl = os.environ.get("PMT_DECODE_ATTN_IMPL", "auto")
    if impl == "auto":
        impl = "flash" if jax.default_backend() == "tpu" else "dense"
    if impl not in ("dense", "flash"):
        raise ValueError(f"unknown decode_attn_impl {impl!r}")
    return impl


# -- init -----------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, idx: int, encoder: bool = False):
    kind = layer_kind(cfg, idx, encoder)
    ks = jax.random.split(key, 8)
    if kind in ("m", "s"):
        p = {"norm": layers.init_norm(ks[0], cfg)}
        p["cell"] = (xlstm.init_mlstm(ks[1], cfg) if kind == "m"
                     else xlstm.init_slstm(ks[1], cfg))
        return p

    p = {"norm_1": layers.init_norm(ks[0], cfg),
         "norm_2": layers.init_norm(ks[1], cfg)}
    if cfg.post_block_norm:
        p["post_norm_1"] = layers.init_norm(ks[6], cfg)
        p["post_norm_2"] = layers.init_norm(ks[7], cfg)

    if kind == "M":
        p["mixer"] = mamba.init_mamba(ks[2], cfg)
    elif cfg.attention == "mla":
        p["mixer"] = mla.init_mla(ks[2], cfg)
    else:
        p["mixer"] = attn.init_attention(ks[2], cfg)

    if kind == "X":
        p["norm_x"] = layers.init_norm(ks[4], cfg)
        p["cross"] = attn.init_attention(ks[5], cfg, cross=True)

    if kind != "E" and moe.is_moe_layer(cfg, idx):
        p["ffn"] = moe.init_moe(ks[3], cfg)
    else:
        ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.dense_ff_dim and kind != "E":
            ff = cfg.moe.dense_ff_dim
        p["ffn"] = layers.init_mlp(ks[3], cfg, ff=ff)
    return p


# -- forward (full sequence) ------------------------------------------------------

def block_forward(cfg: ModelConfig, p, x, positions, idx: int, *,
                  enc_out=None, encoder: bool = False,
                  collect_kv: bool = False):
    """One block over the full sequence.

    Returns (x, aux_loss, kv) — kv is the mixer state the serve path needs
    to build a cache from prefill (None unless collect_kv).
    """
    kind = layer_kind(cfg, idx, encoder)
    aux = jnp.zeros((), jnp.float32)
    kv = None

    if kind in ("m", "s"):
        h = layers.apply_norm(cfg, p["norm"], x)
        fwd = xlstm.mlstm_forward if kind == "m" else xlstm.slstm_forward
        if collect_kv:
            out, kv = fwd(cfg, p["cell"], h, return_state=True)
            return x + out, aux, kv
        return x + fwd(cfg, p["cell"], h), aux, None

    h = layers.apply_norm(cfg, p["norm_1"], x)
    impl = attn_impl(cfg, h.shape[1])
    if kind == "M":
        if collect_kv:
            out, kv = mamba.mamba_forward(cfg, p["mixer"], h,
                                          return_state=True)
        else:
            out = mamba.mamba_forward(cfg, p["mixer"], h)
    elif cfg.attention == "mla":
        out, kv_pair = mla.mla_self_attention(cfg, p["mixer"], h, positions,
                                              impl=impl, chunk=cfg.attn_chunk)
        kv = kv_pair if collect_kv else None
    else:
        window = layer_window(cfg, idx)
        causal = kind != "E"
        q, k, v = attn.project_qkv(cfg, p["mixer"], h, positions,
                                   rope=cfg.use_rope)
        pos1d = positions[..., 0] if positions.ndim == 3 else positions
        o = attn.attention(cfg, q, k, v, q_pos=pos1d, kv_pos=pos1d,
                           causal=causal, window=window, impl=impl,
                           chunk=cfg.attn_chunk,
                           unroll=cfg.unroll_time_chunks,
                           causal_kv_trim=cfg.causal_kv_trim)
        out = attn.output_proj(p["mixer"], o)
        kv = (k, v) if collect_kv else None
    if cfg.post_block_norm:
        out = layers.apply_norm(cfg, p["post_norm_1"], out)
    x = x + out

    if kind == "X":
        h = layers.apply_norm(cfg, p["norm_x"], x)
        x = x + attn.cross_attention(cfg, p["cross"], h, enc_out)

    h = layers.apply_norm(cfg, p["norm_2"], x)
    if "router" in p["ffn"]:
        out, aux = moe.apply_moe(cfg, p["ffn"], h)
    else:
        out = layers.apply_mlp(cfg, p["ffn"], h)
    if cfg.post_block_norm:
        out = layers.apply_norm(cfg, p["post_norm_2"], out)
    return x + out, aux, kv


# -- caches ------------------------------------------------------------------------

def init_block_cache(cfg: ModelConfig, idx: int, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    kind = layer_kind(cfg, idx)
    if kind == "m":
        return xlstm.init_mlstm_cache(cfg, batch, dtype)
    if kind == "s":
        return xlstm.init_slstm_cache(cfg, batch, dtype)
    if kind == "M":
        return mamba.init_mamba_cache(cfg, batch, dtype)
    if cfg.attention == "mla":
        return mla.init_mla_cache(cfg, batch, max_len, dtype)
    window = layer_window(cfg, idx)
    cache = attn.init_kv_cache(cfg, batch, max_len, window=window,
                               dtype=dtype)
    if kind == "X":
        # cross-attention k/v are filled once from the encoder output
        # (distinct buffers — donated cache trees must not share; see
        # attn.init_kv_cache)
        xshape = (batch, cfg.enc_len, cfg.num_kv_heads, cfg.head_dim)
        cache["xk"] = jnp.zeros(xshape, dtype)
        cache["xv"] = jnp.zeros(xshape, dtype)
    return cache


def cache_axes(cfg: ModelConfig, idx: int):
    kind = layer_kind(cfg, idx)
    if kind == "m":
        return xlstm.mlstm_cache_axes()
    if kind == "s":
        return xlstm.slstm_cache_axes()
    if kind == "M":
        return mamba.mamba_cache_axes()
    if cfg.attention == "mla":
        return mla.mla_cache_axes(cfg)
    ax = {"k": attn.cache_spec_axes(), "v": attn.cache_spec_axes()}
    if cfg.kv_quant is not None:
        ax["k_scale"] = attn.scale_spec_axes()
        ax["v_scale"] = attn.scale_spec_axes()
    if kind == "X":
        ax["xk"] = attn.cache_spec_axes()
        ax["xv"] = attn.cache_spec_axes()
    return ax


# -- decode (one token) ---------------------------------------------------------------

def block_decode(cfg: ModelConfig, p, x, cache, cur_len, idx: int):
    """One-token decode through one block. x: (B,1,d)."""
    kind = layer_kind(cfg, idx)
    if kind in ("m", "s"):
        h = layers.apply_norm(cfg, p["norm"], x)
        dec = xlstm.mlstm_decode if kind == "m" else xlstm.slstm_decode
        out, cache = dec(cfg, p["cell"], h, cache)
        return x + out, cache

    h = layers.apply_norm(cfg, p["norm_1"], x)
    if kind == "M":
        out, cache = mamba.mamba_decode(cfg, p["mixer"], h, cache)
    elif cfg.attention == "mla":
        out, cache = mla.mla_decode_attention(cfg, p["mixer"], h, cache,
                                              cur_len,
                                              impl=decode_attn_impl(cfg))
    else:
        window = layer_window(cfg, idx)
        kv_cache = {n: cache[n] for n in ("k", "v", "k_scale", "v_scale")
                    if n in cache}
        out, kv_cache = attn.decode_self_attention(
            cfg, p["mixer"], h, kv_cache, cur_len, window=window,
            impl=decode_attn_impl(cfg))
        cache = dict(cache, **kv_cache)
    if cfg.post_block_norm:
        out = layers.apply_norm(cfg, p["post_norm_1"], out)
    x = x + out

    if kind == "X":
        h = layers.apply_norm(cfg, p["norm_x"], x)
        b = h.shape[0]
        q, _, _ = attn.project_qkv(cfg, p["cross"], h, None, rope=False)
        skv = cache["xk"].shape[1]
        o = attn.attention(
            cfg, q, cache["xk"].astype(q.dtype), cache["xv"].astype(q.dtype),
            q_pos=jnp.zeros((b, 1), jnp.int32),
            kv_pos=jnp.zeros((b, skv), jnp.int32), causal=False, impl="dense")
        x = x + attn.output_proj(p["cross"], o)

    h = layers.apply_norm(cfg, p["norm_2"], x)
    if "router" in p["ffn"]:
        out, _ = moe.apply_moe(cfg, p["ffn"], h)
    else:
        out = layers.apply_mlp(cfg, p["ffn"], h)
    if cfg.post_block_norm:
        out = layers.apply_norm(cfg, p["post_norm_2"], out)
    return x + out, cache


# -- chunked prefill (resume from a partial cache at an offset) ------------------------

def _masked_state_scan(cell_fn, x, cache, valid_len):
    """Scan a one-token decode cell over a chunk, freezing the carried
    state at pad positions.

    ``cell_fn(x_t (B,1,d), cache) -> (out (B,1,d), new_cache)`` is the
    cell's existing decode recurrence — chunked prefill for state
    blocks (mamba / mLSTM / sLSTM) is exactly the decode scan resumed
    from the carried cache, so prefix-resume costs nothing new.  Steps
    ``t >= valid_len`` (a final partial chunk's right-padding) keep the
    previous state: the carry a later decode resumes from reflects the
    real prompt only.  Pad outputs are garbage the caller discards.
    """
    t = x.shape[1]

    def step(carry, xt_i):
        xt, i = xt_i
        out, new = cell_fn(xt[:, None], carry)
        keep = i < valid_len
        carry = jax.tree.map(lambda n, o: jnp.where(keep, n, o), new, carry)
        return carry, out[:, 0]

    # Some cells widen state leaves on their first step (sLSTM keeps h
    # in compute dtype while the stored cache is bf16); promote the
    # carry up front so the scan sees one stable dtype per leaf, and
    # demote on exit so chunk N+1's input cache matches chunk N's —
    # the serve engine jits one chunk function and feeds caches back
    # through it, so the cache tree must be a dtype fixpoint.
    orig = cache
    new_struct = jax.eval_shape(lambda c: cell_fn(x[:, :1], c)[1], cache)
    cache = jax.tree.map(lambda o, s: o.astype(s.dtype), cache, new_struct)
    idx = jnp.arange(t, dtype=jnp.int32)
    cache, ys = jax.lax.scan(step, cache, (x.swapaxes(0, 1), idx))
    cache = jax.tree.map(lambda n, o: n.astype(o.dtype), cache, orig)
    return ys.swapaxes(0, 1), cache


def block_prefill_chunk(cfg: ModelConfig, p, x, cache, offset, valid_len,
                        idx: int):
    """One prefill chunk through one block. x: (B, T, d) at absolute
    positions ``offset + i``; ``cache`` holds the state/KV of positions
    ``< offset``; ``valid_len`` marks a final partial chunk's real
    length.  Returns (x, new_cache) — same contract as ``block_decode``
    widened to T tokens."""
    kind = layer_kind(cfg, idx)
    if kind == "X":
        raise NotImplementedError(
            "chunked prefill does not cover encoder-decoder archs (the "
            "cross-attention KV comes from one whole-encoder pass); "
            "serve admission falls back to blocking prefill for them")
    if kind in ("m", "s"):
        h = layers.apply_norm(cfg, p["norm"], x)
        dec = xlstm.mlstm_decode if kind == "m" else xlstm.slstm_decode
        out, cache = _masked_state_scan(
            lambda xt, c: dec(cfg, p["cell"], xt, c), h, cache, valid_len)
        return x + out, cache

    h = layers.apply_norm(cfg, p["norm_1"], x)
    if kind == "M":
        out, cache = _masked_state_scan(
            lambda xt, c: mamba.mamba_decode(cfg, p["mixer"], xt, c),
            h, cache, valid_len)
    elif cfg.attention == "mla":
        out, cache = mla.mla_prefill_chunk(cfg, p["mixer"], h, cache,
                                           offset, valid_len)
    else:
        window = layer_window(cfg, idx)
        kv_cache = {n: cache[n] for n in ("k", "v", "k_scale", "v_scale")
                    if n in cache}
        out, kv_cache = attn.prefill_chunk_self_attention(
            cfg, p["mixer"], h, kv_cache, offset, valid_len,
            window=window)
        cache = dict(cache, **kv_cache)
    if cfg.post_block_norm:
        out = layers.apply_norm(cfg, p["post_norm_1"], out)
    x = x + out

    h = layers.apply_norm(cfg, p["norm_2"], x)
    if "router" in p["ffn"]:
        out, _ = moe.apply_moe(cfg, p["ffn"], h)
    else:
        out = layers.apply_mlp(cfg, p["ffn"], h)
    if cfg.post_block_norm:
        out = layers.apply_norm(cfg, p["post_norm_2"], out)
    return x + out, cache


# -- paged decode / prefill (block pools + page tables) --------------------------------

def init_paged_block_cache(cfg: ModelConfig, idx: int, num_pages: int,
                           page_size: int,
                           dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Paged pools for one block.  Only attention-kind layers ("A",
    including MLA) page — state blocks carry O(1) recurrent state and
    encoder-decoder blocks a one-shot cross cache, neither of which
    a page table buys anything for (``model.supports_paged`` gates
    whole-model eligibility)."""
    if layer_kind(cfg, idx) != "A":
        raise ValueError(f"layer {idx} (kind {layer_kind(cfg, idx)!r}) "
                         "has no paged cache layout")
    if cfg.attention == "mla":
        return mla.init_paged_mla_pool(cfg, num_pages, page_size, dtype)
    return attn.init_paged_kv_pools(cfg, num_pages, page_size, dtype)


def paged_cache_axes(cfg: ModelConfig, idx: int):
    """Logical axes for paged pool leaves — no batch axis (the pool's
    leading dim is physical pages shared by every slot)."""
    if cfg.attention == "mla":
        ax = {"kv": ("kv_pages", "page", "kv_rank")}
        if cfg.kv_quant is not None:
            ax["kv_scale"] = ("kv_pages", "page")
        return ax
    ax = ("kv_pages", "page", "kv_heads", "head_dim")
    axes = {"k": ax, "v": ax}
    if cfg.kv_quant is not None:
        sax = ("kv_pages", "page", "kv_heads")
        axes["k_scale"] = sax
        axes["v_scale"] = sax
    return axes


def _block_tail(cfg: ModelConfig, p, x, out):
    """Shared post-mixer tail: post-norm, residual, FFN (dense or MoE)."""
    if cfg.post_block_norm:
        out = layers.apply_norm(cfg, p["post_norm_1"], out)
    x = x + out
    h = layers.apply_norm(cfg, p["norm_2"], x)
    if "router" in p["ffn"]:
        out, _ = moe.apply_moe(cfg, p["ffn"], h)
    else:
        out = layers.apply_mlp(cfg, p["ffn"], h)
    if cfg.post_block_norm:
        out = layers.apply_norm(cfg, p["post_norm_2"], out)
    return x + out


def block_paged_decode(cfg: ModelConfig, p, x, cache, cur_len, page_table,
                       idx: int):
    """One-token decode through one block against paged pools.
    x: (B,1,d); cur_len: (B,); page_table: (B, NB)."""
    h = layers.apply_norm(cfg, p["norm_1"], x)
    if cfg.attention == "mla":
        out, cache = mla.mla_paged_decode_attention(cfg, p["mixer"], h,
                                                    cache, cur_len,
                                                    page_table)
    else:
        out, cache = attn.paged_decode_self_attention(
            cfg, p["mixer"], h, cache, cur_len, page_table,
            window=layer_window(cfg, idx))
    return _block_tail(cfg, p, x, out), cache


def block_paged_prefill_chunk(cfg: ModelConfig, p, x, cache, offset,
                              valid_len, page_table, idx: int):
    """One prefill chunk through one block against paged pools.
    x: (B, T, d); offset/valid_len: (B,); page_table: (B, NB)."""
    h = layers.apply_norm(cfg, p["norm_1"], x)
    if cfg.attention == "mla":
        out, cache = mla.mla_paged_prefill_chunk(cfg, p["mixer"], h, cache,
                                                 offset, valid_len,
                                                 page_table)
    else:
        out, cache = attn.paged_prefill_chunk_self_attention(
            cfg, p["mixer"], h, cache, offset, valid_len, page_table,
            window=layer_window(cfg, idx))
    return _block_tail(cfg, p, x, out), cache


# -- prefill cache construction --------------------------------------------------------

def prefill_block_cache(cfg: ModelConfig, idx: int, kv, max_len: int,
                        x_enc_kv=None, dtype=jnp.bfloat16):
    """Build this block's decode cache from prefill-collected state."""
    kind = layer_kind(cfg, idx)
    if kind in ("m", "s", "M"):
        raise ValueError("state blocks build caches inside prefill")
    if cfg.attention == "mla":
        latent, k_rope = kv
        return mla.prefill_mla_cache(cfg, latent, k_rope, max_len, dtype)
    k, v = kv
    window = layer_window(cfg, idx)
    cache = attn.prefill_kv_cache(cfg, k, v, max_len, window=window,
                                  dtype=dtype)
    if kind == "X" and x_enc_kv is not None:
        cache["xk"], cache["xv"] = (z.astype(dtype) for z in x_enc_kv)
    return cache
