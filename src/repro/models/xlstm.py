"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, sequential) — xlstm-1.3b stacks them 7:1.

mLSTM train/prefill uses the paper's *stabilized parallel form*: per query
block, the full decay matrix D_ts = F_t - F_s + i_s is materialized
(q-chunked like chunked attention, so the live tensor is (B, nh, Qc, S)),
row-max stabilized, and contracted with V.  Decode is the O(1) recurrence
on the (hd x hd) matrix memory.

sLSTM is inherently sequential (recurrent gate input R.h_{t-1}): train uses
lax.scan over time.  XLA cost analysis counts scan bodies once, so the
roofline module adds the documented analytic correction for the recurrent
matvecs (repro.roofline.costs.SLSTM_CORRECTION).

State caches (decode):
  mLSTM: C (B, nh, hd, hd), n (B, nh, hd), m (B, nh)
  sLSTM: h, c, n, m each (B, d)
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.constants import NEG_INF
from repro.models import layers
from repro.sharding.specs import annotate, shard


def m_inner(cfg: ModelConfig) -> int:
    return int(cfg.xlstm.mlstm_proj_factor * cfg.d_model)


def _heads(cfg: ModelConfig):
    return cfg.num_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig):
    d, din, nh = cfg.d_model, m_inner(cfg), _heads(cfg)
    xc = cfg.xlstm
    ks = jax.random.split(key, 8)
    di = layers.dense_init
    return {
        "w_up": annotate(di(ks[0], (d, 2 * din)), "d_model", "lstm_inner"),
        "conv_w": annotate(di(ks[1], (xc.conv1d_kernel, din), in_axis=0),
                           None, "lstm_inner"),
        "conv_b": annotate(jnp.zeros((din,), jnp.float32), "lstm_inner"),
        "wq": annotate(di(ks[2], (din, din)), "lstm_inner", None),
        "wk": annotate(di(ks[3], (din, din)), "lstm_inner", None),
        "wv": annotate(di(ks[4], (din, din)), "lstm_inner", None),
        "w_if": annotate(di(ks[5], (din, 2 * nh)), "lstm_inner", None),
        "b_if": annotate(jnp.concatenate(
            [jnp.zeros((nh,)), jnp.linspace(3.0, 6.0, nh)]).astype(
                jnp.float32), None),
        "gn": annotate(jnp.ones((din,), jnp.float32), "lstm_inner"),
        "w_down": annotate(di(ks[6], (din, d)), "lstm_inner", "d_model"),
    }


def _mlstm_pre(cfg: ModelConfig, p, x, conv_hist=None):
    """Shared projections. x: (B,S,d) -> q,k,v (B,S,nh,hd), i/f pre-acts
    (B,S,nh), gate z (B,S,din), new conv history (B,k-1,din)."""
    nh = _heads(cfg)
    dt = x.dtype
    xz = x @ p["w_up"].astype(dt)
    xm, z = jnp.split(xz, 2, axis=-1)
    xm = shard(xm, "batch", "seq", "lstm_inner")
    k_w = p["conv_w"].astype(dt)
    kk = k_w.shape[0]
    hist = jnp.zeros((x.shape[0], kk - 1, xm.shape[-1]), dt) \
        if conv_hist is None else conv_hist.astype(dt)
    xp = jnp.concatenate([hist, xm], axis=1)
    xc = sum(xp[:, i:i + xm.shape[1]] * k_w[i] for i in range(kk))
    xc = jax.nn.silu(xc + p["conv_b"].astype(dt))
    new_hist = xp[:, -(kk - 1):]

    b, s, din = xm.shape
    hd = din // nh
    q = (xc @ p["wq"].astype(dt)).reshape(b, s, nh, hd)
    k = (xc @ p["wk"].astype(dt)).reshape(b, s, nh, hd) / math.sqrt(hd)
    v = (xm @ p["wv"].astype(dt)).reshape(b, s, nh, hd)
    ifg = (xm @ p["w_if"].astype(dt)).astype(jnp.float32) \
        + p["b_if"].astype(jnp.float32)
    i_pre, f_pre = ifg[..., :nh], ifg[..., nh:]
    return q, k, v, i_pre, f_pre, z, new_hist


def _group_norm(h, scale, nh, eps=1e-6):
    """Per-head group norm on (B, S, nh, hd) -> flattened (B,S,din)."""
    h32 = h.astype(jnp.float32)
    mu = h32.mean(-1, keepdims=True)
    var = h32.var(-1, keepdims=True)
    y = (h32 - mu) * jax.lax.rsqrt(var + eps)
    b, s = h.shape[:2]
    y = y.reshape(b, s, -1) * scale
    return y


def _mlstm_rows(q, k, v, fcum, kv_fcum, kv_i, mask):
    """Stabilized parallel mLSTM for one query block.

    q: (B,Qc,nh,hd), fcum: (B,Qc,nh) cumulative log-f at query positions,
    kv_*: (B,S,nh) key-side cumulative log-f / input pre-acts,
    mask: (B,Qc,S) True where s<=t. Returns (B,Qc,nh,hd).
    """
    d = fcum[:, :, None, :].transpose(0, 3, 1, 2) \
        - kv_fcum[:, None, :, :].transpose(0, 3, 1, 2) \
        + kv_i[:, None, :, :].transpose(0, 3, 1, 2)        # (B,nh,Qc,S)
    d = jnp.where(mask[:, None], d, NEG_INF)
    m = jnp.max(d, axis=-1, keepdims=True)                 # (B,nh,Qc,1)
    dexp = jnp.exp(d - m)
    qk = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32)
    c = qk * dexp
    denom = jnp.maximum(jnp.abs(c.sum(-1, keepdims=True)), jnp.exp(-m))
    w = (c / denom).astype(v.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", w, v)


def mlstm_forward(cfg: ModelConfig, p, x, return_state: bool = False):
    """Full-sequence mLSTM block. x: (B,S,d) -> (B,S,d)
    (+ the decode cache when ``return_state``)."""
    nh = _heads(cfg)
    dt = x.dtype
    b, s, _ = x.shape
    q, k, v, i_pre, f_pre, z, conv_hist = _mlstm_pre(cfg, p, x)
    logf = jax.nn.log_sigmoid(f_pre)                       # (B,S,nh)
    fcum = jnp.cumsum(logf, axis=1)

    qc = min(cfg.attn_chunk, s)
    pos = jnp.arange(s, dtype=jnp.int32)
    rows = jax.checkpoint(_mlstm_rows)   # recompute D in backward
    if s == qc:
        mask = pos[None, :, None] >= pos[None, None, :]
        mask = jnp.broadcast_to(mask, (b, s, s))
        h = rows(q, k, v, fcum, fcum, i_pre, mask)
    else:
        nb = s // qc
        outs = []
        for i in range(nb):
            sl = slice(i * qc, (i + 1) * qc)
            if cfg.causal_kv_trim:
                hi = (i + 1) * qc
                mask = pos[None, sl, None] >= pos[None, None, :hi]
                mask = jnp.broadcast_to(mask, (b, qc, hi))
                outs.append(rows(q[:, sl], k[:, :hi], v[:, :hi],
                                 fcum[:, sl], fcum[:, :hi], i_pre[:, :hi],
                                 mask))
            else:
                mask = pos[None, sl, None] >= pos[None, None, :]
                mask = jnp.broadcast_to(mask, (b, qc, s))
                outs.append(rows(q[:, sl], k, v, fcum[:, sl], fcum, i_pre,
                                 mask))
        h = jnp.concatenate(outs, axis=1)

    y = _group_norm(h, p["gn"].astype(jnp.float32), nh).astype(dt)
    y = y * jax.nn.silu(z)
    out = y @ p["w_down"].astype(dt)
    out = shard(out, "batch", "seq", "d_model")
    if not return_state:
        return out
    # final recurrent state from the parallel form: with stabilizer
    # m* = max_s (F_T - F_s + i_s), the cached C/n are the exp(-m*)-scaled
    # sums the decode recurrence expects.
    d_end = fcum[:, -1:, :] - fcum + i_pre                 # (B,S,nh)
    m_end = jnp.max(d_end, axis=1)                         # (B,nh)
    w = jnp.exp(d_end - m_end[:, None, :])                 # (B,S,nh)
    kw = k.astype(jnp.float32) * w[..., None]
    c_end = jnp.einsum("bshk,bshv->bhkv", kw, v.astype(jnp.float32))
    n_end = kw.sum(axis=1)                                 # (B,nh,hd)
    cache = {"C": c_end.astype(jnp.bfloat16), "n": n_end, "m": m_end,
             "conv": conv_hist.astype(jnp.bfloat16)}
    return out, cache


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    nh = _heads(cfg)
    din = m_inner(cfg)
    hd = din // nh
    return {
        "C": jnp.zeros((batch, nh, hd, hd), dtype),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), 0.0, jnp.float32),
        "conv": jnp.zeros((batch, cfg.xlstm.conv1d_kernel - 1, din), dtype),
    }


def mlstm_cache_axes():
    return {"C": ("batch", None, "lstm_inner", None),
            "n": ("batch", None, "lstm_inner"),
            "m": ("batch", None),
            "conv": ("batch", None, "lstm_inner")}


def mlstm_decode(cfg: ModelConfig, p, x, cache):
    """One-token mLSTM recurrence. x: (B,1,d)."""
    nh = _heads(cfg)
    dt = x.dtype
    q, k, v, i_pre, f_pre, z, new_hist = _mlstm_pre(cfg, p, x,
                                                    cache["conv"])
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]                 # (B,nh,hd)
    i1, f1 = i_pre[:, 0], f_pre[:, 0]                      # (B,nh)
    logf = jax.nn.log_sigmoid(f1)
    m_new = jnp.maximum(logf + cache["m"], i1)
    f_sc = jnp.exp(logf + cache["m"] - m_new)[..., None]
    i_sc = jnp.exp(i1 - m_new)[..., None]
    kv = jnp.einsum("bhk,bhv->bhkv", k1.astype(jnp.float32),
                    v1.astype(jnp.float32))
    c_new = f_sc[..., None] * cache["C"].astype(jnp.float32) + i_sc[..., None] * kv
    n_new = f_sc * cache["n"] + i_sc * k1.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", q1.astype(jnp.float32), c_new)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", q1.astype(jnp.float32), n_new)),
        jnp.exp(-m_new))[..., None]
    h = (num / den)[:, None]                               # (B,1,nh,hd)
    y = _group_norm(h, p["gn"].astype(jnp.float32), nh).astype(dt)
    y = y * jax.nn.silu(z)
    out = y @ p["w_down"].astype(dt)
    out = shard(out, "batch", "seq", "d_model")
    return out, {"C": c_new.astype(cache["C"].dtype), "n": n_new,
                 "m": m_new, "conv": new_hist.astype(cache["conv"].dtype)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig):
    d, nh = cfg.d_model, _heads(cfg)
    hd = d // nh
    dff = int(cfg.xlstm.slstm_proj_factor * d)
    ks = jax.random.split(key, 5)
    di = layers.dense_init
    return {
        # input projections for i,f,z,o fused: (d, 4d)
        "w_in": annotate(di(ks[0], (d, 4 * d)), "d_model", "lstm_inner"),
        "b_in": annotate(jnp.concatenate(
            [jnp.zeros((d,)), jnp.ones((d,)) * 3.0, jnp.zeros((2 * d,))]
        ).astype(jnp.float32), "lstm_inner"),
        # block-diagonal recurrent weights per head: (nh, hd, 4*hd)
        "r": annotate(di(ks[1], (nh, hd, 4 * hd), in_axis=1) * 0.5,
                      None, None, "lstm_inner"),
        "gn": annotate(jnp.ones((d,), jnp.float32), "d_model"),
        "ff_up": annotate(di(ks[2], (d, 2 * dff)), "d_model", "ffn"),
        "ff_down": annotate(di(ks[3], (dff, d)), "ffn", "d_model"),
    }


def _slstm_cell(cfg: ModelConfig, p, gates_x, state):
    """One step. gates_x: (B, 4d) precomputed input projections.
    state: (h, c, n, m) each (B, d). Returns (new_state, h_out)."""
    nh = _heads(cfg)
    d = cfg.d_model
    hd = d // nh
    h, c, n, m = state
    hh = h.reshape(-1, nh, hd)
    rec = jnp.einsum("bhk,hkj->bhj", hh, p["r"].astype(h.dtype))
    g = gates_x + rec.reshape(-1, 4 * d)
    gi, gf, gz, go = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    m_new = jnp.maximum(jax.nn.log_sigmoid(gf) + m, gi)
    i_sc = jnp.exp(gi - m_new)
    f_sc = jnp.exp(jax.nn.log_sigmoid(gf) + m - m_new)
    c_new = f_sc * c + i_sc * jnp.tanh(gz)
    n_new = f_sc * n + i_sc
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new.astype(h.dtype), c_new, n_new, m_new), h_new


def _slstm_out(cfg: ModelConfig, p, h_seq, x_dtype):
    """GroupNorm + gated FFN on the recurrent output."""
    nh = _heads(cfg)
    y = _group_norm(h_seq.reshape(*h_seq.shape[:2], nh, -1),
                    p["gn"].astype(jnp.float32), nh).astype(x_dtype)
    up, gate = jnp.split(y @ p["ff_up"].astype(x_dtype), 2, axis=-1)
    y = jax.nn.gelu(gate) * up
    out = y @ p["ff_down"].astype(x_dtype)
    return shard(out, "batch", "seq", "d_model")


def slstm_forward(cfg: ModelConfig, p, x, return_state: bool = False):
    """Full-sequence sLSTM (lax.scan over time). x: (B,S,d)."""
    b, s, d = x.shape
    dt = x.dtype
    gates_x = (x @ p["w_in"].astype(dt)
               + p["b_in"].astype(dt))                     # (B,S,4d)
    state = (jnp.zeros((b, d), dt), jnp.zeros((b, d), jnp.float32),
             jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32))

    def step(st, gx):
        st, h = _slstm_cell(cfg, p, gx, st)
        return st, h

    (h, c, n, m), hs = jax.lax.scan(step, state, gates_x.swapaxes(0, 1))
    h_seq = hs.swapaxes(0, 1)                              # (B,S,d) fp32
    out = _slstm_out(cfg, p, h_seq, dt)
    if return_state:
        return out, {"h": h.astype(jnp.bfloat16), "c": c, "n": n, "m": m}
    return out


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d), dtype),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.zeros((batch, d), jnp.float32)}


def slstm_cache_axes():
    return {"h": ("batch", "d_model"), "c": ("batch", "d_model"),
            "n": ("batch", "d_model"), "m": ("batch", "d_model")}


def slstm_decode(cfg: ModelConfig, p, x, cache):
    dt = x.dtype
    gates_x = (x[:, 0] @ p["w_in"].astype(dt) + p["b_in"].astype(dt))
    state = (cache["h"].astype(dt), cache["c"], cache["n"], cache["m"])
    (h, c, n, m), h_out = _slstm_cell(cfg, p, gates_x, state)
    out = _slstm_out(cfg, p, h_out[:, None], dt)
    return out, {"h": h, "c": c, "n": n, "m": m}


def slstm_recurrent_flops(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Analytic FLOPs of the recurrent matvecs that XLA cost analysis
    undercounts (scan body counted once): per step, per head, a
    (hd x 4hd) matvec, fwd + 2x bwd."""
    nh = _heads(cfg)
    hd = cfg.d_model // nh
    per_step = batch * nh * hd * 4 * hd * 2
    return 3.0 * per_step * (seq - 1)
