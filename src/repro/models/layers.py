"""Shared layers: norms, MLPs, embeddings, RoPE (incl. M-RoPE), softcap."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.specs import annotate, shard


# -- init helpers ------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else \
        math.prod(shape[a] for a in in_axis)
    std = 1.0 / math.sqrt(max(1, fan_in))
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * std


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# -- norms ---------------------------------------------------------------------

def init_norm(key, cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm_type == "layernorm_nonparam":
        return {}  # olmo: non-parametric LN has no weights
    p = {"scale": annotate(jnp.ones((d,), jnp.float32), "d_model")}
    if cfg.norm_type == "layernorm":  # whisper: parametric LN with bias
        p["bias"] = annotate(jnp.zeros((d,), jnp.float32), "d_model")
    return p


def apply_norm(cfg: ModelConfig, p, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm_type in ("layernorm_nonparam", "layernorm"):
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        if cfg.norm_bf16_io:
            # bf16 datapath: only the (B,S,1) stats stay fp32, so the
            # upstream TP all-reduce keeps a bf16 operand (§Perf)
            y = (x - mu.astype(dtype)) * jax.lax.rsqrt(
                var + eps).astype(dtype)
            if p:
                y = y * p["scale"].astype(dtype)
                if "bias" in p:
                    y = y + p["bias"].astype(dtype)
            return y
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        if p:
            y = y * p["scale"].astype(jnp.float32)
            if "bias" in p:
                y = y + p["bias"].astype(jnp.float32)
        return y.astype(dtype)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    if cfg.norm_bf16_io:
        y = x * jax.lax.rsqrt(ms + eps).astype(dtype)
        if p:
            y = y * p["scale"].astype(dtype)
        return y
    y = x32 * jax.lax.rsqrt(ms + eps)
    if p:
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(dtype)


# -- softcap (gemma2) ------------------------------------------------------------

def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# -- MLP -----------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d: Optional[int] = None,
             ff: Optional[int] = None):
    d = d or cfg.d_model
    ff = ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": annotate(dense_init(k1, (d, ff)), "d_model", "ffn"),
         "w_down": annotate(dense_init(k2, (ff, d), in_axis=0), "ffn",
                            "d_model")}
    if cfg.act in ("silu", "geglu"):  # gated (SwiGLU / GeGLU)
        p["w_gate"] = annotate(dense_init(k3, (d, ff)), "d_model", "ffn")
    return p


def apply_mlp(cfg: ModelConfig, p, x):
    dt = x.dtype
    up = x @ p["w_up"].astype(dt)
    up = shard(up, "batch", "seq", "ffn")
    if cfg.act in ("silu", "geglu"):
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        up = act(x @ p["w_gate"].astype(dt)) * up
    else:
        up = jax.nn.gelu(up)
    out = up @ p["w_down"].astype(dt)
    return shard(out, "batch", "seq", "d_model")


# -- embedding / head -------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = {"embedding": annotate(embed_init(k1, (cfg.vocab_size, cfg.d_model)),
                               "vocab", "d_model")}
    if not cfg.tie_embeddings:
        p["lm_head"] = annotate(dense_init(k2, (cfg.d_model, cfg.vocab_size)),
                                "d_model", "vocab")
    return p


def embed_tokens(cfg: ModelConfig, p, tokens):
    emb = p["embedding"]
    x = jnp.take(emb, tokens, axis=0).astype(cfg.dtype)
    if cfg.scale_embeddings:  # gemma-style sqrt(d) embedding scale
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return shard(x, "batch", "seq", "d_model")


def logits_from_hidden(cfg: ModelConfig, p, x):
    if cfg.tie_embeddings:
        w = p["embedding"].astype(x.dtype)
        logits = x @ w.T
    else:
        logits = x @ p["lm_head"].astype(x.dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return shard(logits, "batch", "seq", "vocab")


# -- RoPE ----------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               m_rope_sections: Optional[Tuple[int, int, int]] = None):
    """Rotary embedding.

    x: (B, S, H, hd). positions: (B, S) for standard RoPE, or (B, S, 3)
    for M-RoPE (qwen2-vl), where the half-dim is split into
    ``m_rope_sections`` chunks driven by the temporal/height/width
    position streams respectively.
    """
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)          # (half,)
    if m_rope_sections is not None and positions.ndim == 3:
        secs = _scaled_sections(m_rope_sections, hd // 2)
        comp = jnp.concatenate(
            [jnp.full((n,), i, jnp.int32) for i, n in enumerate(secs)])
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),                  # (B,S,3)
            comp[None, None, :].repeat(positions.shape[0], 0)
                .repeat(positions.shape[1], 1), axis=-1)    # (B,S,half)
        angles = pos * inv[None, None, :]
    else:
        angles = positions.astype(jnp.float32)[..., None] * inv  # (B,S,half)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _scaled_sections(sections: Tuple[int, int, int], half: int):
    total = sum(sections)
    scaled = [int(round(s * half / total)) for s in sections]
    scaled[-1] = half - sum(scaled[:-1])
    return scaled


def sinusoidal_embedding(seq: int, d: int, dtype=jnp.float32):
    """Whisper-encoder style fixed sinusoidal positional embedding (S, d)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(1, half - 1))
    angles = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)],
                           axis=-1).astype(dtype)


def sinusoidal_row(pos, d: int, dtype=jnp.float32):
    """Row(s) of :func:`sinusoidal_embedding` at traced position(s).

    pos: scalar -> (d,); (B,) vector (per-slot decode positions) -> (B, d).
    """
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(1, half - 1))
    angles = jnp.asarray(pos, jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)],
                           axis=-1).astype(dtype)


def default_positions(batch: int, seq: int, offset=0):
    return jnp.arange(seq, dtype=jnp.int32)[None, :] + offset \
        + jnp.zeros((batch, 1), jnp.int32)


def mrope_positions(batch: int, seq: int, patch_len: int, offset=0):
    """Stub M-RoPE position ids: a (t,h,w) grid for the leading patch
    region (square-ish grid) and shared temporal positions for text."""
    side = max(1, int(math.isqrt(max(1, patch_len))))
    t = jnp.arange(seq, dtype=jnp.int32)
    h = jnp.where(t < patch_len, (t // side) % side, t)
    w = jnp.where(t < patch_len, t % side, t)
    pos = jnp.stack([t, h, w], axis=-1)[None]  # (1, S, 3)
    pos = pos + offset
    return jnp.broadcast_to(pos, (batch, seq, 3))
