"""Mixture-of-Experts layer: sort-based dispatch, static capacity, EP-sharded.

Used by kimi-k2 (384e top-8 + 1 shared), deepseek-v3 (256e top-8 + 1 shared,
first 3 layers dense) and jamba (16e top-2, MoE every other layer).

Dispatch algorithm (TPU-native adaptation of sort-based/MegaBlocks-style
dispatch; DESIGN.md §6):

  1. tokens are grouped along the batch axis into G groups that align with
     the data shards, so routing/sorting is *local* to a shard;
  2. per group: router top-k -> (token, expert) assignments, sorted by
     expert id; rank-within-expert via searchsorted; assignments whose
     rank exceeds the static capacity C are dropped (token keeps shared-
     expert + residual path only);
  3. an inverse index ``token_for_slot (E*C,)`` gathers tokens into the
     expert buffer (G, E, C, d) — the only O(E*C*d) tensor; there is no
     (T*k, d) intermediate;
  4. expert FFNs run as one einsum with experts sharded on the "model"
     mesh axis (EP);
  5. combine is a scatter-add back to token layout weighted by the gate —
     under GSPMD this lowers to partial scatters + an all-reduce over the
     expert axis, the standard GShard combine collective.

Dispatch FLOPs are therefore ~ active FLOPs x capacity_factor, never
num_experts x dense FLOPs.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import layers
from repro.sharding.specs import annotate, shard


# -- params -------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig):
    """Router + expert bank (+ optional shared experts as one fused MLP)."""
    m = cfg.moe
    d, e, ff = cfg.d_model, m.num_experts, m.ff_dim
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": annotate(layers.dense_init(k1, (d, e)), "d_model", "experts"),
        "w_up": annotate(_expert_init(k2, (e, d, ff)), "experts", "d_model",
                         "ffn"),
        "w_gate": annotate(_expert_init(k3, (e, d, ff)), "experts", "d_model",
                           "ffn"),
        "w_down": annotate(_expert_init(k4, (e, ff, d), in_axis=1), "experts",
                           "ffn", "d_model"),
    }
    if m.num_shared_experts:
        p["shared"] = layers.init_mlp(k5, cfg, d=d,
                                      ff=m.num_shared_experts * ff)
    return p


def _expert_init(key, shape, in_axis: int = 1):
    std = 1.0 / math.sqrt(shape[in_axis])
    return jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                       jnp.float32) * std


# -- static sizing ------------------------------------------------------------

def moe_groups(cfg: ModelConfig, batch: int) -> int:
    """Number of routing groups: the largest power-of-two divisor of the
    batch that does not exceed the data-shard count (32 on the production
    mesh). Groups align with data shards so sorting stays shard-local."""
    g = math.gcd(batch, 32)
    return max(1, g)


def capacity(tokens_per_group: int, m: MoEConfig) -> int:
    c = math.ceil(tokens_per_group * m.top_k * m.capacity_factor
                  / m.num_experts)
    return max(1, min(c, tokens_per_group * m.top_k))


# -- routing -------------------------------------------------------------------

def route(cfg: ModelConfig, p, xg: jnp.ndarray
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Router probabilities and top-k choice.

    xg: (G, T, d) -> gates (G, T, k) fp32, expert ids (G, T, k) int32,
    probs (G, T, E) fp32 (for the aux loss).
    """
    m = cfg.moe
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    # deepseek/kimi renormalize the selected gate weights to sum to one
    gates = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return gates, top_i.astype(jnp.int32), probs


def aux_loss(probs: jnp.ndarray, top_i: jnp.ndarray, num_experts: int
             ) -> jnp.ndarray:
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    e = num_experts
    counts = jax.nn.one_hot(top_i, e, dtype=jnp.float32).sum((1, 2))  # (G,E)
    f = counts / jnp.maximum(counts.sum(-1, keepdims=True), 1.0)
    pbar = probs.mean(1)                                              # (G,E)
    return (e * (f * pbar).sum(-1)).mean()


# -- dispatch indices (per group, vmapped) --------------------------------------

def _dispatch_indices(top_i: jnp.ndarray, cap: int, num_experts: int):
    """Sort-based dispatch plan for one group.

    top_i: (T, k) expert ids. Returns
      token_for_slot: (E*C,) token index feeding each expert slot
                      (sentinel T when the slot is empty),
      slot_for_tk:    (T, k) slot index of each assignment
                      (sentinel E*C when dropped at capacity).
    """
    t, k = top_i.shape
    flat_e = top_i.reshape(-1)                       # (T*k,)
    flat_t = jnp.arange(t * k, dtype=jnp.int32) // k  # token of assignment
    order = jnp.argsort(flat_e, stable=True)
    sid = jnp.take(flat_e, order)
    stok = jnp.take(flat_t, order)
    first = jnp.searchsorted(sid, sid, side="left")
    rank = jnp.arange(t * k, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = rank < cap
    slot = jnp.where(keep, sid * cap + rank, num_experts * cap)

    token_for_slot = jnp.full((num_experts * cap + 1,), t, jnp.int32)
    token_for_slot = token_for_slot.at[slot].set(stok, mode="drop")
    token_for_slot = token_for_slot[:num_experts * cap]

    slot_for_flat = jnp.zeros((t * k,), jnp.int32).at[order].set(slot)
    return token_for_slot, slot_for_flat.reshape(t, k)


# -- the layer -------------------------------------------------------------------

def apply_moe(cfg: ModelConfig, p, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN. x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    dt = x.dtype
    g = moe_groups(cfg, b)
    tg = (b // g) * s
    cap = capacity(tg, m)
    e = m.num_experts

    xg = x.reshape(g, tg, d)
    xg = shard(xg, "batch", None, "d_model")
    gates, top_i, probs = route(cfg, p, xg)
    loss = aux_loss(probs, top_i, e)

    token_for_slot, slot_for_tk = jax.vmap(
        lambda ti: _dispatch_indices(ti, cap, e))(top_i)

    # dispatch: gather tokens into the expert buffer (sentinel row is zero)
    xpad = jnp.concatenate([xg, jnp.zeros((g, 1, d), dt)], axis=1)
    buf = jnp.take_along_axis(
        xpad, token_for_slot[:, :, None], axis=1)        # (G, E*C, d)
    buf = buf.reshape(g, e, cap, d)
    buf = shard(buf, "batch", "experts", None, "d_model")

    # expert FFN (EP einsum; experts sharded on "model")
    up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    gate = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt))
    h = jax.nn.silu(gate) * up
    h = shard(h, "batch", "experts", None, "ffn")
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    y = y.reshape(g, e * cap, d)

    # combine: gate-weighted scatter-add back to token layout.
    gate_for_slot = jnp.zeros((g, e * cap + 1), jnp.float32)
    gate_for_slot = jax.vmap(lambda z, sl, gt: z.at[sl.reshape(-1)].set(
        gt.reshape(-1), mode="drop"))(gate_for_slot, slot_for_tk, gates)
    y = y * gate_for_slot[:, :e * cap, None].astype(dt)

    out = jnp.zeros((g, tg + 1, d), dt)
    out = jax.vmap(lambda o, tok, yy: o.at[tok].add(yy, mode="drop"))(
        out, token_for_slot, y)
    out = out[:, :tg].reshape(b, s, d)
    out = shard(out, "batch", "seq", "d_model")

    if "shared" in p:
        out = out + layers.apply_mlp(cfg, p["shared"], x)
    return out, loss.astype(jnp.float32)


def is_moe_layer(cfg: ModelConfig, layer_idx: int) -> bool:
    """Whether layer ``layer_idx`` uses the MoE FFN (vs a dense MLP)."""
    m = cfg.moe
    if m is None:
        return False
    if layer_idx < m.first_dense_layers:
        return False
    return (layer_idx % m.every_k_layers) == m.moe_layer_offset
