from repro.models.model import (build_forward, init_params, loss_fn,
                                make_serve_fns)

__all__ = ["init_params", "build_forward", "loss_fn", "make_serve_fns"]
