"""Mamba-1 selective-SSM block (Jamba's "M" layers).

Train/prefill uses a *time-chunked* selective scan: a lax.scan over chunks
of ``cfg.ssm_chunk`` tokens carrying the (B, d_in, N) SSM state, with an
associative scan inside each chunk.  The (B, Q, d_in, N) discretized-state
tensor is the only large intermediate and is bounded by the chunk size —
this is the TPU/VMEM-minded adaptation of the CUDA selective-scan kernel
(DESIGN.md §2): blocking over time instead of a fused warp kernel.

``unroll_time_chunks=True`` (used by the roofline probe lowerings) replaces
the outer lax.scan with a Python loop so every chunk's FLOPs appear in the
HLO — scan bodies are otherwise counted once by XLA cost analysis.

Decode is the O(1) recurrence with a {conv window, ssm state} cache.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.specs import annotate, shard


def d_inner(cfg: ModelConfig) -> int:
    return cfg.mamba.expand * cfg.d_model


# -- params -------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig):
    mc = cfg.mamba
    d, din, n = cfg.d_model, d_inner(cfg), mc.d_state
    ks = jax.random.split(key, 6)
    di = layers.dense_init
    # S4-style A init: -[1..N] per channel, stored as log
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :],
                         (din, n))
    return {
        "in_proj": annotate(di(ks[0], (d, 2 * din)), "d_model", "mamba_inner"),
        "conv_w": annotate(di(ks[1], (mc.d_conv, din), in_axis=0),
                           None, "mamba_inner"),
        "conv_b": annotate(jnp.zeros((din,), jnp.float32), "mamba_inner"),
        "x_proj": annotate(di(ks[2], (din, mc.dt_rank + 2 * n)),
                           "mamba_inner", None),
        "dt_w": annotate(di(ks[3], (mc.dt_rank, din)), None, "mamba_inner"),
        "dt_b": annotate(jnp.full((din,), -4.6, jnp.float32), "mamba_inner"),
        "a_log": annotate(jnp.log(a), "mamba_inner", None),
        "d_skip": annotate(jnp.ones((din,), jnp.float32), "mamba_inner"),
        "out_proj": annotate(di(ks[4], (din, d)), "mamba_inner", "d_model"),
        # jamba stabilizing norms on dt/B/C
        "dt_norm": annotate(jnp.ones((mc.dt_rank,), jnp.float32), None),
        "bc_norm": annotate(jnp.ones((2 * n,), jnp.float32), None),
    }


def _rms(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# -- shared pre-scan compute -----------------------------------------------------

def _split_proj(cfg: ModelConfig, p, u):
    dt_r = u.dtype
    xz = u @ p["in_proj"].astype(dt_r)
    x, z = jnp.split(xz, 2, axis=-1)
    return shard(x, "batch", "seq", "mamba_inner"), z


def _ssm_inputs(cfg: ModelConfig, p, xc):
    """Per-token SSM tensors from conv output xc (B, S, din) (fp32 math).

    Returns dA (B,S,din,N) decay, dBx (B,S,din,N) input, c (B,S,N).
    """
    mc = cfg.mamba
    dt = xc.dtype
    proj = xc @ p["x_proj"].astype(dt)
    dtr, bc = proj[..., :mc.dt_rank], proj[..., mc.dt_rank:]
    dtr = _rms(dtr, p["dt_norm"])
    bc = _rms(bc, p["bc_norm"])
    b, c = jnp.split(bc, 2, axis=-1)                       # (B,S,N) each
    delta = jax.nn.softplus(dtr @ p["dt_w"].astype(dt)
                            + p["dt_b"].astype(dt))        # (B,S,din)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))           # (din,N)
    delta32 = delta.astype(jnp.float32)
    da = jnp.exp(delta32[..., None] * a[None, None])       # (B,S,din,N)
    dbx = (delta32 * xc.astype(jnp.float32))[..., None] \
        * b.astype(jnp.float32)[:, :, None, :]             # (B,S,din,N)
    return da, dbx, c.astype(jnp.float32)


def _chunk_scan(da, dbx, c, h0):
    """Selective scan over one chunk. da/dbx: (B,Q,din,N), h0: (B,din,N).
    Returns (y (B,Q,din) fp32, h_end)."""
    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, bl * ar + br

    a_cum, b_cum = jax.lax.associative_scan(combine, (da, dbx), axis=1)
    h = b_cum + a_cum * h0[:, None]                        # (B,Q,din,N)
    y = jnp.einsum("bqdn,bqn->bqd", h, c)
    return y, h[:, -1]


def causal_conv(cfg: ModelConfig, p, x, history=None):
    """Depthwise causal conv1d. x: (B,S,din). history: (B,d_conv-1,din)
    carried state for decode/chunk boundaries (zeros if None)."""
    mc = cfg.mamba
    k = mc.d_conv
    if history is None:
        history = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)
    w = p["conv_w"].astype(x.dtype)                        # (k, din)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    out = out + p["conv_b"].astype(x.dtype)
    return jax.nn.silu(out), xp[:, -(k - 1):]


# -- train / prefill -------------------------------------------------------------

def mamba_forward(cfg: ModelConfig, p, u, return_state: bool = False):
    """Full-sequence mamba block. u: (B, S, d) -> (B, S, d)
    (+ the decode cache when ``return_state``)."""
    mc = cfg.mamba
    b, s, _ = u.shape
    dt = u.dtype
    x, z = _split_proj(cfg, p, u)
    xc, conv_hist = causal_conv(cfg, p, x)

    q = min(cfg.ssm_chunk, s)
    if s % q:
        q = s   # non-chunk-aligned (odd prefill lengths): single chunk
    nc = s // q
    din, n = x.shape[-1], mc.d_state
    h0 = jnp.zeros((b, din, n), jnp.float32)

    # chunk body is checkpointed: backward recomputes the (B, Q, din, N)
    # discretized tensors from the chunk's conv output instead of saving a
    # per-chunk stack of them (the selective-scan recompute trick).
    def chunk_body(h, blk):
        da, dbx, c = _ssm_inputs(cfg, p, blk)
        y_i, h = _chunk_scan(da, dbx, c, h)
        return h, y_i

    chunk_body_ck = jax.checkpoint(chunk_body)

    if nc == 1:
        h, y = chunk_body_ck(h0, xc)
    elif cfg.unroll_time_chunks:
        ys = []
        h = h0
        for i in range(nc):
            h, y_i = chunk_body_ck(h, xc[:, i * q:(i + 1) * q])
            ys.append(y_i)
        y = jnp.concatenate(ys, axis=1)
    else:
        xcs = xc.reshape(b, nc, q, din).swapaxes(0, 1)     # (nc,B,Q,din)
        h, ys = jax.lax.scan(chunk_body_ck, h0, xcs)
        y = ys.swapaxes(0, 1).reshape(b, s, din)

    y = y + p["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = y.astype(dt) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    out = shard(out, "batch", "seq", "d_model")
    if return_state:
        # conv history is the raw (pre-activation) input window
        return out, {"conv": conv_hist.astype(jnp.bfloat16), "ssm": h}
    return out


# -- decode -----------------------------------------------------------------------

def init_mamba_cache(cfg: ModelConfig, batch: int,
                     dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    mc = cfg.mamba
    din = d_inner(cfg)
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, din), dtype),
        "ssm": jnp.zeros((batch, din, mc.d_state), jnp.float32),
    }


def mamba_cache_axes() -> Dict[str, Tuple]:
    return {"conv": ("batch", None, "mamba_inner"),
            "ssm": ("batch", "mamba_inner", None)}


def mamba_decode(cfg: ModelConfig, p, u, cache):
    """One-token step. u: (B,1,d). Returns (out (B,1,d), new_cache)."""
    dt = u.dtype
    x, z = _split_proj(cfg, p, u)
    xc, conv_hist = causal_conv(cfg, p, x, cache["conv"].astype(dt))
    da, dbx, c = _ssm_inputs(cfg, p, xc)                   # (B,1,din,N)
    h = da[:, 0] * cache["ssm"] + dbx[:, 0]                # (B,din,N)
    y = jnp.einsum("bdn,bn->bd", h, c[:, 0])[:, None]      # (B,1,din)
    y = y + p["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = y.astype(dt) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    out = shard(out, "batch", "seq", "d_model")
    return out, {"conv": conv_hist.astype(cache["conv"].dtype), "ssm": h}
