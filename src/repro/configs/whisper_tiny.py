"""whisper-tiny — Whisper tiny backbone [arXiv:2212.04356].

Assigned: 4L d_model=384 6H (MHA kv=6) d_ff=1536 vocab=51865.
Encoder-decoder; the conv audio frontend is a STUB — ``input_specs()``
provides precomputed frame embeddings (B, 1500, d).  Parametric
LayerNorm with bias, plain-GELU MLP, absolute sinusoidal positions
(no RoPE).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    norm_type="layernorm",
    act="gelu",
    pos_embed="sinusoidal",
    is_encoder_decoder=True,
    encoder_layers=4,
    enc_len=1500,
)

REDUCED = dataclasses.replace(
    CONFIG,
    num_layers=2, encoder_layers=2, d_model=48, num_heads=3,
    num_kv_heads=3, d_ff=96, vocab_size=256, enc_len=16,
    loss_chunk=0, attn_chunk=64,
)
