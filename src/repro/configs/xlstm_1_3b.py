"""xlstm-1.3b — xLSTM[7:1] 1.3B [arXiv:2405.04517].

Assigned: 48L d_model=2048 4H d_ff=0 vocab=50304.  Repeating unit of
7 mLSTM + 1 sLSTM blocks (the paper's 7:1 ratio); blocks are
self-contained (no separate FFN for mLSTM; sLSTM carries a 4/3-factor
gated FFN).  Sub-quadratic — runs the long_500k shape.
"""
import dataclasses

from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    attention="none",
    xlstm=XLSTMConfig(pattern="smmmmmmm", mlstm_proj_factor=2.0,
                      slstm_proj_factor=4.0 / 3.0, conv1d_kernel=4),
    tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG,
    num_layers=4, d_model=64, num_heads=2, num_kv_heads=2, vocab_size=256,
    xlstm=XLSTMConfig(pattern="sm", mlstm_proj_factor=2.0,
                      slstm_proj_factor=4.0 / 3.0, conv1d_kernel=4),
    loss_chunk=0, attn_chunk=64,
)
