"""Architecture registry: ``--arch <id>`` resolution + dry-run input specs.

``get_config(name)`` returns the full published config;
``get_config(name, reduced=True)`` the reduced smoke-test variant.
``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that (arch x shape) cell — weak-type-correct, shardable,
no device allocation.  Decode shapes also need cache specs, built with
``jax.eval_shape`` over ``model.init_caches`` (still allocation-free).
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (InputShape, ModelConfig, SHAPES,
                                shape_applicable)

_MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "smollm-135m": "smollm_135m",
    "qwen3-0.6b": "qwen3_0_6b",
    "olmo-1b": "olmo_1b",
    "gemma2-27b": "gemma2_27b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "whisper-tiny": "whisper_tiny",
    "xlstm-1.3b": "xlstm_1_3b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, reduced: bool = False, **overrides) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.REDUCED if reduced else mod.CONFIG
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def patch_len(cfg: ModelConfig, seq: int) -> int:
    return int(seq * cfg.patch_frac)


def input_specs(cfg: ModelConfig, shape: InputShape,
                max_len: Optional[int] = None) -> Dict[str, object]:
    """ShapeDtypeStruct batch for one (arch x shape) cell.

    train/prefill: token batch (+ frontend stubs).
    decode: one new token + cur_len; caches are produced separately by
    ``cache_specs`` (they are carried state, not part of the batch).
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        batch = {"tokens": _sds((b, 1), jnp.int32)}
        if cfg.is_encoder_decoder:
            pass  # cross-attention K/V live in the cache
        return batch

    batch = {"tokens": _sds((b, s), jnp.int32)}
    if shape.kind == "train":
        batch["targets"] = _sds((b, s), jnp.int32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = _sds((b, patch_len(cfg, s), cfg.d_model),
                                     jnp.bfloat16)
    if cfg.is_encoder_decoder:
        batch["frame_embeds"] = _sds((b, cfg.enc_len, cfg.d_model),
                                     jnp.bfloat16)
    return batch


def cache_specs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStruct tree for decode caches of size ``shape.seq_len``."""
    from repro.models import model as model_mod
    return jax.eval_shape(
        lambda: model_mod.init_caches(cfg, shape.global_batch,
                                      shape.seq_len))


__all__ = ["ARCH_NAMES", "get_config", "input_specs", "cache_specs",
           "SHAPES", "shape_applicable", "ModelConfig", "patch_len"]
