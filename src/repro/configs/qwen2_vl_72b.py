"""qwen2-vl-72b — Qwen2-VL 72B backbone [arXiv:2409.12191].

Assigned: 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
M-RoPE with (t,h,w) position streams; the vision frontend is a STUB —
``input_specs()`` provides precomputed patch embeddings for the leading
``patch_frac`` of the sequence.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    m_rope=True,
    m_rope_sections=(16, 24, 24),
    rope_theta=1000000.0,
    patch_frac=0.125,
)

REDUCED = dataclasses.replace(
    CONFIG,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256,
    loss_chunk=0, attn_chunk=64,
)
