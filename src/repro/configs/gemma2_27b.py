"""gemma2-27b — Gemma 2 27B [arXiv:2408.00118].

Assigned: 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
Alternating local(4096-window)/global attention, attn-logit softcap 50,
final-logit softcap 30, pre+post block norms, GeGLU, sqrt(d) embedding
scale, query_pre_attn_scalar 144, head_dim 128, tied embeddings.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_pre_attn_scalar=144.0,
    sliding_window=4096,
    layer_pattern="LG",
    post_block_norm=True,
    scale_embeddings=True,
    act="geglu",
    rope_theta=10000.0,
    tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG,
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, sliding_window=32,
    query_pre_attn_scalar=16.0,
    loss_chunk=0, attn_chunk=64,
)
