"""jamba-v0.1-52b — Jamba v0.1 [arXiv:2403.19887].

Assigned: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2.  Repeating 8-layer unit with attention at offset 4
(1:7 attention:mamba), MoE replacing the MLP on every other layer
(offset 1).  Hybrid — runs the long_500k shape.
"""
import dataclasses

from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    hybrid_pattern="MMMMAMMM",
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
    moe=MoEConfig(num_experts=16, top_k=2, ff_dim=14336,
                  capacity_factor=1.25, every_k_layers=2,
                  moe_layer_offset=1, dense_ff_dim=14336),
    pos_embed="none",   # jamba uses no positional embedding at all
)

REDUCED = dataclasses.replace(
    CONFIG,
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256,
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2, dt_rank=16),
    moe=MoEConfig(num_experts=4, top_k=2, ff_dim=128,
                  capacity_factor=1.25, every_k_layers=2,
                  moe_layer_offset=1, dense_ff_dim=128),
    loss_chunk=0, attn_chunk=64, ssm_chunk=16,
)
