"""deepseek-v3-671b — DeepSeek-V3 [arXiv:2412.19437].

Assigned: 61L d_model=7168 128H d_ff=2048 vocab=129280, MoE 256e top-8,
MLA, 1 shared + 256 routed, MTP.  First 3 layers dense (ff 18432).
"""
import dataclasses

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,                      # leading dense layers' ffn
    vocab_size=129280,
    head_dim=128,
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    rope_theta=10000.0,
    moe=MoEConfig(num_experts=256, top_k=8, ff_dim=2048,
                  num_shared_experts=1, capacity_factor=1.25,
                  first_dense_layers=3, dense_ff_dim=18432),
    mtp=True,
    mtp_loss_weight=0.3,
    param_dtype="bfloat16",
    optimizer="adafactor",
)

REDUCED = dataclasses.replace(
    CONFIG,
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, ff_dim=32, num_shared_experts=1,
                  capacity_factor=1.25, first_dense_layers=1,
                  dense_ff_dim=128),
    loss_chunk=0, attn_chunk=64, ssm_chunk=16,
)
