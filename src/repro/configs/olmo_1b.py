"""olmo-1b — OLMo 1B [arXiv:2402.00838].

Assigned: 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304.
Non-parametric LayerNorm (no scale/bias); SwiGLU; tied embeddings.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm_type="layernorm_nonparam",
    rope_theta=10000.0,
    tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256,
    loss_chunk=0, attn_chunk=64,
)
