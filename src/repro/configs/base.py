"""Model/run configuration dataclasses.

One :class:`ModelConfig` per assigned architecture lives in
``repro/configs/<arch>.py``; the registry in ``repro/configs/__init__.py``
resolves ``--arch <id>``.  Every config also provides a ``reduced()``
variant (same family, tiny dims) used by the CPU smoke tests — the full
configs are only ever lowered via the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (token-dropping, sort-based dispatch)."""

    num_experts: int
    top_k: int
    ff_dim: int                      # per-expert intermediate size
    num_shared_experts: int = 0      # deepseek-style always-on experts
    capacity_factor: float = 1.25
    first_dense_layers: int = 0      # leading layers that stay dense
    dense_ff_dim: int = 0            # ffn size of those dense layers
    every_k_layers: int = 1          # jamba: MoE on every k-th layer only
    moe_layer_offset: int = 0        # jamba: first MoE layer index
    router_aux_loss: float = 0.001   # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V3)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 selective SSM block (Jamba)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 256


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack settings."""

    # position pattern within a repeating unit: "m" = mLSTM, "s" = sLSTM
    pattern: str = "ms"
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv1d_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A single architecture. Field values come from the assignment table."""

    name: str
    family: str                      # dense|moe|vlm|audio|ssm|hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # -- attention variants ------------------------------------------------
    attention: str = "gqa"           # gqa | mla | none (pure ssm)
    qk_norm: bool = False            # qwen3
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    query_pre_attn_scalar: Optional[float] = None  # gemma2-27b: 144
    final_softcap: Optional[float] = None   # gemma2: 30.0
    sliding_window: Optional[int] = None    # gemma2 local layers: 4096
    layer_pattern: Optional[str] = None     # e.g. "LG" local/global repeat
    rope_theta: float = 10000.0
    # positional scheme: "rope" | "sinusoidal" (whisper) | "none" (jamba)
    pos_embed: str = "rope"
    m_rope: bool = False             # qwen2-vl 3-section rope
    m_rope_sections: Tuple[int, int, int] = (16, 24, 24)
    mla: Optional[MLAConfig] = None

    # -- norms / mlp ---------------------------------------------------------
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm_nonparam
    # keep the residual-stream norm bf16-in/bf16-out (stats still fp32):
    # stops XLA hoisting the fp32 upcast across the TP all-reduce, halving
    # activation-AR bytes (§Perf finding on kimi train_4k)
    norm_bf16_io: bool = False
    act: str = "silu"                # silu (SwiGLU mlp) | gelu (plain mlp)
    post_block_norm: bool = False    # gemma2 post-norms
    tie_embeddings: bool = False
    scale_embeddings: bool = False   # gemma-style sqrt(d) embedding scale

    # -- families beyond dense decoder ---------------------------------------
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid (jamba): repeating unit of layer kinds, "M"=mamba, "A"=attention
    hybrid_pattern: Optional[str] = None
    # enc-dec (whisper): decoder uses num_layers; encoder adds these
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    enc_len: int = 1500              # encoder output length (whisper 30 s)
    # deepseek multi-token prediction head (1 extra layer + head)
    mtp: bool = False
    mtp_loss_weight: float = 0.3

    # -- numerics --------------------------------------------------------------
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"     # master weights
    remat: str = "full"              # full | dots | none
    # optimizer: adamw everywhere Adam's fp32 moments fit; the ~1T-class
    # archs use adafactor + bf16 params (DESIGN.md §6 memory budget)
    optimizer: str = "adamw"

    # -- implementation knobs (perf-iteration surface) -------------------------
    attn_impl: str = "auto"          # auto | dense | chunked | pallas
    attn_chunk: int = 1024           # q-block for chunked attention
    # serve decode attention: "flash" = kernels/decode_attention fused
    # length-aware path (Pallas on TPU, masked-lax sweep elsewhere),
    # "dense" = masked full-cache attend; "auto" picks flash on TPU.
    decode_attn_impl: str = "auto"   # auto | dense | flash
    # serve admission: chunked prefill interleaved with decode — the
    # prompt is processed prefill_chunk tokens at a time through
    # kernels/prefill_attention (one compiled shape, no power-of-two
    # bucket family) so admissions stop stalling the live decode batch.
    # 0 = blocking bucketed whole-prompt prefill (the measured
    # baseline).  Env PMT_PREFILL_CHUNK and ServeEngine(prefill_chunk=)
    # override; see serve/engine.py.
    prefill_chunk: int = 32
    # paged KV serving: tokens per physical cache page (block).  Used by
    # ServeEngine(kv_layout="paged") for the page pool, the radix prefix
    # cache edge length, and the kernels' scalar-prefetch page tables.
    kv_page_size: int = 16
    # quantized KV cache: "int8" / "fp8_e4m3" stores attention K/V (and
    # the MLA latent) cache rows as low-bit codes plus per-row float32
    # absmax scales (kernels/quant.py); the decode/prefill attention
    # kernels dequantize blocks in-register.  None = store at the
    # serving cache dtype.  Surface knobs: ServeEngine(cache_dtype=
    # "int8") / launch/serve --cache-dtype.  State (mamba/xlstm) and
    # cross-attention caches are never quantized.
    kv_quant: Optional[str] = None
    ssm_chunk: int = 128             # time-chunk for mamba associative scan
    mla_absorb: bool = True          # DeepSeek absorbed-weights decode path
    kernels: str = "reference"       # reference | pallas
    scan_layers: bool = True         # lax.scan over layer units (False: loop)
    unroll_time_chunks: bool = False  # Python-unroll inner time chunks
    causal_kv_trim: bool = False     # skip fully-masked KV blocks (unrolled)
    loss_chunk: int = 2048           # seq-chunk for the xent head (0 = whole)
    max_decode_len: int = 0          # serve: cache size (0 = from shape)

    # -- frontend stubs ---------------------------------------------------------
    # vlm: fraction of the sequence that arrives as precomputed patch embeds
    patch_frac: float = 0.125
    # audio: encoder input is precomputed frame embeddings (B, enc_len, d)

    @property
    def use_rope(self) -> bool:
        return self.pos_embed == "rope"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(1, self.num_kv_heads) != 0:
            raise ValueError(f"{self.name}: num_heads {self.num_heads} not "
                             f"divisible by kv heads {self.num_kv_heads}")
        if self.family == "hybrid" and not self.hybrid_pattern:
            raise ValueError("hybrid family requires hybrid_pattern")
        if self.kv_quant is not None and self.kv_quant not in (
                "int8", "fp8_e4m3"):
            raise ValueError(f"{self.name}: unknown kv_quant "
                             f"{self.kv_quant!r} (int8 | fp8_e4m3)")

    # -- derived sizes --------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        return _param_count(self, active_only=True)


def _attn_params(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    if cfg.attention == "mla":
        m = cfg.mla
        qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
        n = d * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * qk_hd
        n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
        n += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim
                                               + m.v_head_dim)
        n += cfg.num_heads * m.v_head_dim * d
        return n
    q = d * cfg.num_heads * hd
    kv = 2 * d * cfg.num_kv_heads * hd
    o = cfg.num_heads * hd * d
    return q + kv + o


def _mlp_params(d: int, ff: int, act: str) -> int:
    return d * ff * (3 if act in ("silu", "geglu") else 2)


def _mamba_params(cfg: ModelConfig) -> int:
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    n = cfg.d_model * 2 * d_in                      # in_proj
    n += d_in * mc.d_conv                            # conv1d
    n += d_in * (mc.dt_rank + 2 * mc.d_state)        # x_proj
    n += mc.dt_rank * d_in + d_in                    # dt_proj
    n += d_in * mc.d_state + d_in                    # A_log, D
    n += d_in * cfg.d_model                          # out_proj
    return n


def _xlstm_params(cfg: ModelConfig, kind: str) -> int:
    xc = cfg.xlstm
    d = cfg.d_model
    if kind == "m":
        d_in = int(xc.mlstm_proj_factor * d)
        n = d * 2 * d_in                 # up proj (x, gate)
        n += 3 * d_in * d_in             # q,k,v
        n += 2 * d_in * 2                # i,f gate projections (per head dim folded)
        n += d_in * d                    # down proj
        return n
    d_in = int(xc.slstm_proj_factor * d)
    n = 4 * d * d                        # i,f,z,o recurrent-input projections
    n += 4 * d * d                       # recurrent weights (block-diag approx)
    n += d * d_in + d_in * d             # ffn up/down
    return n


def mc_conv(xc: XLSTMConfig) -> int:
    return xc.conv1d_kernel


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d, v = cfg.d_model, cfg.vocab_size
    total = v * d                                    # embedding
    if not cfg.tie_embeddings:
        total += v * d                               # lm head

    def layer_kind(i: int) -> str:
        if cfg.family == "ssm":
            pat = cfg.xlstm.pattern
            return pat[i % len(pat)]
        if cfg.family == "hybrid":
            return cfg.hybrid_pattern[i % len(cfg.hybrid_pattern)]
        return "A"

    def ffn_params(i: int) -> int:
        if cfg.moe is None:
            return _mlp_params(d, cfg.d_ff, cfg.act)
        m = cfg.moe
        if i < m.first_dense_layers or (i % m.every_k_layers) != 0:
            ff = m.dense_ff_dim or cfg.d_ff
            return _mlp_params(d, ff, cfg.act)
        router = d * m.num_experts
        experts = m.num_experts * _mlp_params(d, m.ff_dim, cfg.act)
        shared = m.num_shared_experts * _mlp_params(d, m.ff_dim, cfg.act)
        if active_only:
            experts = m.top_k * _mlp_params(d, m.ff_dim, cfg.act)
        return router + experts + shared

    n_layers = cfg.num_layers
    for i in range(n_layers):
        kind = layer_kind(i)
        if kind in ("A", "a"):
            total += _attn_params(cfg)
            total += ffn_params(i)
        elif kind == "M":
            total += _mamba_params(cfg)
            total += ffn_params(i)
        elif kind in ("m", "s"):
            total += _xlstm_params(cfg, kind)
        # norms are negligible but counted coarsely:
        total += 2 * d
    if cfg.is_encoder_decoder:
        for _ in range(cfg.encoder_layers):
            total += _attn_params(cfg) + _mlp_params(d, cfg.d_ff, cfg.act)
            # cross attention in decoder counted once per decoder layer:
        total += cfg.num_layers * _attn_params(cfg)
    if cfg.mtp:
        total += _attn_params(cfg) + _mlp_params(d, cfg.d_ff or 4 * d, cfg.act)
    return int(total)


# ---------------------------------------------------------------------------
# Input shapes (assigned; one set shared by all LM-family archs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# long_500k requires sub-quadratic sequence handling; only SSM/hybrid run it
# (DESIGN.md §5). Everything else runs the first three shapes.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.family in LONG_CONTEXT_FAMILIES
    return True
