"""smollm-135m — HuggingFaceTB/SmolLM-135M (llama-arch small).

Assigned: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
Tied embeddings; this is the ~100M end-to-end training example arch.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    rope_theta=10000.0,
    tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG,
    num_layers=2, d_model=48, num_heads=3, num_kv_heads=1, d_ff=128,
    vocab_size=256,
    loss_chunk=0, attn_chunk=64,
)
