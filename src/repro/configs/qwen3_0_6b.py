"""qwen3-0.6b — Qwen3 family [hf:Qwen/Qwen3-8B].

Assigned: 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
qk-norm on per-head q/k; explicit head_dim 128; tied embeddings.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16,
    loss_chunk=0, attn_chunk=64,
)
