"""kimi-k2-1t-a32b — Kimi K2, trillion-param MoE [arXiv:2501.kimi2].

Assigned: 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840,
MoE 384e top-8.  Per the K2 report: 1 leading dense layer (ff 18432),
1 shared expert, per-expert ff 2048.  head_dim 128 (explicit, like the
DeepSeek-V3 lineage it derives from).
"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=18432,                      # the leading dense layer's ffn
    vocab_size=163840,
    head_dim=128,
    rope_theta=50000.0,
    moe=MoEConfig(num_experts=384, top_k=8, ff_dim=2048,
                  num_shared_experts=1, capacity_factor=1.25,
                  first_dense_layers=1, dense_ff_dim=18432),
    param_dtype="bfloat16",
    optimizer="adafactor",
)

REDUCED = dataclasses.replace(
    CONFIG,
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16,
    moe=MoEConfig(num_experts=8, top_k=2, ff_dim=32, num_shared_experts=1,
                  capacity_factor=1.25, first_dense_layers=1,
                  dense_ff_dim=128),
    loss_chunk=0, attn_chunk=64, ssm_chunk=16,
)
