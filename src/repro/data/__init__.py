from repro.data.pipeline import (DataConfig, SyntheticLMDataset,
                                 host_batch_iterator, make_global_batch)

__all__ = ["DataConfig", "SyntheticLMDataset", "host_batch_iterator",
           "make_global_batch"]
