"""Deterministic synthetic LM data pipeline, sharded per host.

Production framing: every host independently and deterministically
generates the *same* global batch schedule and slices out its own rows
(``host_batch_iterator``), so there is no data server to fail and restart
is exact — ``skip_to(step)`` fast-forwards without generating intermediate
batches (counter-based generation, not a stateful RNG stream), which is
what makes checkpoint-restart O(1) in data terms.

The token stream is a reproducible Zipf-ish mixture with enough structure
for the loss to actually drop during the example training runs:
each sequence is a Markov chain whose transition row is seeded by
(seed, step, row) — the model can learn bigram statistics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic-structure knobs
    n_states: int = 64           # markov states driving the stream
    pad_fraction: float = 0.0    # tail padding (tests loss masking)


class SyntheticLMDataset:
    """Counter-based deterministic batch generator."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        # one shared transition structure per run (small, regenerated
        # identically on every host)
        self._state_tokens = root.integers(
            0, cfg.vocab_size, size=(cfg.n_states, 8), dtype=np.int64)
        self._transition = root.integers(
            0, cfg.n_states, size=(cfg.n_states, 4), dtype=np.int64)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """The full global batch for ``step`` (same on every host)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, 1, step))
        b, s = cfg.global_batch, cfg.seq_len
        state = rng.integers(0, cfg.n_states, size=(b,))
        toks = np.empty((b, s + 1), dtype=np.int32)
        choices = rng.integers(0, 4, size=(b, s + 1))
        emit = rng.integers(0, 8, size=(b, s + 1))
        for t in range(s + 1):
            toks[:, t] = self._state_tokens[state, emit[:, t]]
            state = self._transition[state, choices[:, t]]
        tokens = toks[:, :-1]
        targets = toks[:, 1:].astype(np.int32)
        if cfg.pad_fraction > 0:
            pad = int(s * cfg.pad_fraction)
            if pad:
                targets[:, -pad:] = -1
        return {"tokens": tokens, "targets": targets}


def make_global_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    return SyntheticLMDataset(cfg).batch(step)


def host_batch_iterator(cfg: DataConfig, host_id: int, num_hosts: int,
                        start_step: int = 0,
                        extra_specs: Optional[Dict[str, tuple]] = None
                        ) -> Iterator[Dict[str, np.ndarray]]:
    """Yield this host's slice of each global batch, forever.

    ``extra_specs``: {name: (per-batch shape tail, dtype)} for frontend
    stubs (patch/frame embeddings), generated deterministically too.
    """
    if cfg.global_batch % num_hosts:
        raise ValueError("global batch must divide evenly across hosts")
    rows = cfg.global_batch // num_hosts
    lo, hi = host_id * rows, (host_id + 1) * rows
    ds = SyntheticLMDataset(cfg)
    step = start_step
    while True:
        gb = ds.batch(step)
        out = {k: v[lo:hi] for k, v in gb.items()}
        if extra_specs:
            rng = np.random.default_rng((cfg.seed, 2, step, host_id))
            for name, (tail, dtype) in extra_specs.items():
                out[name] = rng.standard_normal(
                    (rows, *tail)).astype(dtype) * 0.02
        yield out
        step += 1
