"""Host-side paged-KV bookkeeping: page allocator + radix prefix cache.

The paged serve path replaces per-slot contiguous caches (B, max_len, ...)
with one physical page pool (P, page_size, ...) per cache leaf.  Every
leaf shares a single page-id space: page ``p`` of a request is the same
index into every layer's pool arrays, so ONE host-side allocator and ONE
per-request page table row (logical block -> physical page) cover the
whole model.  Nothing here touches device memory — these classes hand
out integer page ids; the device-side indirection lives in the paged
kernels (``kernels/*/ops.py``) whose BlockSpec index maps read the page
table from scalar-prefetch SMEM.

``PagePool``    free-list allocator with per-page refcounts.  Page 0 is
                reserved scratch: it is never allocated, every masked /
                padded kernel write is routed there, and no page table
                may map real content to it.
``RadixPrefixCache``
                page-stride radix tree over token ids: each edge spans
                exactly one page (``page_size`` tokens), so a node *is*
                a cached physical page and a tree walk is a longest
                cached-prefix match at page granularity.  Matching maps
                the cached pages copy-free into a new request's page
                table (taking pool refs); inserting at retire adopts the
                request's full pages; eviction releases LRU leaves back
                toward the free list.  A page referenced by both the
                tree and live requests survives eviction until the last
                request retires — the pool refcount is the single
                source of truth for page lifetime.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

SCRATCH_PAGE = 0


class PagePool:
    """Free-list page allocator with refcounts over ``num_pages`` pages.

    Page ``SCRATCH_PAGE`` (0) is reserved and never handed out; usable
    capacity is ``num_pages - 1``.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("PagePool needs >= 2 pages (page 0 is scratch)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        # LIFO free list: recently-freed pages are re-used first.
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._refs: List[int] = [0] * num_pages
        self._refs[SCRATCH_PAGE] = 1        # pinned forever

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    @property
    def total_pages(self) -> int:
        """Usable (non-scratch) capacity."""
        return self.num_pages - 1

    def refcount(self, page: int) -> int:
        return self._refs[page]

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages at refcount 1, or None if short (all or
        nothing — a partial grab would deadlock concurrent admissions)."""
        if n < 0:
            raise ValueError("alloc of negative page count")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def ref(self, pages: Sequence[int]) -> None:
        """Add one reference to each page (copy-free sharing)."""
        for p in pages:
            if self._refs[p] <= 0:
                raise ValueError(f"ref of free page {p}")
            self._refs[p] += 1

    def release(self, pages: Sequence[int]) -> int:
        """Drop one reference per page; pages hitting zero return to the
        free list.  Returns how many pages were actually freed."""
        freed = 0
        for p in pages:
            if p == SCRATCH_PAGE or self._refs[p] <= 0:
                raise ValueError(f"release of page {p} (refs "
                                 f"{self._refs[p]})")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)
                freed += 1
        return freed


class _Node:
    __slots__ = ("children", "parent", "key", "page", "last_used")

    def __init__(self, parent=None, key=None, page=None):
        self.children: Dict[tuple, "_Node"] = {}
        self.parent = parent
        self.key = key
        self.page = page
        self.last_used = 0


class RadixPrefixCache:
    """Page-stride radix tree mapping token prefixes to cached pages.

    Every edge is exactly ``pool.page_size`` token ids; the child node
    owns one pool reference on its physical page.  ``match`` walks the
    tree and refs the matched pages for the caller (the new request);
    ``insert`` adopts a retired request's full pages; ``evict_lru``
    drops leaf nodes in least-recently-used order, releasing the tree's
    reference (the page returns to the free list only once no live
    request still holds it).
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.root = _Node()
        self._clock = 0
        self.lookups = 0
        self.hits = 0
        self.evictions = 0
        self.node_count = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, tokens: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest cached page-aligned prefix of ``tokens``.

        Returns ``(matched_tokens, pages)``; the caller receives one
        pool reference per matched page and owns releasing them.
        """
        ps = self.pool.page_size
        now = self._tick()
        self.lookups += 1
        node, pages = self.root, []
        for i in range(0, len(tokens) - ps + 1, ps):
            child = node.children.get(tuple(tokens[i:i + ps]))
            if child is None:
                break
            child.last_used = now
            pages.append(child.page)
            node = child
        if pages:
            self.hits += 1
            self.pool.ref(pages)
        return len(pages) * ps, pages

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Adopt the full-page prefix of a retired request.

        ``pages[i]`` backs ``tokens[i*ps:(i+1)*ps]``.  Pages whose
        prefix is already cached are skipped (the existing page wins —
        same token content); new nodes take a pool reference.  Returns
        the number of pages adopted.
        """
        ps = self.pool.page_size
        now = self._tick()
        node, adopted = self.root, 0
        n = min(len(tokens) // ps, len(pages))
        for i in range(n):
            key = tuple(tokens[i * ps:(i + 1) * ps])
            child = node.children.get(key)
            if child is None:
                child = _Node(parent=node, key=key, page=pages[i])
                node.children[key] = child
                self.pool.ref([pages[i]])
                self.node_count += 1
                adopted += 1
            child.last_used = now
            node = child
        return adopted

    def _leaves(self) -> List[_Node]:
        out, stack = [], list(self.root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def evict_lru(self, count: int = 1) -> int:
        """Evict up to ``count`` least-recently-used leaf nodes,
        releasing the tree's page references.  Returns nodes evicted."""
        done = 0
        while done < count:
            leaves = self._leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_used)
            del victim.parent.children[victim.key]
            self.pool.release([victim.page])
            self.node_count -= 1
            self.evictions += 1
            done += 1
        return done

    def evict_for(self, pages_needed: int) -> int:
        """Evict LRU leaves until the pool could satisfy an allocation
        of ``pages_needed`` pages (or the tree is empty).  Returns nodes
        evicted.  Evicting a leaf whose page is still shared with a
        live request releases only the tree's ref, so the loop keeps
        going until the free list itself is long enough."""
        done = 0
        while self.pool.free_pages < pages_needed:
            if not self.evict_lru(1):
                break
            done += 1
        return done

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0
