"""Serving: prefill/decode step functions + a continuous-batching engine.

``make_prefill_fn`` / ``make_decode_fn`` / ``make_prefill_chunk_fn`` are
the pjit-able pure steps the dry-run lowers (``serve_step`` for the
decode_* shapes = one new token against a seq_len cache).

``ServeEngine`` implements **sequence-level continuous batching**
(``mode="continuous"``, the default): every batch slot carries its own
position counter, one decode step advances all live slots at their own
offsets (per-row KV-cache scatter via ``kernels/cache_update`` — Pallas
on TPU, ``vmap``'d dynamic-update-slice elsewhere), and a slot that
finishes its request is refilled from the queue on the *next* step
instead of idling until the longest request in a synchronized wave
drains.

Admission is **chunked prefill interleaved with decode** (the
``prefill_chunk`` knob, default ``cfg.prefill_chunk``): a request's
prompt is processed ``prefill_chunk`` tokens at a time through
``ServeFns.prefill_chunk`` — each chunk attends the request's already-
written cache prefix plus its own causal keys via the
``kernels/prefill_attention`` flash kernel and scatters its KV slice in
place — and the scheduler drains the chunk queue *alongside* decode,
one chunk per decode step.  Two levers fall out:

  * prefill compiles **once**, at one (1, chunk) shape, for any prompt
    length — no power-of-two bucket family, and pad waste shrinks from
    up-to-2x (bucketing) to the final partial chunk;
  * a whole-prompt admission no longer stalls the live decode batch:
    the head-of-line decode stall per admission drops from a full
    prompt's prefill to one chunk (see benchmarks/bench_prefill.py;
    per-generate stall samples are kept in ``stall_events``).

``prefill_chunk=0`` keeps the previous *blocking bucketed* admission —
one whole-prompt prefill per request at a power-of-two prompt bucket —
as the measured baseline (and the fallback for encoder-decoder archs,
whose cross-attention KV needs one whole-encoder pass).  Note the
semantic difference: bucketed prefill left-pads the prompt (pad tokens
sit *in context* at the sequence start and shift RoPE positions), while
chunked prefill processes the exact prompt from position 0 — for
prompts that are not already bucket-sized the two can generate
different tokens, chunked being the faithful one.  ``mode="wave"``
keeps the old synchronized-wave decode as the coarser baseline (see
benchmarks/bench_serve.py).

Sampling: ``ServeEngine(greedy=False, temperature=..., seed=...)``
threads a per-step PRNG key (``fold_in`` of a seeded base key and a
monotone step counter) into ``make_decode_fn``'s categorical draw —
and into the prefill fns for the first token — instead of always
decoding greedily.

PMT integration — per-request, per-phase energy attribution: each
admitted request opens a flat session span (``serve/req<N>``,
``nested=False`` so interleaved lifetimes don't fight the nesting
stack) closed right after the fenced decode step that produced its
last token, plus two *phase* child scopes tiling the same window:
``serve/req<N>/prefill`` (admission -> last prefill chunk fenced,
token count = prompt length) and ``serve/req<N>/decode`` (first ->
last decode token, token count = generated tokens).  All spans resolve
in vectorized batches against the shared background ring sampler, so
the engine reports true per-request J/token — split by phase — next to
the aggregate region (``serve/batch<N>`` / ``serve/wave<N>``) whose
token count is the *actually generated* total.  Passing a
``PowerMonitor`` routes the same spans through
``measure_step``/``measure_request(..., phase=...)`` accounting
instead (``per_request_energy`` then carries the J split).

Known semantic caveat: MoE layers route with cross-batch capacity
limits, so under continuous batching a request's tokens can be dropped
differently depending on its slot neighbours; dense/GQA/MLA/SSM archs
decode each row independently (slot refill leaks no state — see
tests/test_serve_continuous.py for the byte-parity gate).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import math
import os
import time
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_mod
from repro.serve.paging import PagePool, RadixPrefixCache


def _pick(logits, greedy: bool, temperature: float, key):
    if greedy or key is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def make_prefill_fn(cfg: ModelConfig, max_len: int, greedy: bool = True,
                    temperature: float = 1.0, cache_dtype=jnp.bfloat16):
    prefill = model_mod.make_serve_fns(cfg, cache_dtype=cache_dtype).prefill

    def prefill_fn(params, batch, key=None):
        logits, caches = prefill(params, batch, max_len)
        return _pick(logits, greedy, temperature, key), caches

    return prefill_fn


def make_prefill_chunk_fn(cfg: ModelConfig, greedy: bool = True,
                          temperature: float = 1.0):
    """One prefill chunk: resume the cache at ``offset``, return the
    token sampled from the ``last_idx`` position's logits (only the
    final chunk's is used) plus the updated caches."""
    prefill_chunk = model_mod.make_serve_fns(cfg).prefill_chunk

    def chunk_fn(params, caches, tokens, offset, last_idx, key=None):
        logits, caches = prefill_chunk(params, caches, tokens, offset,
                                       last_idx)
        return _pick(logits, greedy, temperature, key), caches

    return chunk_fn


def make_decode_fn(cfg: ModelConfig, greedy: bool = True,
                   temperature: float = 1.0):
    decode = model_mod.make_serve_fns(cfg).decode

    def decode_fn(params, caches, tokens, cur_len, key=None):
        logits, caches = decode(params, caches, tokens, cur_len)
        return _pick(logits, greedy, temperature, key)[:, None], caches

    return decode_fn


def make_paged_decode_fn(cfg: ModelConfig, greedy: bool = True,
                         temperature: float = 1.0):
    decode = model_mod.make_paged_serve_fns(cfg).decode

    def decode_fn(params, caches, tokens, cur_len, page_table, key=None):
        logits, caches = decode(params, caches, tokens, cur_len, page_table)
        return _pick(logits, greedy, temperature, key)[:, None], caches

    return decode_fn


def make_paged_prefill_chunk_fn(cfg: ModelConfig, greedy: bool = True,
                                temperature: float = 1.0):
    """Batched paged prefill chunk: every pending admission's next chunk
    rides in one (B, chunk) dispatch, each row at its own offset with
    its own fill (``last_idx[j] == -1`` marks passenger rows).  Returns
    per-row sampled tokens (B,) — only rows finishing their prefill this
    step use theirs."""
    pf = model_mod.make_paged_serve_fns(cfg).prefill_chunk

    def chunk_fn(params, caches, tokens, offset, last_idx, page_table,
                 key=None):
        logits, caches = pf(params, caches, tokens, offset, last_idx,
                            page_table)
        return _pick(logits, greedy, temperature, key), caches

    return chunk_fn


def prompt_bucket(plen: int, min_bucket: int = 8) -> int:
    """Pad a prompt length to its power-of-two bucket.

    Bounds the *blocking* prefill jit cache: every prompt length in
    (2^(k-1), 2^k] shares one compiled prefill, so at most
    log2(max_len) prefill variants exist no matter how many distinct
    lengths arrive.  Used by the wave baseline and the
    ``prefill_chunk=0`` blocking admission; chunked admission compiles
    one shape and needs no buckets.

    ``min_bucket`` must itself be a power of two — a non-power floor
    would silently produce non-power buckets (``b <<= 1`` preserves
    whatever factor it starts with) and fracture the jit cache.
    """
    if plen < 1:
        raise ValueError("empty prompt")
    if min_bucket < 1 or (min_bucket & (min_bucket - 1)):
        raise ValueError(
            f"min_bucket must be a power of two >= 1, got {min_bucket}")
    b = min_bucket
    while b < plen:
        b <<= 1
    return b


def stall_p95(events) -> float:
    """p95 of the engine's ``stall_events`` samples (nearest-rank on the
    inclusive index) — shared by the serve launcher and
    benchmarks/bench_prefill.py so the two report the same number."""
    if not events:
        return 0.0
    xs = sorted(events)
    return float(xs[min(len(xs) - 1, int(round(0.95 * (len(xs) - 1))))])


def resolve_prefill_chunk(cfg: ModelConfig,
                          prefill_chunk: Optional[int]) -> int:
    """Engine arg beats the ``PMT_PREFILL_CHUNK`` env var beats
    ``cfg.prefill_chunk``; encoder-decoder archs force 0 (blocking)."""
    if prefill_chunk is None:
        env = os.environ.get("PMT_PREFILL_CHUNK")
        prefill_chunk = int(env) if env else cfg.prefill_chunk
        if cfg.is_encoder_decoder:
            prefill_chunk = 0
    if prefill_chunk < 0:
        raise ValueError(f"prefill_chunk must be >= 0, got {prefill_chunk}")
    if prefill_chunk and cfg.is_encoder_decoder:
        raise ValueError(
            "chunked prefill is not available for encoder-decoder archs "
            "(cross-attention KV needs one whole-encoder pass); use "
            "prefill_chunk=0")
    return prefill_chunk


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    id: Optional[int] = None        # assigned by the engine at admission
    tenant: Optional[str] = None    # quota accounting key (governor)
    # Wall-clock budget in seconds, measured from generate() submission
    # (continuous mode only).  A request past its deadline — waiting,
    # mid-prefill, or mid-decode — retires with finish_reason "timeout",
    # keeps whatever tokens it generated, closes its spans cleanly, and
    # frees its slot.  None = no deadline.
    deadline_s: Optional[float] = None
    # "length" (ran to max_new_tokens) or "timeout"; None until served.
    finish_reason: Optional[str] = None


@dataclasses.dataclass
class _Prefill:
    """An admission mid-chunked-prefill: its slot is reserved, its
    batch-1 cache row is being built chunk by chunk.  The open
    serve/req<N>/prefill span lives in the engine loop's per-slot
    ``pf_ctxs`` (closed on completion or by the cleanup ``finally``)."""

    req: Request
    slot: int
    caches: Any                     # batch-1 cache tree under construction
    toks: np.ndarray                # (1, padded) right-padded prompt
    plen: int
    offset: int = 0


@dataclasses.dataclass
class _PagedPrefill:
    """An admission mid-chunked-prefill on the *paged* path: its slot
    and pages are reserved; chunks write straight into the shared pools
    through the slot's page-table row (no batch-1 side cache, no insert
    step).  ``offset`` starts at ``matched_tokens`` when the radix
    prefix cache mapped cached pages in — prefill resumes from the
    match point."""

    req: Request
    slot: int
    toks: np.ndarray                # (plen + chunk,) right-zero-padded
    plen: int
    offset: int
    matched_tokens: int = 0


class ServeEngine:
    """Continuous-batching decode over fixed slots (wave mode as baseline).

    Args:
      cfg, params: model config + parameter tree.
      batch_size: number of decode slots.
      max_len: KV-cache capacity per slot.  Chunked admission needs
        ``ceil(plen / chunk) * chunk <= max_len`` and
        ``plen + max_new_tokens <= max_len + 1``; blocking/wave
        admission needs ``prompt_bucket(plen) + max_new_tokens
        <= max_len + 1``.
      monitor: a ``PowerMonitor`` — aggregate regions go through its
        non-blocking ``measure_step``, per-request and per-phase spans
        through ``measure_request(..., phase=...)`` (J/token and the
        prefill/decode J split per request via
        ``monitor.per_request_energy()``).
      session: a ``pmt.Session`` — aggregate region ``serve/batch<N>``
        (or ``serve/wave<N>``) plus flat ``serve/req<N>`` /
        ``serve/req<N>/prefill`` / ``serve/req<N>/decode`` spans per
        request, all resolved asynchronously off the shared ring
        sampler.  Monitor wins when both are passed.
      mode: "continuous" (default) or "wave" (synchronized baseline).
      min_prompt_bucket: smallest prompt bucket (power of two; blocking
        and wave admission only).
      cache_impl: per-row scatter impl forwarded to
        ``kernels/cache_update`` ("auto" picks Pallas on TPU).
      decode_attn_impl: overrides ``cfg.decode_attn_impl`` for this
        engine — "flash" routes decode attention through the
        length-aware ``kernels/decode_attention`` path, "dense" keeps
        the masked full-cache attend, "auto" picks flash on TPU.
      prefill_chunk: chunk size for interleaved chunked prefill; 0 =
        blocking bucketed admission (the measured baseline); None
        (default) resolves ``PMT_PREFILL_CHUNK`` then
        ``cfg.prefill_chunk``.
      governor: a ``serve.governor.PowerGovernor`` consulted by the
        continuous scheduler at admission (gate + tenant-priority pick),
        chunk drain (0..max chunks per decode step), and before each
        decode dispatch (duty-cycle pause) — holds the engine under the
        governor's watts cap / tenant quotas.  With a cap set, decode
        runs one step per loop so the governor sees every step;
        ``cap_watts=None`` keeps the bursty device-side decode runs.
        Ignored in wave mode (the synchronized baseline has no
        per-step scheduling points to govern).
      kv_layout: "contiguous" (default) keeps per-slot (B, max_len, ...)
        caches; "paged" serves from one physical page pool per cache
        leaf with per-slot page tables — pages are allocated at
        admission (after a radix prefix-cache match maps any cached
        prompt prefix in copy-free) and recycled at retirement, so the
        cache-memory budget is the *pool*, decoupled from slots x
        max_len.  Requires continuous mode, chunked prefill, and an
        all-attention arch (``model.supports_paged``).
      kv_page_size: tokens per page (default ``cfg.kv_page_size``).
      kv_pool_pages: usable pool capacity in pages (default
        ``batch_size * ceil(max_len / page_size)`` — parity with the
        contiguous footprint; smaller pools oversubscribe slots and
        admissions wait for pages).
      prefix_cache: keep retired requests' full prompt pages in a radix
        tree for copy-free prefix reuse (paged layout only).
      greedy, temperature, seed: decoding policy.  ``greedy=False``
        threads ``fold_in(PRNGKey(seed), step)`` into every decode
        step's categorical draw (and the prefill first-token pick);
        the step counter is monotone across ``generate()`` calls.

    ``compile_counts`` tracks retraces — continuous-mode decode
    compiles exactly once, chunked prefill exactly once (one chunk
    shape), blocking prefill once per prompt bucket.
    ``stall_events`` holds, for the most recent ``generate()``, the
    seconds decode sat blocked behind each fenced prefill dispatch
    (one whole prompt when blocking, one chunk when chunked) while at
    least one request was mid-decode — the head-of-line stall the
    chunked scheduler exists to shrink (p95 reported by
    benchmarks/bench_prefill.py).
    """

    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 max_len: int, monitor=None, session=None,
                 mode: str = "continuous", min_prompt_bucket: int = 8,
                 cache_impl: str = "auto",
                 decode_attn_impl: Optional[str] = None,
                 prefill_chunk: Optional[int] = None,
                 governor=None,
                 kv_layout: str = "contiguous",
                 kv_page_size: Optional[int] = None,
                 kv_pool_pages: Optional[int] = None,
                 prefix_cache: bool = True,
                 greedy: bool = True, temperature: float = 1.0,
                 seed: int = 0, cache_dtype=jnp.bfloat16):
        if mode not in ("continuous", "wave"):
            raise ValueError(f"unknown serve mode {mode!r}")
        if kv_layout not in ("contiguous", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if decode_attn_impl is not None:
            cfg = dataclasses.replace(cfg,
                                      decode_attn_impl=decode_attn_impl)
        if not greedy and temperature <= 0.0:
            raise ValueError("sampling needs temperature > 0")
        # ``cache_dtype`` accepts a jnp storage dtype, its name, or a
        # quantized-KV mode string ("int8" / "fp8_e4m3"): the quant
        # modes flip ``cfg.kv_quant`` so every serve fn built below
        # traces the quantized cache tree (code leaves + per-row f32
        # scales; the attention kernels dequantize in-register).
        if isinstance(cache_dtype, str):
            if cache_dtype in ("int8", "fp8_e4m3"):
                cfg = dataclasses.replace(cfg, kv_quant=cache_dtype)
                cache_dtype = jnp.bfloat16      # unused by quant leaves
            else:
                named = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                         "float16": jnp.float16}
                if cache_dtype not in named:
                    raise ValueError(
                        f"unknown cache_dtype {cache_dtype!r}; expected a "
                        f"dtype, one of {sorted(named)}, or a KV-quant "
                        f"mode ('int8', 'fp8_e4m3')")
                cache_dtype = named[cache_dtype]
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.monitor = monitor
        self.session = session
        self.mode = mode
        self.min_prompt_bucket = min_prompt_bucket
        self.cache_impl = cache_impl
        self.prefill_chunk = resolve_prefill_chunk(cfg, prefill_chunk)
        if self.prefill_chunk > max_len:
            if prefill_chunk is not None:
                raise ValueError(f"prefill_chunk {self.prefill_chunk} "
                                 f"exceeds max_len {max_len}")
            # config/env default larger than this engine's cache: clamp
            # (one whole-cache chunk) rather than refuse to serve.
            self.prefill_chunk = max_len
        self.governor = governor
        self.greedy = greedy
        self.temperature = temperature
        # Scheduler gauges — plain attribute reads, safe from any thread
        # (e.g. a load-coupled DummySensor watts_fn or a telemetry stats
        # provider sampling engine state mid-run).
        self.live_slots = 0             # decoding + mid-prefill slots
        self.queue_depth = 0            # admitted-nothing-yet backlog
        self.pending_prefill_chunks = 0
        self._key_base = jax.random.PRNGKey(seed)
        self._step_idx = 0          # monotone sampling-step counter
        self._batch_count = 0       # aggregate regions (waves or batches)
        self._request_count = 0
        self.stall_events: List[float] = []
        self._timeouts = 0          # requests retired past their deadline
        # rid -> tenant for every admitted request (telemetry's
        # /requests?tenant= filter reads this via attach_engine).
        self.request_tenants: Dict[int, str] = {}
        self.compile_counts: Dict[str, int] = {"prefill": 0, "decode": 0,
                                               "prefill_chunk": 0}
        self.cache_dtype = cache_dtype
        sample_kw = dict(greedy=greedy, temperature=temperature)
        self._prefill = jax.jit(self._counted(
            "prefill", make_prefill_fn(cfg, max_len, cache_dtype=cache_dtype,
                                       **sample_kw)))
        self._decode = jax.jit(self._counted(
            "decode", make_decode_fn(cfg, **sample_kw)))
        if self.prefill_chunk:
            # Donate the row cache: each chunk overwrites its slice in
            # place instead of copying the whole tree per chunk.
            self._prefill_chunk_fn = jax.jit(
                self._counted("prefill_chunk",
                              make_prefill_chunk_fn(cfg, **sample_kw)),
                donate_argnums=1)
        self._insert = self._make_insert()

        # -- paged KV cache (block pools + page tables + prefix reuse) --
        self.kv_layout = kv_layout
        self.kv_page_size = int(kv_page_size if kv_page_size is not None
                                else cfg.kv_page_size)
        self.prefix_hit_tokens = 0          # prompt tokens served off pages
        self.saved_prefill_joules = 0.0     # priced at the learned J/token
        self._prefill_jpt: Optional[float] = None   # EWMA J per prefill tok
        self.pool_wait_events = 0           # admissions deferred on pages
        self._pool_short = False            # mid-wait episode flag
        self._bytes_per_token: Optional[float] = None   # stats() memo
        self._pool: Optional[PagePool] = None
        self._radix: Optional[RadixPrefixCache] = None
        if kv_layout == "paged":
            if mode != "continuous":
                raise ValueError("paged KV requires continuous mode")
            if not self.prefill_chunk:
                raise ValueError("paged KV requires chunked prefill "
                                 "(prefill_chunk > 0)")
            if not model_mod.supports_paged(cfg):
                raise ValueError(
                    f"{cfg.name}: paged KV needs an all-attention arch "
                    "(state and encoder-decoder archs keep the contiguous "
                    "layout)")
            ps = self.kv_page_size
            if ps < 1:
                raise ValueError(f"kv_page_size must be >= 1, got {ps}")
            self._pages_per_slot = math.ceil(max_len / ps)
            usable = (int(kv_pool_pages) if kv_pool_pages is not None
                      else batch_size * self._pages_per_slot)
            if usable < self._pages_per_slot:
                raise ValueError(
                    f"kv_pool_pages {usable} cannot hold even one slot "
                    f"({self._pages_per_slot} pages of {ps})")
            # +1: page 0 is the reserved scratch page
            self._pool = PagePool(usable + 1, ps)
            if prefix_cache:
                self._radix = RadixPrefixCache(self._pool)
            self._paged_caches = model_mod.init_paged_caches(
                cfg, usable + 1, ps, dtype=cache_dtype)
            self._page_table = np.zeros(
                (batch_size, self._pages_per_slot), np.int32)
            self._slot_pages: List[List[int]] = \
                [[] for _ in range(batch_size)]
            self._paged_decode = jax.jit(
                self._counted("decode",
                              make_paged_decode_fn(cfg, **sample_kw)),
                donate_argnums=1)
            self._paged_prefill_chunk_fn = jax.jit(
                self._counted("prefill_chunk",
                              make_paged_prefill_chunk_fn(cfg, **sample_kw)),
                donate_argnums=1)

    def _counted(self, name: str, fn):
        counts = self.compile_counts

        def wrapper(*args, **kwargs):
            counts[name] += 1       # runs at trace time == once per compile
            return fn(*args, **kwargs)

        return wrapper

    def _next_key(self):
        """Per-step PRNG key (None when greedy — the jitted fns then
        trace a single keyless signature)."""
        if self.greedy:
            return None
        key = jax.random.fold_in(self._key_base, self._step_idx)
        self._step_idx += 1
        return key

    # -- cache row insertion ------------------------------------------------
    def _make_insert(self):
        """Jitted ``insert(caches, row, j)`` scattering a single-request
        prefill cache (batch 1) into batch row ``j`` of the live caches.

        Cache leaves put the batch axis at different positions (stacked
        units lead with a "layers" axis), so the per-leaf batch-axis
        index comes from ``cache_logical_axes``.
        """
        axes_tree = model_mod.cache_logical_axes(self.cfg)
        is_axes = lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x)
        batch_axes = [ax.index("batch") for ax in
                      jax.tree.leaves(axes_tree, is_leaf=is_axes)]

        def insert(caches, row, j):
            leaves, treedef = jax.tree.flatten(caches)
            row_leaves = jax.tree.leaves(row)
            out = []
            for c, r, ax in zip(leaves, row_leaves, batch_axes):
                starts = [0] * c.ndim
                starts[ax] = j
                out.append(jax.lax.dynamic_update_slice(
                    c, r.astype(c.dtype), tuple(starts)))
            return jax.tree.unflatten(treedef, out)

        # Donate the live caches: admission overwrites one row in place
        # instead of copying the whole KV tree per admitted request (the
        # caller always rebinds `caches = insert(caches, ...)`).
        return jax.jit(insert, donate_argnums=0)

    # -- measurement contexts ----------------------------------------------
    def _measure_ctx(self, agg_id: int, tokens: int):
        # Aggregate region per generate() call (continuous) or per wave.
        # Both paths are non-blocking: exit enqueues a span and returns.
        # Monitor keeps precedence so callers passing both still get its
        # J/token accounting.
        if self.monitor is not None:
            return self.monitor.measure_step(agg_id, tokens=tokens,
                                             blocking=False)
        if self.session is not None:
            label = "wave" if self.mode == "wave" else "batch"
            return self.session.region(f"serve/{label}{agg_id}",
                                       tokens=tokens)
        return contextlib.nullcontext()

    def _request_ctx(self, rid: int, tokens: int,
                     phase: Optional[str] = None):
        if self.monitor is not None:
            return self.monitor.measure_request(rid, tokens=tokens,
                                                blocking=False, phase=phase)
        if self.session is not None:
            label = f"serve/req{rid}" + (f"/{phase}" if phase else "")
            return self.session.region(label, tokens=tokens, nested=False)
        return contextlib.nullcontext()

    # -- public API ----------------------------------------------------------
    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve ``requests``; returns them in input order, ``out`` filled."""
        chunk = self.prefill_chunk if self.mode == "continuous" else 0
        for r in requests:
            if r.max_new_tokens < 1:
                raise ValueError("max_new_tokens must be >= 1")
            if r.deadline_s is not None:
                if r.deadline_s <= 0:
                    raise ValueError(
                        f"deadline_s must be > 0, got {r.deadline_s}")
                if self.mode == "wave":
                    raise ValueError(
                        "deadline_s requires continuous mode (waves have "
                        "no per-request retirement point)")
            r.finish_reason = None
            plen = len(r.prompt)
            if chunk:
                padded = math.ceil(plen / chunk) * chunk
                if padded > self.max_len \
                        or plen + r.max_new_tokens > self.max_len + 1:
                    raise ValueError(
                        f"request needs {max(padded, plen + r.max_new_tokens - 1)} "
                        f"cache slots (chunk-padded prompt / prompt + "
                        f"max_new_tokens) but max_len is {self.max_len}")
            else:
                need = prompt_bucket(plen, self.min_prompt_bucket) \
                    + r.max_new_tokens
                if need > self.max_len + 1:
                    raise ValueError(
                        f"request needs {need} cache slots (bucketed prompt "
                        f"+ max_new_tokens) but max_len is {self.max_len}")
        self.stall_events = []
        if self.governor is not None and self.mode == "continuous":
            self.governor.begin(self)
        if self.mode == "wave":
            done: List[Request] = []
            for i in range(0, len(requests), self.batch):
                wave = requests[i:i + self.batch]
                done.extend(self._run_wave(wave))
            return done
        if self.kv_layout == "paged":
            return self._run_paged(requests)
        return self._run_continuous(requests)

    def stats(self) -> Dict[str, Any]:
        """Scheduler counters snapshot — what the telemetry ``/stats``
        endpoint and the launcher's end-of-run report surface."""
        s: Dict[str, Any] = {
            "mode": self.mode,
            "kv_layout": self.kv_layout,
            "batch_slots": self.batch,
            "requests_admitted": self._request_count,
            "live_slots": self.live_slots,
            "queue_depth": self.queue_depth,
            "pending_prefill_chunks": self.pending_prefill_chunks,
            "stall_events": len(self.stall_events),
            "stall_p95_s": stall_p95(self.stall_events),
            "requests_timed_out": self._timeouts,
            "compile_counts": dict(self.compile_counts),
        }
        cache_s: Dict[str, Any] = {
            "cache_dtype": (self.cfg.kv_quant
                            if self.cfg.kv_quant is not None
                            else np.dtype(self.cache_dtype).name),
            "bytes_per_token": self.cache_bytes_per_token(),
        }
        if self._pool is not None:
            cache_s.update(
                page_size=self._pool.page_size,
                pages_total=self._pool.total_pages,
                pages_free=self._pool.free_pages,
                pages_used=self._pool.used_pages,
                pool_wait_events=self.pool_wait_events,
                prefix_cache=self._radix is not None,
                prefix_hit_tokens=self.prefix_hit_tokens,
                saved_prefill_joules=self.saved_prefill_joules)
            if self._radix is not None:
                cache_s.update(
                    prefix_lookups=self._radix.lookups,
                    prefix_hits=self._radix.hits,
                    prefix_hit_rate=self._radix.hit_rate,
                    prefix_evictions=self._radix.evictions,
                    prefix_nodes=self._radix.node_count)
        s["kv_cache"] = cache_s
        if self.governor is not None:
            s["governor"] = self.governor.stats()
        return s

    def cache_bytes_per_token(self) -> float:
        """KV-cache bytes per cached token position, all leaves summed —
        the footprint gauge quantized caches exist to shrink (a quant
        mode stores 1-byte codes plus amortized f32 scales instead of
        2-byte bf16 values).  Contiguous: abstract-eval of the cache
        tree over batch x max_len positions.  Paged: live pool leaves
        over pool pages x page_size positions."""
        if self._bytes_per_token is None:
            if self._pool is not None:
                total = sum(l.nbytes
                            for l in jax.tree.leaves(self._paged_caches))
                slots = self._pool.total_pages * self._pool.page_size
            else:
                shapes = jax.eval_shape(
                    lambda: model_mod.init_caches(
                        self.cfg, self.batch, self.max_len,
                        dtype=self.cache_dtype))
                total = sum(math.prod(l.shape) * l.dtype.itemsize
                            for l in jax.tree.leaves(shapes))
                slots = self.batch * self.max_len
            self._bytes_per_token = total / max(1, slots)
        return self._bytes_per_token

    def on_record(self, rec) -> None:
        """Recorder subscriber (wired by ``PowerRecorder.attach_engine``):
        learns joules-per-prefill-token from resolved
        ``serve/req<N>/prefill`` spans — the price of the prefill work a
        prefix-cache hit avoids.  ``saved_prefill_joules`` accrues at
        admission time from this EWMA."""
        path = getattr(rec, "path", "")
        if not (path.startswith("serve/req") and path.endswith("/prefill")):
            return
        tokens = getattr(rec, "tokens", None)
        joules = getattr(rec, "joules", None)
        if not tokens or joules is None or joules <= 0.0:
            return
        jpt = joules / tokens
        self._prefill_jpt = jpt if self._prefill_jpt is None \
            else 0.8 * self._prefill_jpt + 0.2 * jpt

    # -- continuous batching --------------------------------------------------
    def _admit(self, r: Request) -> Request:
        r.id = self._request_count
        self._request_count += 1
        r.out = []
        if r.tenant is not None:
            self.request_tenants[r.id] = r.tenant
        return r

    def _prefill_request(self, r: Request) -> Tuple[np.ndarray, Any, int]:
        """Blocking whole-prompt prefill at the prompt's bucket size
        (the ``prefill_chunk=0`` baseline).

        Returns (first generated token (1,) np.int32, cache row tree
        with batch size 1, next position == bucket size).  Blocking on
        the token fences prefill compute inside the request's span.
        """
        plen = len(r.prompt)
        bucket = prompt_bucket(plen, self.min_prompt_bucket)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, bucket - plen:] = r.prompt          # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.is_encoder_decoder:
            batch["frame_embeds"] = jnp.zeros(
                (1, self.cfg.enc_len, self.cfg.d_model), jnp.bfloat16)
        first, row = self._prefill(self.params, batch, self._next_key())
        return np.asarray(first), row, bucket

    def _start_chunked_prefill(self, r: Request, j: int) -> _Prefill:
        plen = len(r.prompt)
        chunk = self.prefill_chunk
        padded = math.ceil(plen / chunk) * chunk
        toks = np.zeros((1, padded), np.int32)
        toks[0, :plen] = r.prompt                   # right-pad final chunk
        caches = model_mod.init_caches(self.cfg, 1, self.max_len,
                                       dtype=self.cache_dtype)
        return _Prefill(req=r, slot=j, caches=caches, toks=toks, plen=plen)

    def _step_chunked_prefill(self, st: _Prefill, decode_live: bool
                              ) -> Optional[np.ndarray]:
        """Run one chunk; returns the first generated token (1,) when
        this was the final chunk, else None.  Fenced (the chunk's token
        read blocks), so the prefill phase span and the stall sample
        both cover real device work."""
        chunk = self.prefill_chunk
        t0 = time.perf_counter()
        last_idx = min(st.plen - 1 - st.offset, chunk - 1)
        tok, st.caches = self._prefill_chunk_fn(
            self.params, st.caches,
            jnp.asarray(st.toks[:, st.offset:st.offset + chunk]),
            jnp.asarray(st.offset, jnp.int32),
            jnp.asarray(last_idx, jnp.int32), self._next_key())
        tok = np.asarray(tok)                       # fence the chunk
        if decode_live:
            self.stall_events.append(time.perf_counter() - t0)
        st.offset += chunk
        return tok if st.offset >= st.toks.shape[1] else None

    def _run_continuous(self, requests: List[Request]) -> List[Request]:
        b = self.batch
        chunk = self.prefill_chunk
        gov = self.governor
        # Admission order is FIFO without a governor; with one, an
        # over-quota tenant's requests yield to in-quota tenants (but
        # are never skipped outright — see the tenant pick below).
        waiting = list(requests)
        caches = model_mod.init_caches(self.cfg, b, self.max_len,
                                       dtype=self.cache_dtype)
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        active: List[Optional[Request]] = [None] * b
        remaining = [0] * b
        req_ctxs: List[Any] = [None] * b
        pf_ctxs: List[Any] = [None] * b
        dec_ctxs: List[Any] = [None] * b
        prefills: Deque[_Prefill] = collections.deque()
        reserved = [False] * b                   # slot held by a prefill
        # Deadlines anchor at submission; keyed by object identity since
        # waiting requests have no engine id yet.
        deadlines = {id(r): time.monotonic() + r.deadline_s
                     for r in requests if r.deadline_s is not None}
        total_tokens = sum(r.max_new_tokens for r in requests)
        agg_id = self._batch_count
        self._batch_count += 1

        def open_ctx(rid, tokens_, phase=None):
            ctx = self._request_ctx(rid, tokens=tokens_, phase=phase)
            ctx.__enter__()
            return ctx

        def close_ctx(ctx):
            if ctx is not None:
                ctx.__exit__(None, None, None)

        def activate(j, r, row, first, next_pos):
            """Request r finished prefill: its row is live in slot j.

            The decode phase span opens before the row insert so the
            prefill/decode spans tile the request span — the insert
            dispatch belongs to serving this request's decode."""
            dec_ctxs[j] = open_ctx(r.id, r.max_new_tokens, phase="decode")
            caches_j = self._insert(caches, row, j)
            tokens[j, 0] = first[0]
            pos[j] = next_pos
            remaining[j] = r.max_new_tokens - 1
            active[j] = r
            r.out.append(int(first[0]))
            if remaining[j] == 0:
                retire(j)
            return caches_j

        def retire(j: int, reason: str = "length") -> None:
            # The caller already fenced this slot's last token (np reads
            # block), so closing the spans here attributes correctly.
            active[j].finish_reason = reason
            close_ctx(dec_ctxs[j])
            dec_ctxs[j] = None
            close_ctx(req_ctxs[j])
            req_ctxs[j] = None
            active[j] = None

        def sweep_deadlines() -> None:
            """Retire every request past its deadline — waiting (drop
            from the queue), mid-prefill (free the reserved slot, close
            the open prefill/request spans), or mid-decode (retire the
            slot, keeping the tokens generated so far)."""
            if not deadlines:
                return
            now = time.monotonic()

            def expired(r: Request) -> bool:
                dl = deadlines.get(id(r))
                return dl is not None and now > dl

            if any(expired(r) for r in waiting):
                kept = []
                for r in waiting:
                    if expired(r):
                        r.finish_reason = "timeout"
                        self._timeouts += 1
                    else:
                        kept.append(r)
                waiting[:] = kept
            for st in [st for st in prefills if expired(st.req)]:
                prefills.remove(st)
                reserved[st.slot] = False
                close_ctx(pf_ctxs[st.slot])
                pf_ctxs[st.slot] = None
                close_ctx(req_ctxs[st.slot])
                req_ctxs[st.slot] = None
                st.req.finish_reason = "timeout"
                self._timeouts += 1
            for j in range(b):
                if active[j] is not None and expired(active[j]):
                    retire(j, reason="timeout")
                    self._timeouts += 1

        def update_gauges():
            self.queue_depth = len(waiting)
            self.live_slots = sum(1 for a in active if a is not None) \
                + sum(reserved)
            self.pending_prefill_chunks = sum(
                max(0, st.toks.shape[1] - st.offset) // chunk
                for st in prefills) if chunk else 0

        with self._measure_ctx(agg_id, tokens=total_tokens):
            try:
                while waiting or prefills \
                        or any(r is not None for r in active):
                    sweep_deadlines()
                    update_gauges()
                    # slot-granular admission: every free slot refills
                    # now (blocking) or enters the chunk queue (chunked)
                    # instead of waiting for the batch to drain.  The
                    # governor gates the rate and picks *which* waiting
                    # request (in-quota tenants first); when it blocks
                    # admission while the engine is completely idle, the
                    # engine admits anyway — power can only be idle draw,
                    # and liveness beats an unholdable cap.
                    for j in range(b):
                        if active[j] is not None or reserved[j] \
                                or not waiting:
                            continue
                        k = 0
                        if gov is not None:
                            if not gov.admission_allowed():
                                if any(a is not None for a in active) \
                                        or prefills:
                                    break
                                gov.note_forced_admit()
                            else:
                                k = next(
                                    (i for i, w in enumerate(waiting)
                                     if gov.tenant_allowed(w.tenant)), 0)
                        r = self._admit(waiting.pop(k))
                        if gov is not None:
                            gov.note_admitted(r)
                        req_ctxs[j] = open_ctx(r.id, r.max_new_tokens)
                        pf_ctxs[j] = open_ctx(r.id, len(r.prompt),
                                              phase="prefill")
                        if chunk:
                            reserved[j] = True
                            prefills.append(
                                self._start_chunked_prefill(r, j))
                            continue
                        # blocking bucketed baseline: whole prompt now
                        t0 = time.perf_counter()
                        first, row, bucket = self._prefill_request(r)
                        if any(a is not None for a in active):
                            self.stall_events.append(
                                time.perf_counter() - t0)
                        close_ctx(pf_ctxs[j])
                        pf_ctxs[j] = None
                        caches = activate(j, r, row, first, bucket)
                    update_gauges()

                    # prefill chunks interleave with each decode step —
                    # one per step by default, 0 while the governor is
                    # shedding load (forced back to 1 when nothing is
                    # decoding: pausing prefill then would idle the
                    # engine, not save power), several when the governor
                    # sees ample headroom.  With no live decode rows the
                    # chunk queue drains back-to-back.
                    if prefills:
                        decode_live = any(a is not None for a in active)
                        budget = 1
                        if gov is not None:
                            budget = gov.prefill_chunk_budget(decode_live)
                            if budget < 1 and not decode_live:
                                budget = 1
                                gov.note_forced_chunk()
                        for _ in range(budget):
                            if not prefills:
                                break
                            st = prefills[0]
                            first = self._step_chunked_prefill(
                                st, decode_live)
                            if first is not None:
                                prefills.popleft()
                                reserved[st.slot] = False
                                close_ctx(pf_ctxs[st.slot])
                                pf_ctxs[st.slot] = None
                                caches = activate(st.slot, st.req,
                                                  st.caches, first,
                                                  st.plen)
                        update_gauges()

                    live = [j for j in range(b) if active[j] is not None]
                    if not live:
                        continue          # everything retired at prefill
                    if gov is not None:
                        # Last-resort lever: duty-cycle decode while
                        # power exceeds the hard-over threshold.
                        gov.maybe_pause_decode()
                    # Retirement is deterministic (exactly max_new_tokens
                    # per request), so with no admission work pending
                    # decode runs device-side until the *next* slot
                    # retires — one host sync per retirement event, not
                    # per token.  While prefill chunks are pending,
                    # decode advances one step per chunk (the
                    # interleave).  Inactive rows decode garbage into
                    # their own (dead, about-to-be-overwritten) cache
                    # rows only.
                    # Under an active power cap decode advances one step
                    # per loop so every step passes the governor's
                    # pause/admission checkpoints.
                    governed = gov is not None and gov.cap_watts is not None
                    steps = 1 if (prefills or governed) \
                        else min(remaining[j] for j in live)
                    if steps > 1 and deadlines \
                            and any(id(active[j]) in deadlines
                                    for j in live):
                        # A deadline'd request must pass the sweep
                        # checkpoint between bursts: bound the
                        # device-side run so it overshoots by at most a
                        # few steps, not the whole request.
                        steps = min(steps, 8)
                    tok_dev = jnp.asarray(tokens)
                    pos_dev = jnp.asarray(pos)
                    outs = []
                    for _ in range(steps):
                        tok_dev, caches = self._decode(
                            self.params, caches, tok_dev, pos_dev,
                            self._next_key())
                        outs.append(tok_dev)
                        pos_dev = pos_dev + 1
                    gen = np.asarray(jnp.concatenate(outs, axis=1))
                    # np read blocked: every token in the chunk is
                    # computed, so spans closed below are correctly
                    # fenced.
                    for j in live:
                        r = active[j]
                        r.out.extend(gen[j].tolist())
                        tokens[j, 0] = gen[j, -1]
                        pos[j] += steps
                        remaining[j] -= steps
                        if remaining[j] == 0:
                            retire(j)
            finally:
                # An exception mid-loop (a prefill OOM — whole-prompt or
                # chunk — or an interrupt) must not leak open
                # request/phase spans: they hold ring-sampler pins on
                # the shared session for its whole lifetime.
                prefills.clear()
                waiting.clear()
                update_gauges()
                for j in range(b):
                    close_ctx(pf_ctxs[j])
                    pf_ctxs[j] = None
                    close_ctx(dec_ctxs[j])
                    dec_ctxs[j] = None
                    close_ctx(req_ctxs[j])
                    req_ctxs[j] = None
        return requests

    # -- paged continuous batching --------------------------------------------
    def _admit_paged(self, r: Request, j: int) -> Optional[_PagedPrefill]:
        """Reserve slot ``j``'s pages for request ``r``: radix-match the
        prompt (mapping cached prefix pages in copy-free), then allocate
        the remaining ``ceil((plen + max_new - 1) / page_size)`` fresh
        pages up front — decode never waits for a page mid-request.
        Returns None when the pool cannot cover it right now (the caller
        leaves the request waiting; retirements free pages)."""
        pool, radix = self._pool, self._radix
        ps = self.kv_page_size
        plen = len(r.prompt)
        pages_needed = math.ceil((plen + r.max_new_tokens - 1) / ps)
        matched: List[int] = []
        if radix is not None:
            _, mpages = radix.match(r.prompt)
            # cap the match one token short of the prompt: the final
            # chunk must re-run >= 1 real token for first-token logits
            use = min(len(mpages), (plen - 1) // ps)
            if use < len(mpages):
                pool.release(mpages[use:])
            matched = mpages[:use]
        fresh = pool.alloc(pages_needed - len(matched))
        if fresh is None and radix is not None:
            radix.evict_for(pages_needed - len(matched))
            fresh = pool.alloc(pages_needed - len(matched))
        if fresh is None:
            if matched:
                pool.release(matched)
            return None
        slot_pages = matched + fresh
        self._slot_pages[j] = slot_pages
        self._page_table[j, :] = 0
        self._page_table[j, :len(slot_pages)] = slot_pages
        mt = len(matched) * ps
        self.prefix_hit_tokens += mt
        if mt and self._prefill_jpt is not None:
            self.saved_prefill_joules += mt * self._prefill_jpt
        toks = np.zeros((plen + self.prefill_chunk,), np.int32)
        toks[:plen] = r.prompt
        return _PagedPrefill(req=r, slot=j, toks=toks, plen=plen,
                             offset=mt, matched_tokens=mt)

    def _release_slot_pages(self, j: int) -> None:
        if self._slot_pages[j]:
            self._pool.release(self._slot_pages[j])
            self._slot_pages[j] = []
        self._page_table[j, :] = 0

    def _run_paged(self, requests: List[Request]) -> List[Request]:
        """Continuous batching over the paged pools.

        Differences from ``_run_continuous``: admission reserves pages
        instead of a cache row (and may *wait* on the pool, not just on
        slots); prefill chunks write straight into the shared pools
        through the slot's page-table row, with every pending
        admission's chunk batched into ONE (B, chunk) dispatch; decode
        sees a masked page table (mid-prefill / dead rows route to the
        scratch page); retirement adopts the request's full pages into
        the radix prefix tree before releasing its references.
        """
        b = self.batch
        chunk = self.prefill_chunk
        gov = self.governor
        pool, radix = self._pool, self._radix
        ps = self.kv_page_size
        waiting = list(requests)
        caches = self._paged_caches     # pools persist across generate()s
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        active: List[Optional[Request]] = [None] * b
        remaining = [0] * b
        req_ctxs: List[Any] = [None] * b
        pf_ctxs: List[Any] = [None] * b
        dec_ctxs: List[Any] = [None] * b
        prefills: Deque[_PagedPrefill] = collections.deque()
        reserved = [False] * b
        deadlines = {id(r): time.monotonic() + r.deadline_s
                     for r in requests if r.deadline_s is not None}
        total_tokens = sum(r.max_new_tokens for r in requests)
        agg_id = self._batch_count
        self._batch_count += 1

        def open_ctx(rid, tokens_, phase=None):
            ctx = self._request_ctx(rid, tokens=tokens_, phase=phase)
            ctx.__enter__()
            return ctx

        def close_ctx(ctx):
            if ctx is not None:
                ctx.__exit__(None, None, None)

        def activate(j, r, first, next_pos):
            dec_ctxs[j] = open_ctx(r.id, r.max_new_tokens, phase="decode")
            tokens[j, 0] = int(first)
            pos[j] = next_pos
            remaining[j] = r.max_new_tokens - 1
            active[j] = r
            r.out.append(int(first))
            if remaining[j] == 0:
                retire(j)

        def retire(j: int, reason: str = "length") -> None:
            r = active[j]
            r.finish_reason = reason
            if radix is not None:
                # Adopt the full pages actually written — prompt plus
                # every generated token that was fed back (the last
                # sampled token never lands in the cache) — into the
                # prefix tree BEFORE releasing this request's refs, so
                # adopted pages never transit the free list.  Existing
                # nodes win on duplicate content; timeouts contribute
                # their written prefix like any other retirement.
                written = list(r.prompt) + r.out[:-1]
                n_full = len(written) // ps
                if n_full:
                    radix.insert(written[:n_full * ps],
                                 self._slot_pages[j][:n_full])
            self._release_slot_pages(j)
            close_ctx(dec_ctxs[j])
            dec_ctxs[j] = None
            close_ctx(req_ctxs[j])
            req_ctxs[j] = None
            active[j] = None

        def sweep_deadlines() -> None:
            if not deadlines:
                return
            now = time.monotonic()

            def expired(r: Request) -> bool:
                dl = deadlines.get(id(r))
                return dl is not None and now > dl

            if any(expired(r) for r in waiting):
                kept = []
                for r in waiting:
                    if expired(r):
                        r.finish_reason = "timeout"
                        self._timeouts += 1
                    else:
                        kept.append(r)
                waiting[:] = kept
            for st in [st for st in prefills if expired(st.req)]:
                prefills.remove(st)
                reserved[st.slot] = False
                self._release_slot_pages(st.slot)
                close_ctx(pf_ctxs[st.slot])
                pf_ctxs[st.slot] = None
                close_ctx(req_ctxs[st.slot])
                req_ctxs[st.slot] = None
                st.req.finish_reason = "timeout"
                self._timeouts += 1
            for j in range(b):
                if active[j] is not None and expired(active[j]):
                    retire(j, reason="timeout")
                    self._timeouts += 1

        def update_gauges():
            self.queue_depth = len(waiting)
            self.live_slots = sum(1 for a in active if a is not None) \
                + sum(reserved)
            self.pending_prefill_chunks = sum(
                math.ceil((st.plen - st.offset) / chunk) for st in prefills)

        with self._measure_ctx(agg_id, tokens=total_tokens):
            try:
                while waiting or prefills \
                        or any(r is not None for r in active):
                    sweep_deadlines()
                    update_gauges()
                    # Admission: governor gate (now fed the pool's free
                    # fraction as a pressure signal) + tenant pick, then
                    # page reservation.  A pool too drained to cover the
                    # next request simply defers it — retirements free
                    # pages; idle-engine exhaustion is impossible because
                    # one slot's worth of pages always fits the pool
                    # (checked in __init__) and an idle pool (after
                    # prefix-tree eviction) is fully free.
                    for j in range(b):
                        if active[j] is not None or reserved[j] \
                                or not waiting:
                            continue
                        k = 0
                        if gov is not None:
                            free_frac = pool.free_pages \
                                / max(1, pool.total_pages)
                            if not gov.admission_allowed(
                                    pool_free_frac=free_frac):
                                if any(a is not None for a in active) \
                                        or prefills:
                                    break
                                gov.note_forced_admit()
                            else:
                                k = next(
                                    (i for i, w in enumerate(waiting)
                                     if gov.tenant_allowed(w.tenant)), 0)
                        st = self._admit_paged(waiting[k], j)
                        if st is None:
                            # Pool short (even after radix eviction):
                            # leave the request waiting for retirements
                            # to free pages — but say so, once per
                            # episode, instead of silently spinning
                            # through this checkpoint.
                            if not self._pool_short:
                                self._pool_short = True
                                self.pool_wait_events += 1
                                if gov is not None:
                                    need = math.ceil(
                                        (len(waiting[k].prompt)
                                         + waiting[k].max_new_tokens - 1)
                                        / ps)
                                    gov.note_pool_wait(pool.free_pages,
                                                       need)
                            break
                        if self._pool_short:
                            self._pool_short = False
                            if gov is not None:
                                gov.note_pool_ready()
                        r = self._admit(waiting.pop(k))
                        if gov is not None:
                            gov.note_admitted(r)
                        req_ctxs[j] = open_ctx(r.id, r.max_new_tokens)
                        # phase span counts the tokens actually
                        # prefilled — a prefix hit shrinks the work
                        pf_ctxs[j] = open_ctx(
                            r.id, st.plen - st.matched_tokens,
                            phase="prefill")
                        reserved[j] = True
                        prefills.append(st)
                    update_gauges()

                    if prefills:
                        decode_live = any(a is not None for a in active)
                        budget = 1
                        if gov is not None:
                            budget = gov.prefill_chunk_budget(decode_live)
                            if budget < 1 and not decode_live:
                                budget = 1
                                gov.note_forced_chunk()
                        for _ in range(budget):
                            if not prefills:
                                break
                            # Batched chunk admissions: ONE (B, chunk)
                            # dispatch advances EVERY pending prefill by
                            # one chunk — each row at its own offset,
                            # passenger rows masked with last_idx=-1.
                            t0 = time.perf_counter()
                            ctoks = np.zeros((b, chunk), np.int32)
                            offs = np.zeros((b,), np.int32)
                            last = np.full((b,), -1, np.int32)
                            for st in prefills:
                                ctoks[st.slot] = \
                                    st.toks[st.offset:st.offset + chunk]
                                offs[st.slot] = st.offset
                                last[st.slot] = min(
                                    st.plen - 1 - st.offset, chunk - 1)
                            tok, caches = self._paged_prefill_chunk_fn(
                                self.params, caches, jnp.asarray(ctoks),
                                jnp.asarray(offs), jnp.asarray(last),
                                jnp.asarray(self._page_table),
                                self._next_key())
                            tok = np.asarray(tok)   # fence the dispatch
                            if decode_live:
                                self.stall_events.append(
                                    time.perf_counter() - t0)
                            for st in list(prefills):
                                st.offset += chunk
                                if st.offset >= st.plen:
                                    prefills.remove(st)
                                    reserved[st.slot] = False
                                    close_ctx(pf_ctxs[st.slot])
                                    pf_ctxs[st.slot] = None
                                    activate(st.slot, st.req,
                                             tok[st.slot], st.plen)
                        update_gauges()

                    live = [j for j in range(b) if active[j] is not None]
                    if not live:
                        continue
                    if gov is not None:
                        gov.maybe_pause_decode()
                    governed = gov is not None and gov.cap_watts is not None
                    steps = 1 if (prefills or governed) \
                        else min(remaining[j] for j in live)
                    if steps > 1 and deadlines \
                            and any(id(active[j]) in deadlines
                                    for j in live):
                        steps = min(steps, 8)
                    # Decode sees a MASKED page table: only actively
                    # decoding rows expose their pages — mid-prefill and
                    # dead rows read/write the scratch page only, so
                    # their garbage decode tokens cannot touch pages a
                    # prefill is filling.
                    mask = np.zeros((b, 1), np.int32)
                    for j in live:
                        mask[j] = 1
                    pt_dec = jnp.asarray(self._page_table * mask)
                    tok_dev = jnp.asarray(tokens)
                    pos_dev = jnp.asarray(pos)
                    outs = []
                    for _ in range(steps):
                        tok_dev, caches = self._paged_decode(
                            self.params, caches, tok_dev, pos_dev, pt_dec,
                            self._next_key())
                        outs.append(tok_dev)
                        pos_dev = pos_dev + 1
                    gen = np.asarray(jnp.concatenate(outs, axis=1))
                    for j in live:
                        r = active[j]
                        r.out.extend(gen[j].tolist())
                        tokens[j, 0] = gen[j, -1]
                        pos[j] += steps
                        remaining[j] -= steps
                        if remaining[j] == 0:
                            retire(j)
            finally:
                # Exceptions must leak neither spans nor page refs; the
                # (possibly donated) cache tree is re-bound so the next
                # generate() resumes from live buffers.
                self._paged_caches = caches
                for j in range(b):
                    if active[j] is not None or reserved[j]:
                        self._release_slot_pages(j)
                prefills.clear()
                waiting.clear()
                update_gauges()
                for j in range(b):
                    close_ctx(pf_ctxs[j])
                    pf_ctxs[j] = None
                    close_ctx(dec_ctxs[j])
                    dec_ctxs[j] = None
                    close_ctx(req_ctxs[j])
                    req_ctxs[j] = None
        return requests

    # -- synchronized waves (baseline) ---------------------------------------
    def _run_wave(self, wave: List[Request]) -> List[Request]:
        b = self.batch
        plen = prompt_bucket(max(len(r.prompt) for r in wave),
                             self.min_prompt_bucket)
        toks = np.zeros((b, plen), np.int32)
        for j, r in enumerate(wave):
            toks[j, plen - len(r.prompt):] = r.prompt   # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.is_encoder_decoder:
            batch["frame_embeds"] = jnp.zeros(
                (b, self.cfg.enc_len, self.cfg.d_model), jnp.bfloat16)

        steps = max(r.max_new_tokens for r in wave)
        # Wave-level capacity check: rows share the wave-max prompt
        # bucket AND decode wave-max steps, so a long-prompt neighbour
        # can push a short request's positions past max_len even though
        # each request passed its own check — dynamic_update_slice would
        # then clamp-corrupt the last cache slot silently.
        if plen + steps > self.max_len + 1:
            raise ValueError(
                f"wave needs {plen + steps} cache slots (shared prompt "
                f"bucket {plen} + {steps} decode steps) but max_len is "
                f"{self.max_len}; shrink the wave or use continuous mode")
        # J/token must divide by tokens actually generated — padded rows
        # and early-retired slots burn decode FLOPs but emit nothing.
        gen_tokens = sum(r.max_new_tokens for r in wave)
        wave_id = self._batch_count
        self._batch_count += 1
        with self._measure_ctx(wave_id, tokens=gen_tokens):
            nxt, caches = self._prefill(self.params, batch,
                                        self._next_key())
            nxt = nxt[:, None]
            cur = plen
            outs = [nxt]
            for _ in range(steps - 1):
                nxt, caches = self._decode(self.params, caches, nxt,
                                           jnp.asarray(cur, jnp.int32),
                                           self._next_key())
                outs.append(nxt)
                cur += 1
            gen = jax.block_until_ready(jnp.concatenate(outs, axis=1))
        gen = np.asarray(gen)
        for j, r in enumerate(wave):
            r.out = gen[j, :r.max_new_tokens].tolist()
            r.finish_reason = "length"
        return wave
