"""Serving: prefill/decode step functions + a continuous-batching engine.

``make_prefill_fn`` / ``make_decode_fn`` are the pjit-able pure steps the
dry-run lowers (``serve_step`` for the decode_* shapes = one new token
against a seq_len cache).

``ServeEngine`` implements **sequence-level continuous batching**
(``mode="continuous"``, the default): every batch slot carries its own
position counter, one decode step advances all live slots at their own
offsets (per-row KV-cache scatter via ``kernels/cache_update`` — Pallas
on TPU, ``vmap``'d dynamic-update-slice elsewhere), and a slot that
finishes its request is refilled from the queue on the *next* step
instead of idling until the longest request in a synchronized wave
drains.  Admission prefills one request at a time (prompt left-padded to
a power-of-two bucket so the prefill jit cache stays bounded) and
inserts the resulting cache row into the live batch; the decode step
function therefore sees one shape ever and never recompiles across
request mixes.  ``mode="wave"`` keeps the old synchronized-wave decode
as the measured baseline (see benchmarks/bench_serve.py).

PMT integration — per-request energy attribution: each admitted request
opens its own non-blocking flat session span (``serve/req<N>``,
``nested=False`` so interleaved lifetimes don't fight the nesting
stack), closed right after the fenced decode step that produced its
last token; spans resolve in vectorized batches against the shared
background ring sampler, so the engine reports true per-request
J/token next to the aggregate region (``serve/batch<N>`` /
``serve/wave<N>``) whose token count is the *actually generated* total
(sum of per-request ``max_new_tokens``), never padded wave FLOPs.
Concurrent request spans overlap in time, so per-request joules measure
each request's wall-clock window at full device power; token counts sum
exactly to the aggregate.  Passing a ``PowerMonitor`` routes the same
spans through ``measure_step``/``measure_request`` accounting instead.

Known semantic caveat: MoE layers route with cross-batch capacity
limits, so under continuous batching a request's tokens can be dropped
differently depending on its slot neighbours; dense/GQA/MLA/SSM archs
decode each row independently (slot refill leaks no state — see
tests/test_serve_continuous.py for the byte-parity gate).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_mod


def make_prefill_fn(cfg: ModelConfig, max_len: int):
    prefill, _ = model_mod.make_serve_fns(cfg)

    def prefill_fn(params, batch):
        logits, caches = prefill(params, batch, max_len)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    return prefill_fn


def make_decode_fn(cfg: ModelConfig, greedy: bool = True,
                   temperature: float = 1.0):
    _, decode = model_mod.make_serve_fns(cfg)

    def decode_fn(params, caches, tokens, cur_len, key=None):
        logits, caches = decode(params, caches, tokens, cur_len)
        if greedy or key is None:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            nxt = jax.random.categorical(key, logits / temperature)
        return nxt.astype(jnp.int32)[:, None], caches

    return decode_fn


def prompt_bucket(plen: int, min_bucket: int = 8) -> int:
    """Pad a prompt length to its power-of-two bucket.

    Bounds the prefill jit cache: every prompt length in (2^(k-1), 2^k]
    shares one compiled prefill, so at most log2(max_len) prefill
    variants exist no matter how many distinct lengths arrive.
    """
    if plen < 1:
        raise ValueError("empty prompt")
    b = max(min_bucket, 1)
    while b < plen:
        b <<= 1
    return b


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    id: Optional[int] = None        # assigned by the engine at admission


class ServeEngine:
    """Continuous-batching decode over fixed slots (wave mode as baseline).

    Args:
      cfg, params: model config + parameter tree.
      batch_size: number of decode slots.
      max_len: KV-cache capacity per slot; every request must satisfy
        ``prompt_bucket(len(prompt)) + max_new_tokens <= max_len + 1``.
      monitor: a ``PowerMonitor`` — aggregate regions go through its
        non-blocking ``measure_step``, per-request spans through
        ``measure_request`` (J/token per request via
        ``monitor.per_request_energy()``).
      session: a ``pmt.Session`` — aggregate region ``serve/batch<N>``
        (or ``serve/wave<N>``) plus one flat ``serve/req<N>`` span per
        request, all resolved asynchronously off the shared ring
        sampler.  Monitor wins when both are passed.
      mode: "continuous" (default) or "wave" (synchronized baseline).
      min_prompt_bucket: smallest prompt bucket (power of two).
      cache_impl: per-row scatter impl forwarded to
        ``kernels/cache_update`` ("auto" picks Pallas on TPU).
      decode_attn_impl: overrides ``cfg.decode_attn_impl`` for this
        engine — "flash" routes every decode step's attention through
        the length-aware ``kernels/decode_attention`` path (cache
        blocks beyond a row's position are never read; the J/token
        lever on the memory-bound decode step), "dense" keeps the
        masked full-cache attend, "auto" picks flash on TPU.  See
        benchmarks/bench_decode.py for the A/B.

    ``compile_counts`` tracks prefill/decode retraces — continuous-mode
    decode compiles exactly once, prefill once per prompt bucket.
    """

    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 max_len: int, monitor=None, session=None,
                 mode: str = "continuous", min_prompt_bucket: int = 8,
                 cache_impl: str = "auto",
                 decode_attn_impl: Optional[str] = None):
        if mode not in ("continuous", "wave"):
            raise ValueError(f"unknown serve mode {mode!r}")
        if decode_attn_impl is not None:
            cfg = dataclasses.replace(cfg,
                                      decode_attn_impl=decode_attn_impl)
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.monitor = monitor
        self.session = session
        self.mode = mode
        self.min_prompt_bucket = min_prompt_bucket
        self.cache_impl = cache_impl
        self._batch_count = 0       # aggregate regions (waves or batches)
        self._request_count = 0
        self.compile_counts: Dict[str, int] = {"prefill": 0, "decode": 0}
        self._prefill = jax.jit(self._counted("prefill",
                                              make_prefill_fn(cfg, max_len)))
        self._decode = jax.jit(self._counted("decode", make_decode_fn(cfg)))
        self._insert = self._make_insert()

    def _counted(self, name: str, fn):
        counts = self.compile_counts

        def wrapper(*args, **kwargs):
            counts[name] += 1       # runs at trace time == once per compile
            return fn(*args, **kwargs)

        return wrapper

    # -- cache row insertion ------------------------------------------------
    def _make_insert(self):
        """Jitted ``insert(caches, row, j)`` scattering a single-request
        prefill cache (batch 1) into batch row ``j`` of the live caches.

        Cache leaves put the batch axis at different positions (stacked
        units lead with a "layers" axis), so the per-leaf batch-axis
        index comes from ``cache_logical_axes``.
        """
        axes_tree = model_mod.cache_logical_axes(self.cfg)
        is_axes = lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x)
        batch_axes = [ax.index("batch") for ax in
                      jax.tree.leaves(axes_tree, is_leaf=is_axes)]

        def insert(caches, row, j):
            leaves, treedef = jax.tree.flatten(caches)
            row_leaves = jax.tree.leaves(row)
            out = []
            for c, r, ax in zip(leaves, row_leaves, batch_axes):
                starts = [0] * c.ndim
                starts[ax] = j
                out.append(jax.lax.dynamic_update_slice(
                    c, r.astype(c.dtype), tuple(starts)))
            return jax.tree.unflatten(treedef, out)

        # Donate the live caches: admission overwrites one row in place
        # instead of copying the whole KV tree per admitted request (the
        # caller always rebinds `caches = insert(caches, ...)`).
        return jax.jit(insert, donate_argnums=0)

    # -- measurement contexts ----------------------------------------------
    def _measure_ctx(self, agg_id: int, tokens: int):
        # Aggregate region per generate() call (continuous) or per wave.
        # Both paths are non-blocking: exit enqueues a span and returns.
        # Monitor keeps precedence so callers passing both still get its
        # J/token accounting.
        if self.monitor is not None:
            return self.monitor.measure_step(agg_id, tokens=tokens,
                                             blocking=False)
        if self.session is not None:
            label = "wave" if self.mode == "wave" else "batch"
            return self.session.region(f"serve/{label}{agg_id}",
                                       tokens=tokens)
        return contextlib.nullcontext()

    def _request_ctx(self, rid: int, tokens: int):
        if self.monitor is not None:
            return self.monitor.measure_request(rid, tokens=tokens,
                                                blocking=False)
        if self.session is not None:
            return self.session.region(f"serve/req{rid}", tokens=tokens,
                                       nested=False)
        return contextlib.nullcontext()

    # -- public API ----------------------------------------------------------
    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve ``requests``; returns them in input order, ``out`` filled."""
        for r in requests:
            need = prompt_bucket(len(r.prompt), self.min_prompt_bucket) \
                + r.max_new_tokens
            if r.max_new_tokens < 1:
                raise ValueError("max_new_tokens must be >= 1")
            if need > self.max_len + 1:
                raise ValueError(
                    f"request needs {need} cache slots (bucketed prompt + "
                    f"max_new_tokens) but max_len is {self.max_len}")
        if self.mode == "wave":
            done: List[Request] = []
            for i in range(0, len(requests), self.batch):
                wave = requests[i:i + self.batch]
                done.extend(self._run_wave(wave))
            return done
        return self._run_continuous(requests)

    # -- continuous batching --------------------------------------------------
    def _prefill_request(self, r: Request) -> Tuple[np.ndarray, Any, int]:
        """Single-request prefill at the prompt's bucket size.

        Returns (first generated token (1,) np.int32, cache row tree
        with batch size 1, next position == bucket size).  Blocking on
        the token fences prefill compute inside the request's span.
        """
        plen = len(r.prompt)
        bucket = prompt_bucket(plen, self.min_prompt_bucket)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, bucket - plen:] = r.prompt          # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.is_encoder_decoder:
            batch["frame_embeds"] = jnp.zeros(
                (1, self.cfg.enc_len, self.cfg.d_model), jnp.bfloat16)
        first, row = self._prefill(self.params, batch)
        return np.asarray(first), row, bucket

    def _run_continuous(self, requests: List[Request]) -> List[Request]:
        b = self.batch
        queue = list(requests)
        qi = 0                                   # admission cursor
        caches = model_mod.init_caches(self.cfg, b, self.max_len)
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        active: List[Optional[Request]] = [None] * b
        remaining = [0] * b
        ctxs: List[Any] = [None] * b
        total_tokens = sum(r.max_new_tokens for r in requests)
        agg_id = self._batch_count
        self._batch_count += 1

        def retire(j: int) -> None:
            # The caller already fenced this slot's last token (np reads
            # block), so closing the span here attributes correctly.
            ctxs[j].__exit__(None, None, None)
            ctxs[j] = None
            active[j] = None

        with self._measure_ctx(agg_id, tokens=total_tokens):
            try:
                while qi < len(queue) or any(r is not None for r in active):
                    # slot-granular admission: every free slot refills
                    # now instead of waiting for the batch to drain.
                    for j in range(b):
                        if active[j] is not None or qi >= len(queue):
                            continue
                        r = queue[qi]
                        qi += 1
                        r.id = self._request_count
                        self._request_count += 1
                        r.out = []
                        ctx = self._request_ctx(r.id,
                                                tokens=r.max_new_tokens)
                        ctx.__enter__()
                        ctxs[j] = ctx
                        active[j] = r
                        first, row, bucket = self._prefill_request(r)
                        caches = self._insert(caches, row, j)
                        tokens[j, 0] = first[0]
                        pos[j] = bucket
                        remaining[j] = r.max_new_tokens - 1
                        r.out.append(int(first[0]))
                        if remaining[j] == 0:
                            retire(j)
                    live = [j for j in range(b) if active[j] is not None]
                    if not live:
                        continue          # everything retired at prefill
                    # Retirement is deterministic (exactly max_new_tokens
                    # per request), so decode runs device-side until the
                    # *next* slot retires — one host sync per retirement
                    # event, not per token.  Inactive rows decode garbage
                    # into their own (dead, about-to-be-overwritten)
                    # cache rows only.
                    steps = min(remaining[j] for j in live)
                    tok_dev = jnp.asarray(tokens)
                    pos_dev = jnp.asarray(pos)
                    outs = []
                    for _ in range(steps):
                        tok_dev, caches = self._decode(self.params, caches,
                                                       tok_dev, pos_dev)
                        outs.append(tok_dev)
                        pos_dev = pos_dev + 1
                    chunk = np.asarray(jnp.concatenate(outs, axis=1))
                    # np read blocked: every token in the chunk is
                    # computed, so spans closed below are correctly
                    # fenced.
                    for j in live:
                        r = active[j]
                        r.out.extend(chunk[j].tolist())
                        tokens[j, 0] = chunk[j, -1]
                        pos[j] += steps
                        remaining[j] -= steps
                        if remaining[j] == 0:
                            retire(j)
            finally:
                # An exception mid-loop (prefill OOM, interrupt) must not
                # leak open request spans — they hold ring-sampler pins
                # on the shared session for its whole lifetime.
                for j in range(b):
                    if ctxs[j] is not None:
                        ctxs[j].__exit__(None, None, None)
                        ctxs[j] = None
        return requests

    # -- synchronized waves (baseline) ---------------------------------------
    def _run_wave(self, wave: List[Request]) -> List[Request]:
        b = self.batch
        plen = prompt_bucket(max(len(r.prompt) for r in wave),
                             self.min_prompt_bucket)
        toks = np.zeros((b, plen), np.int32)
        for j, r in enumerate(wave):
            toks[j, plen - len(r.prompt):] = r.prompt   # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.is_encoder_decoder:
            batch["frame_embeds"] = jnp.zeros(
                (b, self.cfg.enc_len, self.cfg.d_model), jnp.bfloat16)

        steps = max(r.max_new_tokens for r in wave)
        # Wave-level capacity check: rows share the wave-max prompt
        # bucket AND decode wave-max steps, so a long-prompt neighbour
        # can push a short request's positions past max_len even though
        # each request passed its own check — dynamic_update_slice would
        # then clamp-corrupt the last cache slot silently.
        if plen + steps > self.max_len + 1:
            raise ValueError(
                f"wave needs {plen + steps} cache slots (shared prompt "
                f"bucket {plen} + {steps} decode steps) but max_len is "
                f"{self.max_len}; shrink the wave or use continuous mode")
        # J/token must divide by tokens actually generated — padded rows
        # and early-retired slots burn decode FLOPs but emit nothing.
        gen_tokens = sum(r.max_new_tokens for r in wave)
        wave_id = self._batch_count
        self._batch_count += 1
        with self._measure_ctx(wave_id, tokens=gen_tokens):
            nxt, caches = self._prefill(self.params, batch)
            nxt = nxt[:, None]
            cur = plen
            outs = [nxt]
            for _ in range(steps - 1):
                nxt, caches = self._decode(self.params, caches, nxt,
                                           jnp.asarray(cur, jnp.int32))
                outs.append(nxt)
                cur += 1
            gen = jax.block_until_ready(jnp.concatenate(outs, axis=1))
        gen = np.asarray(gen)
        for j, r in enumerate(wave):
            r.out = gen[j, :r.max_new_tokens].tolist()
        return wave
