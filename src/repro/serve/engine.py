"""Serving: prefill/decode step functions + a batched request engine.

``make_prefill_fn`` / ``make_decode_fn`` are the pjit-able pure steps the
dry-run lowers (``serve_step`` for the decode_* shapes = one new token
against a seq_len cache).

``ServeEngine`` is a minimal batched server on top of them: fixed batch
slots, synchronized decode (all slots share one position counter; slots
are refilled between sequences — sequence-granularity continuous
batching).  Per-slot position counters would need per-row cache scatter;
documented as the production follow-up in DESIGN.md.

PMT integration: each wave runs inside a ``pmt.Session`` region, so the
engine shares one background sampler per backend with the train loop and
any monitors on the same session, and reports J/token — the paper's
energy-efficiency metric applied to serving.  The measurement path is
fully non-blocking: wave close is an O(1) span enqueue, resolution and
exporter fan-out happen on the session's background resolver thread, and
no per-wave measurement dict is ever materialised on the serving thread.
Passing a ``PowerMonitor`` still works (non-blocking too; its accounting
updates as waves resolve).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_mod


def make_prefill_fn(cfg: ModelConfig, max_len: int):
    prefill, _ = model_mod.make_serve_fns(cfg)

    def prefill_fn(params, batch):
        logits, caches = prefill(params, batch, max_len)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    return prefill_fn


def make_decode_fn(cfg: ModelConfig, greedy: bool = True,
                   temperature: float = 1.0):
    _, decode = model_mod.make_serve_fns(cfg)

    def decode_fn(params, caches, tokens, cur_len, key=None):
        logits, caches = decode(params, caches, tokens, cur_len)
        if greedy or key is None:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            nxt = jax.random.categorical(key, logits / temperature)
        return nxt.astype(jnp.int32)[:, None], caches

    return decode_fn


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    """Synchronized batched decoding over fixed slots.

    Measurement plumbing (either or both may be given; monitor wins when
    both are passed, preserving its J/token accounting):
      monitor: a ``PowerMonitor`` — waves go through its non-blocking
        ``measure_step``; cumulative counters/CSV update as spans
        resolve on the session's background resolver.
      session: a ``pmt.Session`` — each wave becomes a nested region
        (``serve/wave<N>``) resolved asynchronously off the shared ring
        sampler; attach a ``MemoryExporter``/``JsonlExporter`` for
        accounting (see launch/serve.py).
    """

    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 max_len: int, monitor=None, session=None):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.monitor = monitor
        self.session = session
        self._wave_count = 0
        self._prefill = jax.jit(make_prefill_fn(cfg, max_len))
        self._decode = jax.jit(make_decode_fn(cfg))

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve requests in waves of ``batch_size``."""
        done: List[Request] = []
        for i in range(0, len(requests), self.batch):
            wave = requests[i:i + self.batch]
            done.extend(self._run_wave(wave))
        return done

    def _measure_ctx(self, wave_id: int, tokens: int):
        # Both paths are non-blocking: wave exit enqueues a span and
        # returns; nothing on the serving thread waits for resolution.
        # Monitor keeps precedence (as before this was non-blocking) so
        # callers passing both still get its J/token accounting.
        if self.monitor is not None:
            return self.monitor.measure_step(wave_id, tokens=tokens,
                                             blocking=False)
        if self.session is not None:
            return self.session.region(f"serve/wave{wave_id}",
                                       tokens=tokens)
        return contextlib.nullcontext()

    def _run_wave(self, wave: List[Request]) -> List[Request]:
        b = self.batch
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, plen), np.int32)
        for j, r in enumerate(wave):
            toks[j, plen - len(r.prompt):] = r.prompt   # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.is_encoder_decoder:
            batch["frame_embeds"] = jnp.zeros(
                (b, self.cfg.enc_len, self.cfg.d_model), jnp.bfloat16)

        steps = max(r.max_new_tokens for r in wave)
        wave_id = self._wave_count
        self._wave_count += 1
        with self._measure_ctx(wave_id, tokens=b * steps):
            nxt, caches = self._prefill(self.params, batch)
            nxt = nxt[:, None]
            cur = plen
            outs = [nxt]
            for _ in range(steps - 1):
                nxt, caches = self._decode(self.params, caches, nxt,
                                           jnp.asarray(cur, jnp.int32))
                outs.append(nxt)
                cur += 1
            gen = jax.block_until_ready(jnp.concatenate(outs, axis=1))
        gen = np.asarray(gen)
        for j, r in enumerate(wave):
            r.out = gen[j, :r.max_new_tokens].tolist()
        return wave
