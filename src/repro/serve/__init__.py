from repro.serve.engine import (Request, ServeEngine, make_decode_fn,
                                make_prefill_chunk_fn, make_prefill_fn,
                                prompt_bucket, resolve_prefill_chunk,
                                stall_p95)
from repro.serve.governor import PowerGovernor, ThrottleDecision

__all__ = ["Request", "ServeEngine", "PowerGovernor", "ThrottleDecision",
           "make_prefill_fn", "make_prefill_chunk_fn", "make_decode_fn",
           "prompt_bucket", "resolve_prefill_chunk", "stall_p95"]
