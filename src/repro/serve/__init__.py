from repro.serve.engine import (Request, ServeEngine, make_decode_fn,
                                make_prefill_chunk_fn, make_prefill_fn,
                                prompt_bucket, resolve_prefill_chunk)

__all__ = ["Request", "ServeEngine", "make_prefill_fn",
           "make_prefill_chunk_fn", "make_decode_fn", "prompt_bucket",
           "resolve_prefill_chunk"]
