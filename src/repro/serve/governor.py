"""``PowerGovernor`` — energy-aware scheduling policy for the serve
engine: the control half of the measurement -> control loop.

The engine measures J/token per request; the governor *acts* on it.  It
reads smoothed power from a :class:`~repro.telemetry.PowerRecorder`
window and holds the engine under a configured watts cap (and per-tenant
joules quotas) by modulating, in escalating order:

  1. **admission rate** — new requests are admitted only while smoothed
     power sits below ``admit_frac * cap_watts``, and at most one
     admission per ``admit_hold_s`` so each admission's power step is
     *observed* before the next one lands (no multi-slot overshoot
     through the smoothing lag);
  2. **prefill chunk pacing** — the interleaved chunk queue drains 0
     chunks per decode step while power is above the admission
     threshold (and up to ``max_chunks_per_step`` when there is lots of
     headroom), trading time-to-first-token for cap headroom while
     in-flight decodes proceed untouched;
  3. **decode idling (last resort)** — when power exceeds
     ``cap_watts * (1 + hard_over_frac)`` the governor duty-cycles the
     decode loop with ``pause_s`` sleeps, stretching wall-clock to pull
     average watts down.  Decode never stops outright, so no request
     starves.

Liveness guarantee: every lever only *defers* work — admission resumes
as soon as the window drops, a paused chunk queue is force-drained when
nothing is decoding (the engine calls :meth:`note_forced_chunk`), and
pauses are bounded sleeps between decode steps.  A governor with
``cap_watts=None`` is a pure observer (every lever wide open), which is
what the uncapped leg of ``benchmarks/bench_governor.py`` measures.

Tenant quotas are *soft priorities*, not hard kills: a tenant whose
accumulated request joules (fed back from the recorder's resolved
``serve/req<N>`` records) exceed its quota is deprioritised behind
other tenants at admission, but is still served when nothing else is
waiting — quota pressure cannot deadlock the queue.

Every throttle decision (state transitions and each decode pause) is
recorded in :attr:`decisions` *and* as a flat ``serve/governor/<action>``
session span, so the control actions themselves show up in the energy
export stream next to the requests they shaped.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple, Union

from repro.core.export import RegionRecord

_REQ = "serve/req"


@dataclasses.dataclass(frozen=True)
class ThrottleDecision:
    """One governor action: what, when, and on which power reading."""

    t: float                      # governor-clock timestamp
    action: str                   # admit_block/admit_resume/chunk_pause/
                                  # chunk_resume/chunk_force/decode_pause/
                                  # tenant_defer/tenant_resume/pool_block/
                                  # pool_resume/pool_wait/pool_ready
    watts: Optional[float]        # smoothed window power at decision time
    cap: Optional[float]
    detail: str = ""


class PowerGovernor:
    """Energy-aware admission/pacing policy consulted by ``ServeEngine``.

    Args:
      recorder: the :class:`~repro.telemetry.PowerRecorder` whose watts
        window is the control signal (and whose resolved ``serve/req<N>``
        records feed tenant quota accounting).
      cap_watts: power budget; ``None`` disables power capping (the
        governor still tracks tenants and records nothing).
      window_s: trailing smoothing window for the control signal.
      admit_frac: admissions (and chunk drains) allowed only below
        ``admit_frac * cap_watts`` — the hysteresis band that absorbs
        the one-slot power step an admission causes.
      hard_over_frac: decode pauses engage above
        ``cap_watts * (1 + hard_over_frac)``.
      admit_hold_s: minimum spacing between admissions near the cap
        (defaults to ``window_s`` so each admission is visible in the
        window before the next); ignored while power is below
        ``boost_frac * cap_watts``.
      pause_s: duration of one decode-idle sleep.
      max_chunks_per_step: chunk-drain budget when power sits below
        ``boost_frac * cap_watts`` (ample headroom).
      tenant_quota_j: per-tenant joules quota — a single float applied
        to every tenant, or a ``{tenant: quota}`` dict (missing tenants
        unlimited).
      pool_reserve_frac: paged-KV pool pressure veto.  When the engine
        passes its pool's free-page fraction to
        :meth:`admission_allowed` and it sits below this reserve, the
        admission is vetoed (``pool_block``/``pool_resume`` decisions)
        regardless of power headroom — an admission that would leave the
        pool unable to absorb in-flight decode growth or the next
        prefix-cache insert is worse than a deferred one.  ``0.0``
        (default) disables the veto; contiguous mode never passes the
        signal.
      backend: restrict the control signal to one backend's watts
        (default: sum over all backends the recorder sees).
      signal_ttl_s: maximum age of the newest watts sample before the
        control signal is declared *stale* (sensor blackout / dead
        sampler).  ``None`` (default) trusts the signal forever — the
        pre-fault-tolerance behaviour.
      fail_mode: what a stale signal means.  ``"closed"`` (default, the
        conservative choice): stop admitting and pause chunk drains
        until the signal recovers — a power-capped fleet must not go
        uncapped just because its meter died; liveness is preserved by
        the engine's existing forced-admit/forced-chunk overrides.
        ``"open"``: keep serving as if uncapped (availability over the
        cap).  Decode is never paused on a stale signal in either mode
        (pausing blind only burns wall-clock).
      clock: injectable time source for deterministic tests.
    """

    def __init__(self, recorder, cap_watts: Optional[float] = None,
                 window_s: float = 0.25, admit_frac: float = 0.9,
                 hard_over_frac: float = 0.10,
                 admit_hold_s: Optional[float] = None,
                 pause_s: float = 0.005, max_chunks_per_step: int = 2,
                 tenant_quota_j: Union[None, float, Dict[str, float]] = None,
                 pool_reserve_frac: float = 0.0,
                 backend: Optional[str] = None,
                 signal_ttl_s: Optional[float] = None,
                 fail_mode: str = "closed",
                 clock: Callable[[], float] = time.monotonic):
        if cap_watts is not None and cap_watts <= 0:
            raise ValueError(f"cap_watts must be > 0, got {cap_watts}")
        if not 0.0 < admit_frac <= 1.0:
            raise ValueError(f"admit_frac must be in (0, 1], got {admit_frac}")
        if max_chunks_per_step < 1:
            raise ValueError("max_chunks_per_step must be >= 1")
        if signal_ttl_s is not None and signal_ttl_s <= 0:
            raise ValueError(f"signal_ttl_s must be > 0, got {signal_ttl_s}")
        if fail_mode not in ("open", "closed"):
            raise ValueError(
                f"fail_mode must be 'open' or 'closed', got {fail_mode!r}")
        if not 0.0 <= pool_reserve_frac < 1.0:
            raise ValueError(f"pool_reserve_frac must be in [0, 1), "
                             f"got {pool_reserve_frac}")
        self.recorder = recorder
        self.cap_watts = cap_watts
        self.window_s = float(window_s)
        self.admit_frac = float(admit_frac)
        self.hard_over_frac = float(hard_over_frac)
        self.admit_hold_s = (window_s if admit_hold_s is None
                             else float(admit_hold_s))
        self.pause_s = float(pause_s)
        self.max_chunks_per_step = int(max_chunks_per_step)
        self.boost_frac = 0.5 * self.admit_frac
        self.backend = backend
        self.signal_ttl_s = (None if signal_ttl_s is None
                             else float(signal_ttl_s))
        self.fail_mode = fail_mode
        self._stale_blocked = False
        self._clock = clock
        self._quota = tenant_quota_j
        self._lock = threading.Lock()
        self._tenant_joules: Dict[str, float] = {}
        self._rid_tenant: Dict[int, str] = {}
        self._tenant_blocked: Dict[str, bool] = {}
        self._last_admit_t = float("-inf")
        self._admit_blocked = False
        self._hold_blocked = False
        self._chunk_blocked = False
        # Learned per-admission power step (EWMA, biased high): each
        # settled admission updates it from the observed window delta,
        # so the admission gate can *predict* whether one more slot
        # still fits under the cap instead of discovering the overshoot
        # after the fact.
        self._step_w: Optional[float] = None
        self._pending_step: Optional[Tuple[Optional[float], float]] = None
        self.pool_reserve_frac = float(pool_reserve_frac)
        self._pool_blocked = False
        self._pool_waiting = False
        # Linear watts-vs-live-slots model fitted from admission
        # history: each settled admission contributes one
        # (live_slots, window watts) sample, and the least-squares slope
        # is the marginal watts of one more slot — a *per-configuration*
        # estimate that, unlike the EWMA step, interpolates across
        # occupancies it has seen instead of trusting the last delta.
        # The EWMA remains the cold-start fallback until the fit has
        # enough spread to be trustworthy.
        self._engine = None           # bound by begin()
        self._slot_obs: collections.deque = collections.deque(maxlen=64)
        self._slot_model: Optional[Tuple[float, float, int]] = None
        self.decisions: collections.deque = collections.deque(maxlen=4096)
        self.throttle_count = 0       # total decisions ever (ring-proof)
        self.pause_total_s = 0.0
        self._session = None          # bound by begin()
        self._unsub: Optional[Callable[[], None]] = None
        if recorder is not None:
            self._unsub = recorder.subscribe(self._on_record)

    # -- engine binding -----------------------------------------------------
    def begin(self, engine) -> None:
        """Called by the engine at the top of each ``generate()``: binds
        the session used for ``serve/governor`` spans and re-arms the
        admission hold."""
        session = engine.session
        if session is None and engine.monitor is not None:
            session = engine.monitor.session
        self._session = session
        self._engine = engine
        self._last_admit_t = float("-inf")

    def close(self) -> None:
        if self._unsub is not None:
            self._unsub()
            self._unsub = None

    # -- control signal -----------------------------------------------------
    def window_watts(self) -> Optional[float]:
        """Smoothed power over the trailing window (``None`` = no data
        yet, every lever stays open)."""
        if self.recorder is None:
            return None
        return self.recorder.mean_watts(self.window_s, backend=self.backend)

    def signal_stale(self) -> bool:
        """Whether the watts signal has outlived ``signal_ttl_s``.

        Stale means: at least one watts sample was ever recorded *and*
        the newest one is older than the TTL on the governor clock.  A
        cold start (no samples yet) is not stale — that is the existing
        "no signal yet" regime, handled by the admission hold.
        """
        if self.signal_ttl_s is None or self.recorder is None:
            return False
        last = self.recorder.last_watts_ts(backend=self.backend)
        if last is None:
            return False
        return self._clock() - last > self.signal_ttl_s

    def _signal(self) -> Tuple[Optional[float], bool]:
        """Control signal + freshness: ``(window watts, stale?)``.

        Records the stale/fresh transition once per episode (shared
        ``_stale_blocked`` state across all levers).  ``mean_watts``
        anchors its window at the newest *sample* — a frozen trace keeps
        reporting its last smoothed value forever — so a stale signal
        must be checked here, not inferred from ``window_watts()``.
        """
        w = self.window_watts()
        stale = self.signal_stale()
        self._transition("_stale_blocked", stale,
                         "signal_stale" if stale else "signal_fresh", w)
        return w, stale

    # -- levers (consulted by ServeEngine._run_continuous) -------------------
    def admission_allowed(
            self, pool_free_frac: Optional[float] = None) -> bool:
        """Whether a new request may be admitted right now.

        ``pool_free_frac`` (paged mode only) is the engine's KV pool
        free-page fraction; below ``pool_reserve_frac`` it vetoes the
        admission even when power headroom exists — this veto is
        independent of ``cap_watts`` and works for uncapped governors.
        """
        if pool_free_frac is not None and self.pool_reserve_frac > 0.0:
            low = pool_free_frac < self.pool_reserve_frac
            self._transition("_pool_blocked", low,
                             "pool_block" if low else "pool_resume",
                             self.window_watts() if low else None)
            if low:
                return False
        if self.cap_watts is None:
            return True
        w, stale = self._signal()
        if stale:
            if self.fail_mode == "closed":
                return False
            w = None          # fail_open: ignore the frozen window value
        if w is not None:
            self._settle_step(w)
            # Predictive gate: one more slot costs ~the learned step, so
            # block unless current + step still fits under the cap.  The
            # admit_frac threshold alone is not enough — when a slot's
            # power step exceeds the (1 - admit_frac) headroom band, a
            # transient dip below the threshold would admit a slot whose
            # settled load overshoots the cap.
            # Per-slot step: fitted slope when the admission history
            # supports it, EWMA (then a headroom-band guess) otherwise.
            step = self._fitted_step()
            if step is None:
                step = self._step_w if self._step_w is not None \
                    else self.cap_watts * (1.0 - self.admit_frac)
            if w >= self.cap_watts * self.admit_frac \
                    or w + step > self.cap_watts:
                self._transition("_admit_blocked", True, "admit_block", w)
                return False
            self._transition("_admit_blocked", False, "admit_resume", w)
        if (w is None or w >= self.cap_watts * self.boost_frac) and \
                self._clock() - self._last_admit_t < self.admit_hold_s:
            # Near the cap — or with no signal yet (recorder hasn't
            # polled): space admissions out so each one's power step is
            # observed in the window before the next lands.  An unknown
            # signal must be treated as near-cap, or the first scheduler
            # pass fills every slot before the first sample arrives.
            # One admit_hold decision per hold episode, not per attempt.
            self._transition("_hold_blocked", True, "admit_hold", w)
            return False
        self._hold_blocked = False       # episode over; no resume span
        return True

    def prefill_chunk_budget(self, decode_live: bool) -> int:
        """Chunks to drain alongside this decode step (0 pauses the
        queue).  The engine force-drains one chunk anyway when nothing
        is decoding (see :meth:`note_forced_chunk`) so a paused queue
        cannot starve."""
        if self.cap_watts is None:
            return 1
        w, stale = self._signal()
        if stale:
            # fail_closed: no chunk drains on a dead meter (the engine's
            # forced-chunk override keeps an otherwise-idle engine live);
            # fail_open: drain at the conservative 1/step rate.
            return 0 if self.fail_mode == "closed" else 1
        if w is None:
            return 1
        if w >= self.cap_watts * self.admit_frac:
            self._transition("_chunk_blocked", True, "chunk_pause", w)
            return 0
        self._transition("_chunk_blocked", False, "chunk_resume", w)
        if w < self.cap_watts * self.boost_frac:
            return self.max_chunks_per_step
        return 1

    def maybe_pause_decode(self) -> float:
        """Last-resort duty cycling: sleep ``pause_s`` when smoothed
        power exceeds the hard-over threshold.  Returns the seconds
        slept (0.0 when no pause was needed).  The sleep itself runs
        inside a ``serve/governor/decode_pause`` span, so idling shows
        up in the energy export like any other scheduled activity."""
        if self.cap_watts is None:
            return 0.0
        w, stale = self._signal()
        if stale:
            return 0.0       # never duty-cycle decode on a dead meter
        if w is None or w <= self.cap_watts * (1.0 + self.hard_over_frac):
            return 0.0
        self._decide("decode_pause", w, detail=f"sleep {self.pause_s}s",
                     span_sleep_s=self.pause_s)
        with self._lock:
            self.pause_total_s += self.pause_s
        return self.pause_s

    def note_forced_chunk(self) -> None:
        """The engine drained a chunk despite a 0 budget (nothing was
        decoding, so pausing prefill would have idled the engine)."""
        self._decide("chunk_force", self.window_watts(),
                     detail="no live decode; liveness override")

    def note_pool_wait(self, free_pages: int, need_pages: int) -> None:
        """The engine's paged admission could not cover the next request
        even after radix eviction: it leaves the request queued and
        relies on retirements to free pages.  Recorded as one
        ``pool_wait`` decision per wait episode (not per scheduler pass)
        so pool exhaustion shows up in the decision stream instead of
        the engine silently spinning at admission checkpoints."""
        if self._pool_waiting:
            return
        self._pool_waiting = True
        self._decide("pool_wait", self.window_watts(),
                     detail=f"pool short: {free_pages} free < "
                            f"{need_pages} needed pages")

    def note_pool_ready(self) -> None:
        """Admission succeeded after a ``pool_wait`` episode: close it."""
        if not self._pool_waiting:
            return
        self._pool_waiting = False
        self._decide("pool_ready", self.window_watts())

    def note_forced_admit(self) -> None:
        """The engine admitted despite a blocked gate: it was completely
        idle (no live decode, no pending prefill) with work waiting, so
        the measured power can only be idle draw — if *that* exceeds the
        cap the cap is unholdable and liveness wins."""
        self._decide("admit_force", self.window_watts(),
                     detail="engine idle with work waiting; liveness override")

    # -- tenant quotas ------------------------------------------------------
    def _quota_for(self, tenant: str) -> Optional[float]:
        if self._quota is None:
            return None
        if isinstance(self._quota, dict):
            return self._quota.get(tenant)
        return float(self._quota)

    def tenant_allowed(self, tenant: Optional[str]) -> bool:
        """Whether ``tenant`` is inside its joules quota.  The engine
        uses this as a *priority* hint: over-quota tenants yield to
        others at admission but are still served when alone."""
        if tenant is None:
            return True
        quota = self._quota_for(tenant)
        if quota is None:
            return True
        with self._lock:
            spent = self._tenant_joules.get(tenant, 0.0)
        over = spent >= quota
        self._tenant_transition(tenant, over, spent, quota)
        return not over

    def tenant_joules(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._tenant_joules)

    def _settle_step(self, w_now: float) -> None:
        """Fold a settled admission's observed power delta into the
        learned per-slot step (biased high: a step estimate that decays
        too eagerly re-opens the overshoot the gate exists to prevent)."""
        if self._pending_step is None:
            self._observe_slots(w_now)
            return
        pre, t_adm = self._pending_step
        if self._clock() - t_adm < self.admit_hold_s:
            return
        self._pending_step = None
        if pre is not None:
            obs = max(0.0, w_now - pre)
            self._step_w = obs if self._step_w is None \
                else max(0.5 * (self._step_w + obs), obs)
        self._observe_slots(w_now)

    def _observe_slots(self, w_now: float) -> None:
        """Record one (live_slots, watts) sample for the linear model —
        only while no admission is mid-settle, so the samples pair the
        window power with the occupancy that actually produced it."""
        eng = self._engine
        if eng is not None:
            self._slot_obs.append((float(eng.live_slots), float(w_now)))

    def _fitted_step(self) -> Optional[float]:
        """Marginal watts per slot from the least-squares line over the
        admission-history samples.  ``None`` (fall back to the EWMA)
        until there are >= 4 samples spanning more than one occupancy —
        a vertical-stack of samples at a single slot count has no slope
        information."""
        obs = list(self._slot_obs)
        if len(obs) < 4:
            return None
        n = float(len(obs))
        sx = sum(x for x, _ in obs)
        sy = sum(y for _, y in obs)
        sxx = sum(x * x for x, _ in obs)
        sxy = sum(x * y for x, y in obs)
        var = sxx - sx * sx / n
        if var < 1e-9:
            return None
        slope = max(0.0, (sxy - sx * sy / n) / var)
        self._slot_model = (slope, (sy - slope * sx) / n, len(obs))
        return slope

    def note_admitted(self, request) -> None:
        """Engine callback at admission: arms the admission hold,
        snapshots pre-admission power for step learning, and registers
        the request's tenant for quota attribution."""
        self._pending_step = (self.window_watts(), self._clock())
        self._last_admit_t = self._clock()
        tenant = getattr(request, "tenant", None)
        if tenant is not None and request.id is not None:
            with self._lock:
                self._rid_tenant[request.id] = tenant

    def _on_record(self, rec: RegionRecord) -> None:
        """Recorder subscriber: fold resolved whole-request spans into
        per-tenant joules accounting."""
        path = rec.path
        if not path.startswith(_REQ) or "/" in path[len(_REQ):]:
            return
        try:
            rid = int(path[len(_REQ):])
        except ValueError:
            return
        with self._lock:
            tenant = self._rid_tenant.get(rid)
            if tenant is not None:
                self._tenant_joules[tenant] = \
                    self._tenant_joules.get(tenant, 0.0) + rec.joules

    # -- decision recording -------------------------------------------------
    def _transition(self, attr: str, blocked: bool, action: str,
                    watts: Optional[float]) -> None:
        """Record a lever state *transition* (not every consultation —
        a long over-cap episode is one block + one resume, not a span
        flood)."""
        if getattr(self, attr) == blocked:
            return
        setattr(self, attr, blocked)
        self._decide(action, watts)

    def _tenant_transition(self, tenant: str, over: bool, spent: float,
                           quota: float) -> None:
        with self._lock:
            was = self._tenant_blocked.get(tenant, False)
            if was == over:
                return
            self._tenant_blocked[tenant] = over
        self._decide("tenant_defer" if over else "tenant_resume",
                     None, detail=f"{tenant}: {spent:.3f}/{quota:.3f} J")

    def _decide(self, action: str, watts: Optional[float],
                detail: str = "", span_sleep_s: float = 0.0) -> None:
        d = ThrottleDecision(t=self._clock(), action=action, watts=watts,
                             cap=self.cap_watts, detail=detail)
        with self._lock:
            self.decisions.append(d)
            self.throttle_count += 1
            n = self.throttle_count
        session = self._session
        if session is not None:
            # Flat span (depth 0, no nesting stack) so governor actions
            # are energy-attributed like request spans.  The pause's
            # sleep runs inside its span; transition spans are instants.
            try:
                with session.region(f"serve/governor/{action}{n}",
                                    nested=False):
                    if span_sleep_s > 0.0:
                        time.sleep(span_sleep_s)
            except Exception:
                pass          # session closed mid-run: keep governing
        elif span_sleep_s > 0.0:
            time.sleep(span_sleep_s)

    # -- reporting ----------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            actions: Dict[str, int] = {}
            for d in self.decisions:
                actions[d.action] = actions.get(d.action, 0) + 1
            return {
                "cap_watts": self.cap_watts,
                "window_s": self.window_s,
                "throttle_decisions": self.throttle_count,
                "throttle_actions": actions,
                "pause_total_s": self.pause_total_s,
                "tenant_joules": dict(self._tenant_joules),
                "signal_ttl_s": self.signal_ttl_s,
                "fail_mode": self.fail_mode,
                "signal_stale": self.signal_stale(),
                "pool_reserve_frac": self.pool_reserve_frac,
                "slot_watts_model": (
                    None if self._slot_model is None else {
                        "slope_w_per_slot": self._slot_model[0],
                        "intercept_w": self._slot_model[1],
                        "samples": self._slot_model[2],
                    }),
            }

    def __repr__(self):
        return (f"<PowerGovernor cap={self.cap_watts} "
                f"window={self.window_s}s decisions={self.throttle_count}>")
