"""Per-row KV-cache quantization (int8 / fp8_e4m3) shared by the
cache_update / decode_attention / prefill_attention kernel families.

Decode is memory-bound: flash-decode already skips *dead* cache bytes,
quantization shrinks the *live* ones.  Cache rows are stored as low-bit
codes plus one float32 absmax scale per row -- "row" meaning the
quantization granularity the append-only write paths can produce
without read-modify-write: one (token, kv-head) head-dim vector for
GQA caches, one (token,) latent+rope vector for the MLA cache.  In the
paged layout the scale leaves are paged exactly like their code leaves
(same page-id space, same page tables), which makes the scales
page-granular: a page's scale rows travel with it through prefix
sharing, adoption, and eviction.

Scheme (absmax, symmetric, zero-point-free):

    amax  = max(|x|, axis=-1)                       # per row
    scale = max(amax, SCALE_EPS) / QMAX             # float32
    codes = cast(clip(round*(x / scale), -QMAX, QMAX))   # *int8 only
    dequant(codes, scale) = f32(codes) * scale

fp8_e4m3 clips BEFORE the cast: out-of-range float32 -> float8_e4m3fn
casts produce NaN (the format has no inf), not a saturated value.

Bit-exactness contract: every consumer dequantizes with the same op
order -- ``codes.astype(float32) * scale[..., None]`` -- so the Pallas
kernels, their blockwise ref twins, and the lax fallbacks all see
bit-identical dequantized blocks in interpret mode.
"""
from __future__ import annotations

import jax.numpy as jnp

# Supported ``ModelConfig.kv_quant`` / ``ServeEngine(cache_dtype=...)``
# modes.  qmax 127 = int8 symmetric range; qmax 448 = float8_e4m3fn
# finfo max (the largest finite magnitude the format represents).
QUANT_MODES = ("int8", "fp8_e4m3")
SCALE_EPS = 1e-8

_QMAX = {"int8": 127.0, "fp8_e4m3": 448.0}
_DTYPE = {"int8": jnp.int8, "fp8_e4m3": jnp.float8_e4m3fn}


def check_mode(mode: str) -> str:
    if mode not in QUANT_MODES:
        raise ValueError(f"unknown kv quant mode {mode!r} "
                         f"(expected one of {QUANT_MODES})")
    return mode


def quant_dtype(mode: str):
    """Storage dtype of the code leaves for ``mode``."""
    return _DTYPE[check_mode(mode)]


def qmax(mode: str) -> float:
    return _QMAX[check_mode(mode)]


def qmax_inv(mode: str) -> float:
    """``1 / qmax`` as a Python (double) constant.  Scales multiply by
    this instead of dividing by ``qmax``: XLA rewrites division by a
    constant into a reciprocal multiply in *some* compilation paths
    (jitted lax) but not others (op-by-op interpret mode), a 1-ulp
    divergence that would break the kernel-vs-ref bit-exactness gate.
    An explicit multiply compiles identically everywhere.
    """
    return 1.0 / _QMAX[check_mode(mode)]


def quantize(x, mode: str):
    """Per-row absmax quantization over the last axis.

    Returns ``(codes, scales)``: codes with ``x.shape`` in the mode's
    storage dtype, scales float32 with ``x.shape[:-1]``.
    """
    check_mode(mode)
    qm = _QMAX[mode]
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scales = jnp.maximum(amax, SCALE_EPS) * qmax_inv(mode)
    y = xf / scales[..., None]
    if mode == "int8":
        y = jnp.round(y)
    # fp8: clip before the cast (overflow casts to NaN, not saturation)
    codes = jnp.clip(y, -qm, qm).astype(_DTYPE[mode])
    return codes, scales


def dequantize(codes, scales):
    """Inverse of :func:`quantize` (up to rounding): float32 rows.

    This exact op order is the bit-exactness contract every kernel,
    ref twin, and lax fallback replicates in-block.
    """
    return codes.astype(jnp.float32) * scales[..., None].astype(jnp.float32)
