"""Pure-jnp oracles for gridder / degridder (complex math)."""
import jax.numpy as jnp

TWO_PI = 2.0 * jnp.pi


def _phasor(lm, uv):
    # (S, P, V) phase matrix
    phase = TWO_PI * jnp.einsum("pc,svc->spv", lm, uv)
    return jnp.exp(1j * phase.astype(jnp.float32))


def gridder_ref(lm, uv, vis):
    """lm (P,2), uv (S,V,2), vis (S,V,2) -> (S,P,2)."""
    ph = _phasor(lm, uv)                               # (S,P,V)
    v = (vis[..., 0] + 1j * vis[..., 1]).astype(ph.dtype)
    sub = jnp.einsum("spv,sv->sp", ph, v)
    return jnp.stack([sub.real, sub.imag], axis=-1).astype(jnp.float32)


def degridder_ref(lm, uv, subgrids):
    """lm (P,2), uv (S,V,2), subgrids (S,P,2) -> (S,V,2)."""
    ph = _phasor(lm, uv)                               # (S,P,V)
    g = (subgrids[..., 0] + 1j * subgrids[..., 1]).astype(ph.dtype)
    vis = jnp.einsum("spv,sp->sv", jnp.conj(ph), g)
    return jnp.stack([vis.real, vis.imag], axis=-1).astype(jnp.float32)
