"""GRIDDER / DEGRIDDER — image-domain-gridding kernels (paper ref. [2]).

The computational core of IDG: every visibility v with baseline
coordinates (u, v) contributes ``vis_v * exp(2 pi i (u x_p + v y_p))`` to
every pixel p of a subgrid (gridder); the degridder is the adjoint
(predict visibilities from a subgrid).

TPU adaptation (DESIGN.md §4): the CUDA original assigns one thread per
pixel and loops visibilities in registers.  Here the pixel axis is the
MXU row dim: per (subgrid, vis-block) grid step, the phase matrix
(P, bv) = lm (P, 2) @ uv (2, bv) is built by one small matmul, sin/cos on
the VPU, and the accumulation Σ_v phasor_v vis_v is two (P, bv) @ (bv, 2)
MXU matmuls into an fp32 VMEM accumulator that stays resident across the
visibility sweep (same K-accumulation idiom as gemm).  Complex numbers
are real/imag planes — TPUs have no complex MXU type.

Shapes: lm (P, 2) pixel coords; uv (S, V, 2); vis (S, V, 2) re/im.
Out: subgrids (S, P, 2).  P and V multiples of 128 (pad outside).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

TWO_PI = 2.0 * math.pi


def _gridder_kernel(lm_ref, uv_ref, vis_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    lm = lm_ref[...]                    # (P, 2)
    uv = uv_ref[0]                      # (bv, 2)
    vis = vis_ref[0]                    # (bv, 2) re/im
    phase = TWO_PI * jnp.dot(lm, uv.T, preferred_element_type=jnp.float32)
    c, s = jnp.cos(phase), jnp.sin(phase)           # (P, bv)
    vr, vi = vis[:, 0], vis[:, 1]
    # (vr + i vi) * (c + i s) summed over v
    re = jnp.dot(c, vr[:, None], preferred_element_type=jnp.float32) \
        - jnp.dot(s, vi[:, None], preferred_element_type=jnp.float32)
    im = jnp.dot(s, vr[:, None], preferred_element_type=jnp.float32) \
        + jnp.dot(c, vi[:, None], preferred_element_type=jnp.float32)
    o_ref[0] += jnp.concatenate([re, im], axis=1)


def gridder_pallas(lm, uv, vis, block_v: int = 128,
                   interpret: bool = False):
    s, v, _ = uv.shape
    p = lm.shape[0]
    bv = min(block_v, v)
    grid = (s, v // bv)
    return pl.pallas_call(
        _gridder_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, 2), lambda i, k: (0, 0)),
            pl.BlockSpec((1, bv, 2), lambda i, k: (i, k, 0)),
            pl.BlockSpec((1, bv, 2), lambda i, k: (i, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, p, 2), lambda i, k: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, p, 2), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lm, uv, vis)


def _degridder_kernel(lm_ref, uv_ref, sub_ref, o_ref):
    lm = lm_ref[...]                    # (P, 2)
    uv = uv_ref[0]                      # (bv, 2)
    sub = sub_ref[0]                    # (P, 2)
    phase = TWO_PI * jnp.dot(uv, lm.T, preferred_element_type=jnp.float32)
    c, s = jnp.cos(phase), jnp.sin(phase)           # (bv, P)
    gr, gi = sub[:, 0], sub[:, 1]
    # adjoint: conj phasor — vis_v = sum_p (gr + i gi) * (c - i s)
    re = jnp.dot(c, gr[:, None], preferred_element_type=jnp.float32) \
        + jnp.dot(s, gi[:, None], preferred_element_type=jnp.float32)
    im = jnp.dot(c, gi[:, None], preferred_element_type=jnp.float32) \
        - jnp.dot(s, gr[:, None], preferred_element_type=jnp.float32)
    o_ref[0] = jnp.concatenate([re, im], axis=1)


def degridder_pallas(lm, uv, subgrids, block_v: int = 128,
                     interpret: bool = False):
    s, v, _ = uv.shape
    p = lm.shape[0]
    bv = min(block_v, v)
    grid = (s, v // bv)
    return pl.pallas_call(
        _degridder_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, 2), lambda i, k: (0, 0)),
            pl.BlockSpec((1, bv, 2), lambda i, k: (i, k, 0)),
            pl.BlockSpec((1, p, 2), lambda i, k: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bv, 2), lambda i, k: (i, k, 0)),
        out_shape=jax.ShapeDtypeStruct((s, v, 2), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(lm, uv, subgrids)
