from repro.kernels.gridder.ops import degridder, gridder
from repro.kernels.gridder.ref import degridder_ref, gridder_ref

__all__ = ["gridder", "gridder_ref", "degridder", "degridder_ref"]
