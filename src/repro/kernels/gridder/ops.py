"""jit'd wrappers for gridder / degridder."""
import functools

import jax

from repro.kernels.gridder.gridder import degridder_pallas, gridder_pallas


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def gridder(lm, uv, vis, block_v: int = 128, interpret: bool = False):
    return gridder_pallas(lm, uv, vis, block_v=block_v, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def degridder(lm, uv, subgrids, block_v: int = 128,
              interpret: bool = False):
    return degridder_pallas(lm, uv, subgrids, block_v=block_v,
                            interpret=interpret)
