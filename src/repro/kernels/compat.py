"""Pallas-TPU API compatibility.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` across
jax releases; resolve whichever this interpreter ships so the kernels
run on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
