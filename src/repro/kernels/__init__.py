"""Pallas TPU kernels.

The paper's Fig. 2 benchmark set (its compute hot-spots), re-tiled for the
TPU memory hierarchy (HBM -> VMEM blocks -> MXU/VPU), plus the framework's
own perf-critical kernel (flash attention):

  fma32            FLOP burner — compute-roofline probe
  stream           triad a + s*b — HBM-bandwidth probe
  gemm             tiled matmul with K-axis accumulation — MXU probe
  jacobi2d         5-point stencil, row-block halo — VMEM-reuse probe
  gridder          IDG-style visibility -> subgrid accumulation
  degridder        adjoint of gridder
  flash_attention  blockwise online-softmax attention (GQA/causal/window)
  cache_update     per-row KV-cache scatter (continuous-batching decode)
  decode_attention length-aware flash-decode: one token vs a full cache,
                   per-row cur_len via scalar prefetch skips KV blocks
                   beyond each row's prefix before their HBM reads issue

Every kernel ships ops.py (jit'd wrapper; interpret= for CPU) and ref.py
(pure-jnp oracle); tests sweep shapes/dtypes and assert_allclose against
the oracle in interpret mode.  The compiled path is TPU-only by design.
"""
