"""Constants shared across the kernel families and their model callers.

``NEG_INF`` is the additive logit mask used by every attention path
(dense, chunked, flash, flash-decode).  It is deliberately a large
finite value rather than ``-inf``: ``exp(NEG_INF - m)`` underflows to
exactly ``0.0`` in fp32 for any realistic running max ``m``, so a fully
masked score contributes nothing to an online-softmax accumulator, while
``-inf`` would poison it with NaNs through ``-inf - (-inf)``.
"""

NEG_INF = -2.0 ** 30

# Default KV tiling for the cache-sweeping kernels (flash-decode,
# chunked-prefill): one lane-width-aligned block per online-softmax
# fold.  Callers tune per shape via ``block_k=``; ``pick_block_k``
# degrades it to a divisor of odd cache sizes.
DEFAULT_BLOCK_K = 128

