"""Dispatching wrapper for the per-row cache scatter.

``cache_update`` accepts caches with arbitrary trailing dims —
(B, C, KVH, hd) attention K/V, (B, C, R) MLA latents — flattens them to
the kernel's (B, C, F) layout, and routes to the Pallas scatter on TPU
or the ``vmap``'d ``dynamic_update_slice`` oracle elsewhere.

``impl`` — "auto" (Pallas iff the default backend is TPU), "pallas",
"pallas_interpret" (CPU parity testing), or "lax".  The env var
``PMT_CACHE_UPDATE_IMPL`` overrides "auto" for experiments.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.cache_update.cache_update import (
    cache_update_pallas, paged_cache_update_pallas)
from repro.kernels.cache_update.ref import (cache_update_ref,
                                            paged_cache_update_ref)


def _resolve(impl: str) -> str:
    if impl == "auto":
        impl = os.environ.get("PMT_CACHE_UPDATE_IMPL", "auto")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "lax"
    return impl


def cache_update(cache: jnp.ndarray, new: jnp.ndarray, slots: jnp.ndarray,
                 impl: str = "auto") -> jnp.ndarray:
    """Write ``new[b, 0]`` at ``cache[b, slots[b]]`` for every batch row.

    cache: (B, C, *rest)   new: (B, 1, *rest)   slots: (B,) int32.
    """
    impl = _resolve(impl)
    if impl == "lax":
        return cache_update_ref(cache, new, slots)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown cache_update impl {impl!r}")
    b, c = cache.shape[:2]
    flat = cache.reshape(b, c, -1)
    out = cache_update_pallas(flat, new.astype(cache.dtype).reshape(b, 1, -1),
                              slots, interpret=impl == "pallas_interpret")
    return out.reshape(cache.shape)


def paged_cache_update(pool: jnp.ndarray, new: jnp.ndarray,
                       page_table: jnp.ndarray, starts: jnp.ndarray,
                       valids: jnp.ndarray, impl: str = "auto") -> jnp.ndarray:
    """Write ``new[b, t]`` at logical position ``starts[b] + t`` of row
    ``b``'s paged cache, for ``t < valids[b]`` (masked rows land in the
    scratch page 0, whose content is undefined).

    pool: (P, page_size, *rest) physical pages shared by all rows.
    new: (B, T, *rest)   page_table: (B, NB) int32   starts/valids: (B,).
    One call covers both paged write paths: decode (T == 1) and chunked
    prefill (T == chunk).  Dispatches on ``PMT_CACHE_UPDATE_IMPL`` like
    ``cache_update``.
    """
    impl = _resolve(impl)
    if impl == "lax":
        return paged_cache_update_ref(pool, new, page_table, starts, valids)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown cache_update impl {impl!r}")
    p, ps = pool.shape[:2]
    b, t = new.shape[:2]
    out = paged_cache_update_pallas(
        pool.reshape(p, ps, -1), new.astype(pool.dtype).reshape(b, t, -1),
        page_table, starts, valids, interpret=impl == "pallas_interpret")
    return out.reshape(pool.shape)
