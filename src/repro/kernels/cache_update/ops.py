"""Dispatching wrapper for the per-row cache scatter.

``cache_update`` accepts caches with arbitrary trailing dims —
(B, C, KVH, hd) attention K/V, (B, C, R) MLA latents — flattens them to
the kernel's (B, C, F) layout, and routes to the Pallas scatter on TPU
or the ``vmap``'d ``dynamic_update_slice`` oracle elsewhere.

``impl`` — "auto" (Pallas iff the default backend is TPU), "pallas",
"pallas_interpret" (CPU parity testing), or "lax".  The env var
``PMT_CACHE_UPDATE_IMPL`` overrides "auto" for experiments.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.cache_update.cache_update import (
    cache_update_pallas, paged_cache_update_pallas,
    quant_cache_update_pallas, quant_paged_cache_update_pallas)
from repro.kernels.cache_update.ref import (cache_update_ref,
                                            paged_cache_update_ref,
                                            quant_cache_update_ref,
                                            quant_paged_cache_update_ref)


def _resolve(impl: str) -> str:
    if impl == "auto":
        impl = os.environ.get("PMT_CACHE_UPDATE_IMPL", "auto")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "lax"
    return impl


def cache_update(cache: jnp.ndarray, new: jnp.ndarray, slots: jnp.ndarray,
                 impl: str = "auto") -> jnp.ndarray:
    """Write ``new[b, 0]`` at ``cache[b, slots[b]]`` for every batch row.

    cache: (B, C, *rest)   new: (B, 1, *rest)   slots: (B,) int32.
    """
    impl = _resolve(impl)
    if impl == "lax":
        return cache_update_ref(cache, new, slots)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown cache_update impl {impl!r}")
    b, c = cache.shape[:2]
    flat = cache.reshape(b, c, -1)
    out = cache_update_pallas(flat, new.astype(cache.dtype).reshape(b, 1, -1),
                              slots, interpret=impl == "pallas_interpret")
    return out.reshape(cache.shape)


def paged_cache_update(pool: jnp.ndarray, new: jnp.ndarray,
                       page_table: jnp.ndarray, starts: jnp.ndarray,
                       valids: jnp.ndarray, impl: str = "auto") -> jnp.ndarray:
    """Write ``new[b, t]`` at logical position ``starts[b] + t`` of row
    ``b``'s paged cache, for ``t < valids[b]`` (masked rows land in the
    scratch page 0, whose content is undefined).

    pool: (P, page_size, *rest) physical pages shared by all rows.
    new: (B, T, *rest)   page_table: (B, NB) int32   starts/valids: (B,).
    One call covers both paged write paths: decode (T == 1) and chunked
    prefill (T == chunk).  Dispatches on ``PMT_CACHE_UPDATE_IMPL`` like
    ``cache_update``.
    """
    impl = _resolve(impl)
    if impl == "lax":
        return paged_cache_update_ref(pool, new, page_table, starts, valids)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown cache_update impl {impl!r}")
    p, ps = pool.shape[:2]
    b, t = new.shape[:2]
    out = paged_cache_update_pallas(
        pool.reshape(p, ps, -1), new.astype(pool.dtype).reshape(b, t, -1),
        page_table, starts, valids, interpret=impl == "pallas_interpret")
    return out.reshape(pool.shape)


# -- quantized writes (codes + per-row scales) --------------------------------

def _quant_heads(cache) -> int:
    """Rows per token: product of the dims between position and the
    quantized last axis — KVH for attention K/V, 1 for MLA latents."""
    h = 1
    for n in cache.shape[2:-1]:
        h *= n
    return h


def quant_cache_update(cache: jnp.ndarray, scales: jnp.ndarray,
                       new: jnp.ndarray, slots: jnp.ndarray, mode: str,
                       impl: str = "auto"):
    """Quantize ``new[b, 0]`` (per-row absmax over the last axis, see
    ``kernels/quant``) and write codes + scales at ``cache[b, slots[b]]``
    / ``scales[b, slots[b]]``.

    cache: (B, C, *rest) codes   scales: (B, C, *rest[:-1]) float32
    new: (B, 1, *rest) full precision   slots: (B,) int32.
    Returns ``(cache, scales)``.  The Pallas path fuses the quantization
    into the scatter (one program per row computes its own scale);
    "lax" quantizes the row then runs two oracle scatters — bit-
    identical results either way.
    """
    impl = _resolve(impl)
    if impl == "lax":
        return quant_cache_update_ref(cache, scales, new, slots, mode)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown cache_update impl {impl!r}")
    b, c = cache.shape[:2]
    h, d = _quant_heads(cache), cache.shape[-1]
    out, s_out = quant_cache_update_pallas(
        cache.reshape(b, c, h, d), scales.reshape(b, c, h),
        new.reshape(b, 1, h, d), slots, mode,
        interpret=impl == "pallas_interpret")
    return out.reshape(cache.shape), s_out.reshape(scales.shape)


def quant_paged_cache_update(pool: jnp.ndarray, scales: jnp.ndarray,
                             new: jnp.ndarray, page_table: jnp.ndarray,
                             starts: jnp.ndarray, valids: jnp.ndarray,
                             mode: str, impl: str = "auto"):
    """Paged twin of :func:`quant_cache_update`: codes land in ``pool``
    and scales in the page-aligned ``scales`` pool through the same
    page-table indirection (masked rows -> scratch page 0 in both).

    pool: (P, page_size, *rest)   scales: (P, page_size, *rest[:-1])
    new: (B, T, *rest)   page_table: (B, NB)   starts/valids: (B,).
    Returns ``(pool, scales)``.
    """
    impl = _resolve(impl)
    if impl == "lax":
        return quant_paged_cache_update_ref(pool, scales, new, page_table,
                                            starts, valids, mode)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown cache_update impl {impl!r}")
    p, ps = pool.shape[:2]
    b, t = new.shape[:2]
    h, d = _quant_heads(pool), pool.shape[-1]
    out, s_out = quant_paged_cache_update_pallas(
        pool.reshape(p, ps, h, d), scales.reshape(p, ps, h),
        new.reshape(b, t, h, d), page_table, starts, valids, mode,
        interpret=impl == "pallas_interpret")
    return out.reshape(pool.shape), s_out.reshape(scales.shape)
