from repro.kernels.cache_update.ops import (cache_update,
                                            paged_cache_update,
                                            quant_cache_update,
                                            quant_paged_cache_update)
from repro.kernels.cache_update.cache_update import cache_update_pallas
from repro.kernels.cache_update.ref import cache_update_ref

__all__ = ["cache_update", "paged_cache_update", "quant_cache_update",
           "quant_paged_cache_update", "cache_update_pallas",
           "cache_update_ref"]
