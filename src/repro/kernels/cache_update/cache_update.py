"""Per-row KV-cache scatter — the continuous-batching cache kernel.

Sequence-level continuous batching gives every batch slot its own
position counter, so one decode step writes row ``b``'s new key/value at
``slots[b]`` — a *different* cache offset per row.  XLA's
``dynamic_update_slice`` only takes one start index per axis, so the
stock lowering is a batch of B separate single-row updates (or a one-hot
scatter that touches the whole cache).  This kernel does the write as a
true scatter: the grid walks the batch, the output BlockSpec's index map
reads the slot from scalar-prefetch SMEM, and each program DMAs exactly
one (1, 1, F) row into place.  The cache operand is aliased to the
output, so untouched rows are never copied.

Layout note: callers flatten trailing dims to one lane axis F
(``ops.cache_update`` handles the reshape).  On real TPUs F should be a
multiple of 128 for an aligned store; the serve path's correctness gate
runs in interpret mode where no such constraint applies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(slots_ref, new_ref, cache_ref, out_ref):
    # cache_ref is the aliased full cache (never read): the alias keeps
    # every row this program does not own; only the slot row is written.
    del slots_ref, cache_ref
    out_ref[...] = new_ref[...]


def cache_update_pallas(cache: jnp.ndarray, new: jnp.ndarray,
                        slots: jnp.ndarray,
                        interpret: bool = False) -> jnp.ndarray:
    """Scatter ``new[b, 0]`` into ``cache[b, slots[b]]`` for every row.

    cache: (B, C, F)   new: (B, 1, F)   slots: (B,) int32 in [0, C).
    Returns the updated (B, C, F) cache; the input buffer is aliased.
    """
    b, _, f = cache.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, 1, f), lambda i, slots: (i, 0, 0)),  # new row
            pl.BlockSpec(memory_space=pl.ANY),                    # cache
        ],
        out_specs=pl.BlockSpec((1, 1, f), lambda i, slots: (i, slots[i], 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        # index 2 counts the scalar-prefetch operand: (slots, new, cache)
        input_output_aliases={2: 0},
        interpret=interpret,
    )(slots.astype(jnp.int32), new.astype(cache.dtype), cache)
