"""Per-row KV-cache scatter — the continuous-batching cache kernel.

Sequence-level continuous batching gives every batch slot its own
position counter, so one decode step writes row ``b``'s new key/value at
``slots[b]`` — a *different* cache offset per row.  XLA's
``dynamic_update_slice`` only takes one start index per axis, so the
stock lowering is a batch of B separate single-row updates (or a one-hot
scatter that touches the whole cache).  This kernel does the write as a
true scatter: the grid walks the batch, the output BlockSpec's index map
reads the slot from scalar-prefetch SMEM, and each program DMAs exactly
one (1, 1, F) row into place.  The cache operand is aliased to the
output, so untouched rows are never copied.

Layout note: callers flatten trailing dims to one lane axis F
(``ops.cache_update`` handles the reshape).  On real TPUs F should be a
multiple of 128 for an aligned store; the serve path's correctness gate
runs in interpret mode where no such constraint applies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import quant


def _scatter_kernel(slots_ref, new_ref, cache_ref, out_ref):
    # cache_ref is the aliased full cache (never read): the alias keeps
    # every row this program does not own; only the slot row is written.
    del slots_ref, cache_ref
    out_ref[...] = new_ref[...]


def _paged_scatter_kernel(pt_ref, starts_ref, valids_ref, new_ref, pool_ref,
                          out_ref):
    # pool_ref is the aliased physical pool (never read): the alias
    # keeps every row this program does not own; the out BlockSpec's
    # index map already routed this program's row (or the scratch page,
    # for masked rows) — see paged_cache_update_pallas.
    del pt_ref, starts_ref, valids_ref, pool_ref
    out_ref[...] = new_ref[...]


def paged_cache_update_pallas(pool: jnp.ndarray, new: jnp.ndarray,
                              page_table: jnp.ndarray, starts: jnp.ndarray,
                              valids: jnp.ndarray,
                              interpret: bool = False) -> jnp.ndarray:
    """Paged scatter: row ``t`` of ``new[b]`` lands at logical position
    ``starts[b] + t`` of row ``b``'s paged cache.

    pool: (P, page_size, F) physical pages shared by all rows.
    new: (B, T, F) rows to write.  page_table: (B, NB) int32 logical
    block -> physical page.  starts: (B,) int32 first logical position.
    valids: (B,) int32 — rows ``t >= valids[b]`` are masked: the index
    map routes them to the scratch page 0 (whose content is undefined
    by contract) so pad rows never touch real pages.

    The same kernel covers both paged write paths: decode (T == 1,
    valids == 1) and chunked prefill (T == chunk, per-row valid
    lengths).  Returns the updated pool; the input pool is aliased.
    """
    p, ps, f = pool.shape
    b, t, _ = new.shape
    nb = page_table.shape[1]

    def new_map(bi, ti, pt, starts, valids):
        return (bi, ti, 0)

    def out_map(bi, ti, pt, starts, valids):
        # Page-table indirection in the index map: the scalar-prefetch
        # page table turns (logical position) into (physical page, row).
        # Masked rows go to scratch page 0 row 0 — revisits of that
        # index collapse into at most one junk DMA per (b) sweep.
        pos = jnp.minimum(starts[bi] + ti, nb * ps - 1)
        ok = ti < valids[bi]
        page = jnp.where(ok, pt[bi, pos // ps], 0)
        row = jnp.where(ok, pos % ps, 0)
        return (page, row, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, t),
        in_specs=[
            pl.BlockSpec((1, 1, f), new_map),                 # new row
            pl.BlockSpec(memory_space=pl.ANY),                # pool
        ],
        out_specs=pl.BlockSpec((1, 1, f), out_map),
    )
    return pl.pallas_call(
        _paged_scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        # index 4 counts the scalar-prefetch operands:
        # (page_table, starts, valids, new, pool)
        input_output_aliases={4: 0},
        interpret=interpret,
    )(page_table.astype(jnp.int32), starts.astype(jnp.int32),
      valids.astype(jnp.int32), new.astype(pool.dtype), pool)


def cache_update_pallas(cache: jnp.ndarray, new: jnp.ndarray,
                        slots: jnp.ndarray,
                        interpret: bool = False) -> jnp.ndarray:
    """Scatter ``new[b, 0]`` into ``cache[b, slots[b]]`` for every row.

    cache: (B, C, F)   new: (B, 1, F)   slots: (B,) int32 in [0, C).
    Returns the updated (B, C, F) cache; the input buffer is aliased.
    """
    b, _, f = cache.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, 1, f), lambda i, slots: (i, 0, 0)),  # new row
            pl.BlockSpec(memory_space=pl.ANY),                    # cache
        ],
        out_specs=pl.BlockSpec((1, 1, f), lambda i, slots: (i, slots[i], 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        # index 2 counts the scalar-prefetch operand: (slots, new, cache)
        input_output_aliases={2: 0},
        interpret=interpret,
    )(slots.astype(jnp.int32), new.astype(cache.dtype), cache)


# -- fused quantize + scatter (quantized KV caches) ---------------------------
#
# The quantized cache stores low-bit codes plus one float32 absmax
# scale per (token, head) row (kernels/quant.py).  These twins fuse the
# quantization into the scatter: each program reads its full-precision
# row, computes the per-head absmax scale in-register, and DMAs the
# codes row and the scale row into their (aliased) caches — so a decode
# step's cache write streams the incoming row once, at full precision,
# and everything it stores is already quantized.

def _quant_scatter_kernel(slots_ref, new_ref, cache_ref, scales_ref,
                          out_ref, s_out_ref, *, mode):
    del slots_ref, cache_ref, scales_ref          # aliased, never read
    qm = quant.qmax(mode)
    x = new_ref[0, 0].astype(jnp.float32)         # (H, D)
    amax = jnp.max(jnp.abs(x), axis=-1)           # (H,)
    s = jnp.maximum(amax, quant.SCALE_EPS) * quant.qmax_inv(mode)
    y = x / s[:, None]
    if mode == "int8":
        y = jnp.round(y)
    out_ref[0, 0] = jnp.clip(y, -qm, qm).astype(out_ref.dtype)
    s_out_ref[0, 0] = s


def quant_cache_update_pallas(cache: jnp.ndarray, scales: jnp.ndarray,
                              new: jnp.ndarray, slots: jnp.ndarray,
                              mode: str,
                              interpret: bool = False):
    """Quantize ``new[b, 0]`` per head row and scatter codes + scales at
    ``slots[b]``.

    cache: (B, C, H, D) codes   scales: (B, C, H) float32
    new: (B, 1, H, D) full precision   slots: (B,) int32 in [0, C).
    Returns (cache, scales) updated; both input buffers are aliased.
    """
    b, _, h, d = cache.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, 1, h, d), lambda i, slots: (i, 0, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),                # cache
            pl.BlockSpec(memory_space=pl.ANY),                # scales
        ],
        out_specs=[
            pl.BlockSpec((1, 1, h, d),
                         lambda i, slots: (i, slots[i], 0, 0)),
            pl.BlockSpec((1, 1, h), lambda i, slots: (i, slots[i], 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_quant_scatter_kernel, mode=mode),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(cache.shape, cache.dtype),
                   jax.ShapeDtypeStruct(scales.shape, scales.dtype)],
        # operands: (slots, new, cache, scales)
        input_output_aliases={2: 0, 3: 1},
        interpret=interpret,
    )(slots.astype(jnp.int32), new, cache, scales)


def _quant_paged_scatter_kernel(pt_ref, starts_ref, valids_ref, new_ref,
                                pool_ref, spool_ref, out_ref, s_out_ref,
                                *, mode):
    del pt_ref, starts_ref, valids_ref, pool_ref, spool_ref
    qm = quant.qmax(mode)
    x = new_ref[0, 0].astype(jnp.float32)         # (H, D)
    amax = jnp.max(jnp.abs(x), axis=-1)
    s = jnp.maximum(amax, quant.SCALE_EPS) * quant.qmax_inv(mode)
    y = x / s[:, None]
    if mode == "int8":
        y = jnp.round(y)
    out_ref[0, 0] = jnp.clip(y, -qm, qm).astype(out_ref.dtype)
    s_out_ref[0, 0] = s


def quant_paged_cache_update_pallas(pool: jnp.ndarray, scales: jnp.ndarray,
                                    new: jnp.ndarray,
                                    page_table: jnp.ndarray,
                                    starts: jnp.ndarray, valids: jnp.ndarray,
                                    mode: str,
                                    interpret: bool = False):
    """Paged twin of :func:`quant_cache_update_pallas`: quantize row
    ``t`` of ``new[b]`` and land codes + scale at logical position
    ``starts[b] + t`` through the page table (masked rows -> scratch
    page 0, same contract as ``paged_cache_update_pallas`` — the scale
    pool pages alongside its code pool, so the per-row scales are
    page-granular and travel with the page through prefix sharing).

    pool: (P, page_size, H, D) codes   scales: (P, page_size, H) f32
    new: (B, T, H, D)   page_table: (B, NB) int32   starts/valids: (B,).
    Returns (pool, scales) updated; both input buffers are aliased.
    """
    p, ps, h, d = pool.shape
    b, t = new.shape[:2]
    nb = page_table.shape[1]

    def new_map(bi, ti, pt, starts, valids):
        return (bi, ti, 0, 0)

    def _route(bi, ti, pt, starts, valids):
        pos = jnp.minimum(starts[bi] + ti, nb * ps - 1)
        ok = ti < valids[bi]
        page = jnp.where(ok, pt[bi, pos // ps], 0)
        row = jnp.where(ok, pos % ps, 0)
        return page, row

    def out_map(bi, ti, pt, starts, valids):
        page, row = _route(bi, ti, pt, starts, valids)
        return (page, row, 0, 0)

    def s_out_map(bi, ti, pt, starts, valids):
        page, row = _route(bi, ti, pt, starts, valids)
        return (page, row, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, t),
        in_specs=[
            pl.BlockSpec((1, 1, h, d), new_map),              # new row
            pl.BlockSpec(memory_space=pl.ANY),                # pool
            pl.BlockSpec(memory_space=pl.ANY),                # scale pool
        ],
        out_specs=[
            pl.BlockSpec((1, 1, h, d), out_map),
            pl.BlockSpec((1, 1, h), s_out_map),
        ],
    )
    return pl.pallas_call(
        functools.partial(_quant_paged_scatter_kernel, mode=mode),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(pool.shape, pool.dtype),
                   jax.ShapeDtypeStruct(scales.shape, scales.dtype)],
        # operands: (page_table, starts, valids, new, pool, scales)
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(page_table.astype(jnp.int32), starts.astype(jnp.int32),
      valids.astype(jnp.int32), new, pool, scales)
