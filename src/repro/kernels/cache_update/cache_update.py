"""Per-row KV-cache scatter — the continuous-batching cache kernel.

Sequence-level continuous batching gives every batch slot its own
position counter, so one decode step writes row ``b``'s new key/value at
``slots[b]`` — a *different* cache offset per row.  XLA's
``dynamic_update_slice`` only takes one start index per axis, so the
stock lowering is a batch of B separate single-row updates (or a one-hot
scatter that touches the whole cache).  This kernel does the write as a
true scatter: the grid walks the batch, the output BlockSpec's index map
reads the slot from scalar-prefetch SMEM, and each program DMAs exactly
one (1, 1, F) row into place.  The cache operand is aliased to the
output, so untouched rows are never copied.

Layout note: callers flatten trailing dims to one lane axis F
(``ops.cache_update`` handles the reshape).  On real TPUs F should be a
multiple of 128 for an aligned store; the serve path's correctness gate
runs in interpret mode where no such constraint applies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(slots_ref, new_ref, cache_ref, out_ref):
    # cache_ref is the aliased full cache (never read): the alias keeps
    # every row this program does not own; only the slot row is written.
    del slots_ref, cache_ref
    out_ref[...] = new_ref[...]


def _paged_scatter_kernel(pt_ref, starts_ref, valids_ref, new_ref, pool_ref,
                          out_ref):
    # pool_ref is the aliased physical pool (never read): the alias
    # keeps every row this program does not own; the out BlockSpec's
    # index map already routed this program's row (or the scratch page,
    # for masked rows) — see paged_cache_update_pallas.
    del pt_ref, starts_ref, valids_ref, pool_ref
    out_ref[...] = new_ref[...]


def paged_cache_update_pallas(pool: jnp.ndarray, new: jnp.ndarray,
                              page_table: jnp.ndarray, starts: jnp.ndarray,
                              valids: jnp.ndarray,
                              interpret: bool = False) -> jnp.ndarray:
    """Paged scatter: row ``t`` of ``new[b]`` lands at logical position
    ``starts[b] + t`` of row ``b``'s paged cache.

    pool: (P, page_size, F) physical pages shared by all rows.
    new: (B, T, F) rows to write.  page_table: (B, NB) int32 logical
    block -> physical page.  starts: (B,) int32 first logical position.
    valids: (B,) int32 — rows ``t >= valids[b]`` are masked: the index
    map routes them to the scratch page 0 (whose content is undefined
    by contract) so pad rows never touch real pages.

    The same kernel covers both paged write paths: decode (T == 1,
    valids == 1) and chunked prefill (T == chunk, per-row valid
    lengths).  Returns the updated pool; the input pool is aliased.
    """
    p, ps, f = pool.shape
    b, t, _ = new.shape
    nb = page_table.shape[1]

    def new_map(bi, ti, pt, starts, valids):
        return (bi, ti, 0)

    def out_map(bi, ti, pt, starts, valids):
        # Page-table indirection in the index map: the scalar-prefetch
        # page table turns (logical position) into (physical page, row).
        # Masked rows go to scratch page 0 row 0 — revisits of that
        # index collapse into at most one junk DMA per (b) sweep.
        pos = jnp.minimum(starts[bi] + ti, nb * ps - 1)
        ok = ti < valids[bi]
        page = jnp.where(ok, pt[bi, pos // ps], 0)
        row = jnp.where(ok, pos % ps, 0)
        return (page, row, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, t),
        in_specs=[
            pl.BlockSpec((1, 1, f), new_map),                 # new row
            pl.BlockSpec(memory_space=pl.ANY),                # pool
        ],
        out_specs=pl.BlockSpec((1, 1, f), out_map),
    )
    return pl.pallas_call(
        _paged_scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        # index 4 counts the scalar-prefetch operands:
        # (page_table, starts, valids, new, pool)
        input_output_aliases={4: 0},
        interpret=interpret,
    )(page_table.astype(jnp.int32), starts.astype(jnp.int32),
      valids.astype(jnp.int32), new.astype(pool.dtype), pool)


def cache_update_pallas(cache: jnp.ndarray, new: jnp.ndarray,
                        slots: jnp.ndarray,
                        interpret: bool = False) -> jnp.ndarray:
    """Scatter ``new[b, 0]`` into ``cache[b, slots[b]]`` for every row.

    cache: (B, C, F)   new: (B, 1, F)   slots: (B,) int32 in [0, C).
    Returns the updated (B, C, F) cache; the input buffer is aliased.
    """
    b, _, f = cache.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, 1, f), lambda i, slots: (i, 0, 0)),  # new row
            pl.BlockSpec(memory_space=pl.ANY),                    # cache
        ],
        out_specs=pl.BlockSpec((1, 1, f), lambda i, slots: (i, slots[i], 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        # index 2 counts the scalar-prefetch operand: (slots, new, cache)
        input_output_aliases={2: 0},
        interpret=interpret,
    )(slots.astype(jnp.int32), new.astype(cache.dtype), cache)
