"""Pure-jax oracle for the per-row cache scatter.

One ``dynamic_update_slice`` per batch row under ``vmap`` — exactly the
semantics the Pallas kernel must reproduce (and the serve engine's
fallback path where Pallas is unavailable, e.g. CPU/GPU backends).
"""
import jax
import jax.numpy as jnp


def cache_update_ref(cache: jnp.ndarray, new: jnp.ndarray,
                     slots: jnp.ndarray) -> jnp.ndarray:
    """cache: (B, C, *rest)  new: (B, 1, *rest)  slots: (B,) int32."""

    def row(c, n, s):
        starts = (s,) + (0,) * (c.ndim - 1)
        return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), starts)

    return jax.vmap(row)(cache, new, slots.astype(jnp.int32))
