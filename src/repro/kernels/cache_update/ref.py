"""Pure-jax oracle for the per-row cache scatter.

One ``dynamic_update_slice`` per batch row under ``vmap`` — exactly the
semantics the Pallas kernel must reproduce (and the serve engine's
fallback path where Pallas is unavailable, e.g. CPU/GPU backends).

The ``quant_*`` twins quantize with :func:`repro.kernels.quant.quantize`
— per-row elementwise ops, so quantizing the whole chunk here and one
row per program in the kernel produces bit-identical codes and scales.
"""
import jax
import jax.numpy as jnp

from repro.kernels import quant


def cache_update_ref(cache: jnp.ndarray, new: jnp.ndarray,
                     slots: jnp.ndarray) -> jnp.ndarray:
    """cache: (B, C, *rest)  new: (B, 1, *rest)  slots: (B,) int32."""

    def row(c, n, s):
        starts = (s,) + (0,) * (c.ndim - 1)
        return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), starts)

    return jax.vmap(row)(cache, new, slots.astype(jnp.int32))


def paged_cache_update_ref(pool: jnp.ndarray, new: jnp.ndarray,
                           page_table: jnp.ndarray, starts: jnp.ndarray,
                           valids: jnp.ndarray) -> jnp.ndarray:
    """Paged-scatter oracle: one flat scatter into the page pool.

    pool: (P, page_size, *rest)  new: (B, T, *rest)
    page_table: (B, NB) int32   starts/valids: (B,) int32.

    Row ``t`` of ``new[b]`` lands at physical row
    ``page_table[b, (starts[b]+t) // ps] * ps + (starts[b]+t) % ps`` of
    the flattened pool when ``t < valids[b]``; masked rows are routed to
    scratch page 0 (row 0), whose content is undefined by contract —
    parity tests compare pools *excluding* page 0.
    """
    p, ps = pool.shape[:2]
    b, t = new.shape[:2]
    nb = page_table.shape[1]
    pos = starts.astype(jnp.int32)[:, None] + jnp.arange(t, dtype=jnp.int32)
    pos = jnp.minimum(pos, nb * ps - 1)                     # (B, T)
    ok = jnp.arange(t, dtype=jnp.int32)[None, :] < \
        valids.astype(jnp.int32)[:, None]
    page = jnp.where(ok, jnp.take_along_axis(
        page_table.astype(jnp.int32), pos // ps, axis=1), 0)
    row = jnp.where(ok, pos % ps, 0)
    flat = pool.reshape(p * ps, -1)
    out = flat.at[(page * ps + row).reshape(-1)].set(
        new.reshape(b * t, -1).astype(pool.dtype))
    return out.reshape(pool.shape)


def quant_cache_update_ref(cache: jnp.ndarray, scales: jnp.ndarray,
                           new: jnp.ndarray, slots: jnp.ndarray, mode: str):
    """Quantizing twin: quantize ``new`` per row, scatter codes into
    ``cache`` and scales into ``scales``.

    cache: (B, C, *rest) codes  scales: (B, C, *rest[:-1]) float32
    new: (B, 1, *rest) full precision  slots: (B,) int32.
    """
    codes, s = quant.quantize(new, mode)
    return (cache_update_ref(cache, codes, slots),
            cache_update_ref(scales, s, slots))


def quant_paged_cache_update_ref(pool: jnp.ndarray, scales: jnp.ndarray,
                                 new: jnp.ndarray, page_table: jnp.ndarray,
                                 starts: jnp.ndarray, valids: jnp.ndarray,
                                 mode: str):
    """Paged quantizing twin: codes land in ``pool``, scales in the
    page-aligned ``scales`` pool (same page-id space, same masking).

    pool: (P, page_size, *rest)  scales: (P, page_size, *rest[:-1])
    new: (B, T, *rest)  page_table: (B, NB)  starts/valids: (B,) int32.
    """
    codes, s = quant.quantize(new, mode)
    return (paged_cache_update_ref(pool, codes, page_table, starts, valids),
            paged_cache_update_ref(scales, s, page_table, starts, valids))
