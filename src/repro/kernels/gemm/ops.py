"""jit'd wrapper for gemm."""
import functools

import jax

from repro.kernels.gemm.gemm import gemm_pallas


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "block_k", "interpret"))
def gemm(a, b, block_m: int = 256, block_n: int = 256, block_k: int = 256,
         interpret: bool = False):
    return gemm_pallas(a, b, block_m=block_m, block_n=block_n,
                       block_k=block_k, interpret=interpret)
