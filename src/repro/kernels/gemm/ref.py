"""Pure-jnp oracle for gemm."""
import jax.numpy as jnp


def gemm_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)
