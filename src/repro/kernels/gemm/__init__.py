from repro.kernels.gemm.ops import gemm
from repro.kernels.gemm.ref import gemm_ref

__all__ = ["gemm", "gemm_ref"]
