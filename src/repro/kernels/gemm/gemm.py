"""Tiled GEMM — the paper's Polybench MXU probe, TPU-blocked.

Grid (M/bm, N/bn, K/bk) with the K axis innermost and *arbitrary*
(sequential) semantics: each (i, j) output tile stays resident in VMEM
as an fp32 accumulator across the K sweep, (bm, bk) x (bk, bn) input
tiles stream through VMEM, and the MXU sees 128-aligned matmuls with
``preferred_element_type=float32`` (bf16 in, fp32 accumulate — the TPU
equivalent of the CUDA tensor-core epilogue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _gemm_kernel(a_ref, b_ref, o_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32)


def gemm_pallas(a, b, block_m: int = 256, block_n: int = 256,
                block_k: int = 256, interpret: bool = False):
    """a: (M, K), b: (K, N) -> fp32 (M, N). Dims multiples of blocks."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
