"""jit'd wrapper for jacobi2d."""
import functools

import jax

from repro.kernels.jacobi2d.jacobi2d import jacobi2d_pallas


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def jacobi2d(x, block_h: int = 256, interpret: bool = False):
    return jacobi2d_pallas(x, block_h=block_h, interpret=interpret)
