"""Pure-jnp oracle for one Jacobi-2D sweep (interior update only)."""
import jax.numpy as jnp


def jacobi2d_ref(x):
    out = 0.2 * (x[1:-1, 1:-1] + x[:-2, 1:-1] + x[2:, 1:-1]
                 + x[1:-1, :-2] + x[1:-1, 2:])
    return x.at[1:-1, 1:-1].set(out)
