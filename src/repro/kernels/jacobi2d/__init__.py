from repro.kernels.jacobi2d.ops import jacobi2d
from repro.kernels.jacobi2d.ref import jacobi2d_ref

__all__ = ["jacobi2d", "jacobi2d_ref"]
