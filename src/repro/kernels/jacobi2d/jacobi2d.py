"""Jacobi-2D 5-point stencil (Polybench) with row-block halo exchange.

TPU adaptation of the thread-per-element CUDA stencil: the grid tiles
*rows* only (blocks are (bh, W) — full-width, lane-dim friendly), and the
vertical halo is realized by binding the SAME input array under three
BlockSpecs whose index maps point at the previous / current / next row
block.  The kernel uses only the boundary rows of the neighbor blocks;
edge blocks clamp their neighbor index and the result is masked, matching
the reference's edge-replication-free semantics (interior update only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_kernel(prev_ref, cur_ref, nxt_ref, o_ref, *, nblocks: int):
    i = pl.program_id(0)
    x = cur_ref[...]
    bh, w = x.shape

    up_edge = jnp.where(i > 0, prev_ref[-1, :], x[0, :])
    dn_edge = jnp.where(i < nblocks - 1, nxt_ref[0, :], x[-1, :])

    up = jnp.concatenate([up_edge[None, :], x[:-1, :]], axis=0)
    down = jnp.concatenate([x[1:, :], dn_edge[None, :]], axis=0)
    left = jnp.concatenate([x[:, :1], x[:, :-1]], axis=1)
    right = jnp.concatenate([x[:, 1:], x[:, -1:]], axis=1)

    out = 0.2 * (x + up + down + left + right)

    # interior-only update: boundary cells of the global array keep x
    row0 = i * bh
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bh, w), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bh, w), 1)
    total_rows = nblocks * bh
    interior = ((rows > 0) & (rows < total_rows - 1)
                & (cols > 0) & (cols < w - 1))
    o_ref[...] = jnp.where(interior, out, x)


def jacobi2d_pallas(x, block_h: int = 256, interpret: bool = False):
    """One Jacobi sweep. x: (H, W) fp32, H % block_h == 0."""
    h, w = x.shape
    bh = min(block_h, h)
    nblocks = h // bh

    def clamp(i, lo, hi):
        return jnp.clip(i, lo, hi)

    return pl.pallas_call(
        functools.partial(_jacobi_kernel, nblocks=nblocks),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((bh, w), lambda i: (clamp(i - 1, 0, nblocks - 1), 0)),
            pl.BlockSpec((bh, w), lambda i: (i, 0)),
            pl.BlockSpec((bh, w), lambda i: (clamp(i + 1, 0, nblocks - 1), 0)),
        ],
        out_specs=pl.BlockSpec((bh, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), x.dtype),
        interpret=interpret,
    )(x, x, x)
