from repro.kernels.prefill_attention.ops import (prefill_attention,
                                                 prefill_attention_lax)
from repro.kernels.prefill_attention.prefill_attention import \
    prefill_attention_pallas
from repro.kernels.prefill_attention.ref import prefill_attention_ref

__all__ = ["prefill_attention", "prefill_attention_lax",
           "prefill_attention_pallas", "prefill_attention_ref"]
