"""Model-facing chunked-prefill attention wrapper.

``prefill_attention`` accepts the framework's chunk layout — chunk
queries (B, T, H, hdq) against the chunk's own keys/values
(B, T, KVH, *) plus the request's already-written cache prefix
(B, C, KVH, *) — reshapes q to the kernel's GQA-packed
(B, KVH, T, G, hdq), and routes to:

  * ``pallas``           the chunked-prefill flash kernel (TPU),
  * ``pallas_interpret`` the same kernel in interpret mode (CPU parity
                         testing),
  * ``lax``              a fused masked-XLA fallback: one dense masked
                         softmax over [cache prefix ++ chunk].  Chunked
                         prefill is compute-bound (T queries per call),
                         so the fallback favors one fused XLA region
                         over a segment-skipping sweep (measured
                         faster; decode's single query row is the
                         opposite trade — see decode_attention_lax);
                         it matches the oracle within fp32 softmax
                         reassociation (~1 ulp).

``impl="auto"`` picks Pallas iff the default backend is TPU; the env
var ``PMT_PREFILL_ATTENTION_DISPATCH`` (values: pallas /
pallas_interpret / lax) overrides "auto" for experiments.

Numerics: the Pallas kernel is bit-exact against the blockwise ref.py
oracle (same op-for-op online softmax; skipped cache blocks are
bit-neutral updates — see ref.py).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.constants import DEFAULT_BLOCK_K, NEG_INF
from repro.kernels.prefill_attention.prefill_attention import (
    prefill_attention_paged_pallas, prefill_attention_pallas)


def _resolve(impl: str) -> str:
    if impl == "auto":
        impl = os.environ.get("PMT_PREFILL_ATTENTION_DISPATCH", "auto")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "lax"
    return impl


def prefill_attention_lax(q, k_chunk, v_chunk, k_cache, v_cache, offs, *,
                          ring: bool = False, window=None, softcap=None,
                          scale: float = 1.0, block_k: int = DEFAULT_BLOCK_K,
                          v_width=None, k_scale=None, v_scale=None):
    """Fused masked chunk attention in plain XLA.

    Same layout as the kernel: q (B, KVH, T, G, hdq), chunk k/v
    (B, T, KVH, *), cache k/v (B, C, KVH, *), offs (B,).  One dense
    masked softmax over [cache prefix ++ chunk]: chunked prefill is
    compute-bound (T queries per call), and on CPU/GPU-via-XLA the
    single fused region beats a segment-skipping sweep — T-row masks
    and per-segment rescaling cost more than the elided reads save
    (measured; decode, with its single query row, is the opposite
    case).  Length-aware read elision is the Pallas kernel's job.
    ``block_k`` is the Pallas tiling knob and is unused here.

    ``k_scale``/``v_scale``: (B, C, KVH) float32 per-row scales when the
    *cache* holds quantized codes (chunk k/v stay full precision) — the
    cache is dequantized with the shared block scales before the fused
    softmax, so the lax path agrees with the blockwise twins to fp
    reassociation like the unquantized case.
    """
    del block_k
    b, kvh, t, g, _ = q.shape
    c = k_cache.shape[1]
    if v_width is not None:
        v_cache = v_cache[..., :v_width]
        v_chunk = v_chunk[..., :v_width]
    if k_scale is not None:
        vs = k_scale if v_scale is None else v_scale
        k_cache = k_cache.astype(jnp.float32) * \
            k_scale[..., None].astype(jnp.float32)
        v_cache = v_cache.astype(jnp.float32) * \
            vs[..., None].astype(jnp.float32)
    qs = q.astype(jnp.float32) * scale
    offs = jnp.asarray(offs, jnp.int32)
    k_all = jnp.concatenate([k_cache, k_chunk], axis=1)    # (B, C+T, KVH, *)
    v_all = jnp.concatenate([v_cache, v_chunk], axis=1)
    s = jnp.einsum("bhtgd,bshd->bhtgs", qs, k_all.astype(jnp.float32))
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    off = offs[:, None, None, None, None]                  # (B,1,1,1,1)
    q_pos = jnp.arange(t, dtype=jnp.int32)[None, None, :, None, None] + off
    slots = jnp.arange(c, dtype=jnp.int32)[None, None, None, None, :]
    if ring:
        last = off - 1
        pos = last - jnp.mod(last - slots, c)
        cache_ok = (pos >= 0) & (q_pos - pos < window)     # (B,1,T,1,C)
    elif window is not None:
        # unwrapped sliding window (paged layout): slot == position,
        # window applied as an explicit mask
        cache_ok = (slots < off) & (q_pos - slots < window)
    else:
        cache_ok = jnp.broadcast_to(slots < off, (b, 1, t, 1, c))
    diff = (jnp.arange(t, dtype=jnp.int32)[:, None]
            - jnp.arange(t, dtype=jnp.int32)[None, :])     # (T, T)
    chunk_ok = diff >= 0
    if window is not None:
        chunk_ok &= diff < window
    chunk_ok = jnp.broadcast_to(chunk_ok[None, None, :, None, :],
                                (b, 1, t, 1, t))
    valid = jnp.concatenate([cache_ok, chunk_ok], axis=-1)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhtgs,bshd->bhtgd", p, v_all.astype(jnp.float32))
    return o.astype(q.dtype)


def prefill_attention_paged_lax(q, k_chunk, v_chunk, k_pool, v_pool,
                                page_table, offs, *, window=None,
                                softcap=None, scale: float = 1.0,
                                v_width=None, k_scale=None, v_scale=None):
    """Fused masked *paged* chunk attention in plain XLA.

    Gathers the logical (B, NB*page_size, KVH, *) cache view through
    the page table — the XLA spelling of the kernel's index-map
    indirection — then runs the same fused masked softmax as
    ``prefill_attention_lax`` (chunked prefill is compute-bound, so
    the one-gather copy is in the noise next to the T-query matmuls).
    Paged caches are unwrapped: ``window`` is an explicit mask.
    """
    b, kvh, t, g, _ = q.shape
    ps = k_pool.shape[1]
    nb = page_table.shape[1]
    pt = page_table.astype(jnp.int32)
    k_cache = jnp.take(k_pool, pt, axis=0).reshape(b, nb * ps, kvh,
                                                   k_pool.shape[-1])
    if v_pool is k_pool:
        v_cache = k_cache
    else:
        v_cache = jnp.take(v_pool, pt, axis=0).reshape(b, nb * ps, kvh,
                                                       v_pool.shape[-1])
    ks = vs = None
    if k_scale is not None:
        ks = jnp.take(k_scale, pt, axis=0).reshape(b, nb * ps, kvh)
        if v_scale is None or v_scale is k_scale:
            vs = ks
        else:
            vs = jnp.take(v_scale, pt, axis=0).reshape(b, nb * ps, kvh)
    return prefill_attention_lax(q, k_chunk, v_chunk, k_cache, v_cache,
                                 offs, ring=False, window=window,
                                 softcap=softcap, scale=scale,
                                 v_width=v_width, k_scale=ks, v_scale=vs)


def prefill_attention_paged(q, k_chunk, v_chunk, k_pool, v_pool, page_table,
                            offset, *, window=None, softcap=None,
                            scale: float = 1.0, v_width=None,
                            k_scale=None, v_scale=None,
                            impl: str = "auto"):
    """Chunked-prefill attention over a *paged* cache prefix.

    q: (B, T, H, hdq) chunk queries at positions ``offset[b] + i``.
    k_chunk/v_chunk: (B, T, KVH, *) — the chunk's own keys/values (NOT
    yet scattered into the pool).  k_pool/v_pool: (P, page_size, KVH, *)
    physical pages holding positions ``< offset[b]`` of every row,
    addressed through page_table (B, NB) int32.  offset: scalar or (B,)
    int32.  Paged caches store sliding-window layers unwrapped, so
    ``window`` is an explicit mask (no ``ring``).  ``v_width`` as in
    ``prefill_attention``.  ``k_scale``/``v_scale``: (P, page_size, KVH)
    float32 per-row scale pools when the code pools are quantized
    (``v_scale`` defaults to ``k_scale``).  Returns (B, T, H, hdv) in
    q.dtype.
    """
    impl = _resolve(impl)
    b, t, h, hdq = q.shape
    if k_chunk.shape[1] != t:
        raise ValueError(f"chunk keys cover {k_chunk.shape[1]} tokens but "
                         f"the query chunk has {t}")
    kvh = k_pool.shape[2]
    if h % kvh:
        raise ValueError(f"H={h} not divisible by KVH={kvh}")
    g = h // kvh
    qg = q.reshape(b, t, kvh, g, hdq).transpose(0, 2, 1, 3, 4)
    offs = jnp.broadcast_to(jnp.asarray(offset, jnp.int32), (b,))
    kw = dict(window=window, softcap=softcap, scale=scale, v_width=v_width,
              k_scale=k_scale, v_scale=v_scale)
    if impl == "lax":
        out = prefill_attention_paged_lax(qg, k_chunk, v_chunk, k_pool,
                                          v_pool, page_table, offs, **kw)
    elif impl in ("pallas", "pallas_interpret"):
        out = prefill_attention_paged_pallas(
            qg, k_chunk, v_chunk, k_pool, v_pool, page_table, offs,
            interpret=impl == "pallas_interpret", **kw)
    else:
        raise ValueError(f"unknown prefill_attention impl {impl!r}")
    hdv = out.shape[-1]
    return out.transpose(0, 2, 1, 3, 4).reshape(b, t, h, hdv)


def prefill_attention(q, k_chunk, v_chunk, k_cache, v_cache, offset, *,
                      ring: bool = False, window=None, softcap=None,
                      scale: float = 1.0, block_k: int = DEFAULT_BLOCK_K,
                      v_width=None, k_scale=None, v_scale=None,
                      impl: str = "auto"):
    """Chunked-prefill attention: T chunk queries over [prefix ++ chunk].

    q: (B, T, H, hdq) chunk queries at positions ``offset + i``.
    k_chunk/v_chunk: (B, T, KVH, hdq/hdv) — the chunk's own keys/values
    (NOT yet scattered into the cache).  k_cache/v_cache:
    (B, C, KVH, hdq/hdv) — the cache holding positions ``< offset``
    (previous chunks).  offset: scalar or (B,) int32.  ``ring=True``
    for sliding-window ring caches; ``window`` (required with ring) is
    applied explicitly — chunk queries trail the prefix, so the ring
    size does not subsume it the way decode's single newest-token query
    does.  ``v_width``: v operands are the first ``v_width`` lanes of
    the given arrays (which may alias k — the MLA latent cache).
    ``k_scale``/``v_scale``: (B, C, KVH) float32 per-row scales when the
    *cache* holds quantized codes (chunk k/v always arrive full
    precision; ``v_scale`` defaults to ``k_scale``).
    Returns (B, T, H, hdv) in q.dtype.
    """
    impl = _resolve(impl)
    b, t, h, hdq = q.shape
    if k_chunk.shape[1] != t:
        raise ValueError(f"chunk keys cover {k_chunk.shape[1]} tokens but "
                         f"the query chunk has {t}")
    kvh = k_cache.shape[2]
    if h % kvh:
        raise ValueError(f"H={h} not divisible by KVH={kvh}")
    if ring and window is None:
        raise ValueError("ring caches need an explicit window")
    if window is not None and not ring:
        raise ValueError("window only applies to ring caches here "
                         "(full-cache layers carry no window)")
    g = h // kvh
    qg = q.reshape(b, t, kvh, g, hdq).transpose(0, 2, 1, 3, 4)
    offs = jnp.broadcast_to(jnp.asarray(offset, jnp.int32), (b,))
    kw = dict(ring=ring, window=window, softcap=softcap, scale=scale,
              block_k=block_k, v_width=v_width, k_scale=k_scale,
              v_scale=v_scale)
    if impl == "lax":
        out = prefill_attention_lax(qg, k_chunk, v_chunk, k_cache, v_cache,
                                    offs, **kw)
    elif impl in ("pallas", "pallas_interpret"):
        out = prefill_attention_pallas(
            qg, k_chunk, v_chunk, k_cache, v_cache, offs,
            interpret=impl == "pallas_interpret", **kw)
    else:
        raise ValueError(f"unknown prefill_attention impl {impl!r}")
    hdv = out.shape[-1]
    return out.transpose(0, 2, 1, 3, 4).reshape(b, t, h, hdv)
