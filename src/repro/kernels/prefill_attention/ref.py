"""Pure-jnp oracle for the chunked-prefill flash kernel.

Like ``decode_attention/ref.py``, this is the kernel's *blockwise twin*:
it sweeps the already-written cache prefix block by block, then the
chunk's own keys block by block, folding every block into the same
(m, l, acc) online-softmax accumulator with the same operations in the
same order.  Fully-masked blocks are bit-neutral updates (masked scores
are ``NEG_INF``, whose exp underflows to exactly 0.0 against any live
running max, and whose garbage contribution while the max is still
``NEG_INF`` is annihilated — multiplied by an exactly-0.0 alpha — the
moment a live block arrives).  The oracle processes *every* block; the
Pallas kernel skips cache blocks beyond each row's prefix, so the two
must agree bitwise (asserted in tests/test_prefill_attention.py).

Semantics (matching the serve engine's chunked admission):

  * Query ``i`` of row ``b`` sits at absolute position ``offs[b] + i``.
  * ``k_cache``/``v_cache`` is the cache *before* this chunk's KV lands:
    it holds positions ``< offs[b]`` only.
      - ``ring=False``: slot ``s`` holds position ``s``; attendable iff
        ``s < offs[b]`` (the chunk's own keys arrive separately).
      - ``ring=True`` (sliding-window ring of size ``C``): slot ``s``
        holds position ``p = (offs[b]-1) - ((offs[b]-1-s) mod C)``;
        attendable iff ``p >= 0`` and ``pos_q - p < window``.  Unlike
        decode — where the single query is the newest token and the
        window mask is subsumed by the ring size — chunk queries
        *trail* the prefix by up to ``chunk-1`` positions, so the
        explicit window mask is load-bearing here.
  * ``k_chunk``/``v_chunk`` are the chunk's own keys/values at positions
    ``offs[b] + j``; query ``i`` attends ``j <= i`` (and, windowed,
    ``i - j < window``).  Right-padding a final partial chunk is the
    *caller's* contract: pad queries produce garbage rows that are
    discarded, and causality keeps real queries off pad keys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.constants import DEFAULT_BLOCK_K, NEG_INF
from repro.kernels.decode_attention.ref import pick_block_k

__all__ = ["prefill_attention_ref", "pick_block_k"]


def _fold_block(q, k_blk, v_blk, valid, m, l, acc, *, softcap):
    """Fold one key block into the online-softmax accumulator.

    q: (B, KVH, T, G, hdq) fp32, pre-scaled.  k_blk: (B, bk, KVH, hdq),
    v_blk: (B, bk, KVH, hdv) in cache dtype.  valid: (B, 1, T, 1, bk)
    bool.  m, l: (B, KVH, T, G, 1) fp32.  acc: (B, KVH, T, G, hdv) fp32.
    """
    s = jnp.einsum("bhtgd,bkhd->bhtgk", q, k_blk.astype(jnp.float32))
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid, s, NEG_INF)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m - m_new)
    l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = alpha * acc + jnp.einsum("bhtgk,bkhd->bhtgd", p,
                                       v_blk.astype(jnp.float32))
    return m_new, l_new, acc_new


def _cache_valid(offs, cols, q_pos, *, cache_size, ring, window):
    """(B, 1, T, 1, bk) mask for cache slots ``cols`` against chunk
    queries at ``q_pos``.  offs: (B,), cols: (bk,), q_pos: (T,).

    ``ring=False`` with ``window`` set is the *unwrapped* sliding-window
    layout the paged cache uses: slot == position, window as an explicit
    mask instead of a ring size."""
    off = offs[:, None, None, None, None]                  # (B,1,1,1,1)
    col = cols[None, None, None, None, :]                  # (1,1,1,1,bk)
    qp = (q_pos[None, :, None] + offs[:, None, None])[:, None, :, :, None]
    if ring:
        last = off - 1
        pos = last - jnp.mod(last - col, cache_size)       # (B,1,1,1,bk)
        valid = (pos >= 0) & (qp - pos < window)
    elif window is not None:
        valid = (col < off) & (qp - col < window)          # (B,1,T,1,bk)
    else:
        valid = jnp.broadcast_to(col < off, qp.shape[:4] + (cols.shape[0],))
    return valid


def _chunk_valid(b, cols, q_idx, *, window):
    """(B, 1, T, 1, bk) causal (and windowed) in-chunk mask."""
    diff = q_idx[:, None] - cols[None, :]                  # (T, bk)
    valid = diff >= 0
    if window is not None:
        valid &= diff < window
    return jnp.broadcast_to(valid[None, None, :, None, :],
                            (b, 1, q_idx.shape[0], 1, cols.shape[0]))


def prefill_attention_ref(q, k_chunk, v_chunk, k_cache, v_cache, offs, *,
                          ring: bool = False, window=None, softcap=None,
                          scale: float = 1.0, block_k: int = DEFAULT_BLOCK_K,
                          k_scale=None, v_scale=None):
    """q: (B, KVH, T, G, hdq); k_chunk/v_chunk: (B, T, KVH, hdq/hdv);
    k_cache/v_cache: (B, C, KVH, hdq/hdv); offs: scalar or (B,) int32.
    Returns (B, KVH, T, G, hdv) in q.dtype.

    ``k_scale``/``v_scale``: (B, C, KVH) float32 per-row absmax scales
    when the *cache* holds quantized codes (the chunk's own k/v are
    always full precision) — dequantized per cache block with the exact
    op order of the kernel's in-register dequant (``v_scale`` defaults
    to ``k_scale`` — the MLA aliased cache quantizes once)."""
    b, kvh, t, g, _ = q.shape
    c = k_cache.shape[1]
    hdv = v_cache.shape[-1]
    bk_c = pick_block_k(c, block_k)
    bk_t = pick_block_k(t, block_k)
    qs = q.astype(jnp.float32) * scale
    offs = jnp.broadcast_to(jnp.asarray(offs, jnp.int32), (b,))
    q_idx = jnp.arange(t, dtype=jnp.int32)
    if k_scale is not None and v_scale is None:
        v_scale = k_scale

    m = jnp.full((b, kvh, t, g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((b, kvh, t, g, 1), jnp.float32)
    acc = jnp.zeros((b, kvh, t, g, hdv), jnp.float32)

    def cache_body(j, carry):
        m, l, acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k_cache, j * bk_c, bk_c, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v_cache, j * bk_c, bk_c, axis=1)
        if k_scale is not None:
            ks_blk = jax.lax.dynamic_slice_in_dim(k_scale, j * bk_c, bk_c,
                                                  axis=1)
            vs_blk = jax.lax.dynamic_slice_in_dim(v_scale, j * bk_c, bk_c,
                                                  axis=1)
            k_blk = k_blk.astype(jnp.float32) * \
                ks_blk[..., None].astype(jnp.float32)
            v_blk = v_blk.astype(jnp.float32) * \
                vs_blk[..., None].astype(jnp.float32)
        cols = j * bk_c + jnp.arange(bk_c, dtype=jnp.int32)
        valid = _cache_valid(offs, cols, q_idx, cache_size=c, ring=ring,
                             window=window)
        return _fold_block(qs, k_blk, v_blk, valid, m, l, acc,
                           softcap=softcap)

    def chunk_body(j, carry):
        m, l, acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k_chunk, j * bk_t, bk_t, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v_chunk, j * bk_t, bk_t, axis=1)
        cols = j * bk_t + jnp.arange(bk_t, dtype=jnp.int32)
        valid = _chunk_valid(b, cols, q_idx, window=window)
        return _fold_block(qs, k_blk, v_blk, valid, m, l, acc,
                           softcap=softcap)

    # The oracle sweeps EVERY block — cache prefix first, then the
    # chunk — through the same fold the implementations use, so the
    # comparison is exact: block skipping is the only thing the Pallas
    # kernel adds.
    m, l, acc = jax.lax.fori_loop(0, c // bk_c, cache_body, (m, l, acc))
    m, l, acc = jax.lax.fori_loop(0, t // bk_t, chunk_body, (m, l, acc))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def prefill_attention_paged_ref(q, k_chunk, v_chunk, k_pool, v_pool,
                                page_table, offs, *, window=None,
                                softcap=None, scale: float = 1.0,
                                v_width=None, k_scale=None, v_scale=None):
    """Blockwise twin of the *paged* chunked-prefill kernel.

    q: (B, KVH, T, G, hdq); k_chunk/v_chunk: (B, T, KVH, *);
    k_pool/v_pool: (P, page_size, KVH, *) physical pages (``v_pool``
    may be ``k_pool`` with ``v_width`` — MLA); page_table: (B, NB);
    offs: (B,) int32 chunk start positions.

    Gathers the logical cache view through the page table and sweeps it
    with cache blocks of exactly one page — the paged kernel's blocking
    — so pages it skips (beyond each row's prefix, or wholly below the
    window) are bit-neutral folds and the comparison is bitwise.  Paged
    caches are unwrapped: ``window`` is an explicit mask, never a ring.
    """
    b, kvh, t, g, _ = q.shape
    ps = k_pool.shape[1]
    nb = page_table.shape[1]
    pt = page_table.astype(jnp.int32)
    k_cache = jnp.take(k_pool, pt, axis=0).reshape(b, nb * ps, kvh,
                                                   k_pool.shape[-1])
    if v_pool is k_pool:
        v_cache = k_cache
    else:
        v_cache = jnp.take(v_pool, pt, axis=0).reshape(b, nb * ps, kvh,
                                                       v_pool.shape[-1])
    if v_width is not None:
        v_cache = v_cache[..., :v_width]
        v_chunk = v_chunk[..., :v_width]
    ks = vs = None
    if k_scale is not None:
        ks = jnp.take(k_scale, pt, axis=0).reshape(b, nb * ps, kvh)
        if v_scale is None or v_scale is k_scale:
            vs = ks
        else:
            vs = jnp.take(v_scale, pt, axis=0).reshape(b, nb * ps, kvh)
    return prefill_attention_ref(q, k_chunk, v_chunk, k_cache, v_cache,
                                 offs, ring=False, window=window,
                                 softcap=softcap, scale=scale, block_k=ps,
                                 k_scale=ks, v_scale=vs)
