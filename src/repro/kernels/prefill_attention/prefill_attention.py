"""Chunked-prefill flash attention for TPU — the serve admission kernel.

Chunked prefill processes a prompt ``chunk`` tokens at a time against
the request's partially-written KV cache, so prefill compiles **once**
(one chunk shape) instead of once per power-of-two prompt bucket, and
the serve scheduler can interleave one chunk between decode steps
instead of stalling the whole live batch for a full prompt.  The kernel
is the admission hot path: a ``(T, G)``-packed query block attending to

  * the **cache prefix** — KV written by previous chunks (positions
    ``< offs[b]``); per-row offsets arrive via scalar prefetch and clamp
    the cache BlockSpec index maps, so cache blocks entirely beyond a
    row's prefix are never read from HBM (the same elision trick as
    ``kernels/decode_attention``) and a ``pl.when`` skips their MXU
    work; and
  * the **chunk's own keys** — passed separately (they have not been
    scattered into the cache yet), causally masked in-kernel.

Grid is (B, KVH, cache_steps + chunk_steps) with the kv sweep innermost
(``arbitrary`` semantics); the fp32 (T, G, hdv) accumulator plus running
row-max/row-sum live in VMEM scratch across both phases of the sweep —
one continuous online softmax, so the result is a single attention over
[prefix ++ chunk].

Ring caches (sliding-window layers): slot ``s`` holds position
``(offs-1) - ((offs-1-s) mod C)``.  Chunk queries trail the newest
prefix position by up to ``T-1``, so — unlike decode — the explicit
window mask is applied in-kernel on both phases.

``v_width`` lets V alias K (the MLA [latent | rope] concatenated cache:
scores use the full row, values only the latent prefix).

Quantized caches (``k_scale``/``v_scale`` set): the *cache prefix*
holds int8/fp8_e4m3 codes plus per-(slot, kv head) float32 absmax
scales (see ``kernels/quant``); the chunk's own k/v are still full
precision — they have not been through the quantizing cache write yet.
Scale blocks ride the same clamped cache index maps (minus the lane
axis), so skipped prefix blocks elide the scale DMA too, and the
cache-phase fold dequantizes in-register.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.constants import DEFAULT_BLOCK_K, NEG_INF
from repro.kernels.prefill_attention.ref import pick_block_k


def _prefill_kernel(offs_ref, q_ref, kx_ref, vx_ref, kc_ref, vc_ref, *refs,
                    scale: float, ring: bool, window, softcap,
                    bk_c: int, bk_t: int, cache_steps: int,
                    total_steps: int, cache_size: int, chunk: int,
                    quantized: bool = False):
    # Quantized call sites append two float32 cache-scale operands —
    # the ref list is (kcs, vcs, o, m, l, acc) or (o, m, l, acc).
    if quantized:
        kcs_ref, vcs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
        kcs_ref = vcs_ref = None
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    off = offs_ref[bi]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1, 1), 0)

    def fold(k_blk, v_blk, valid):
        """One online-softmax fold.  k_blk: (bk, hdq), v_blk: (bk, hdv),
        valid: (T, 1, bk) — broadcast over the G axis of the scores."""
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (T, G, hdq)
        s = jax.lax.dot_general(
            q, k_blk.astype(jnp.float32), (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # (T, G, bk)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]                                # (T, G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1,
                                                  keepdims=True)
        pv = jax.lax.dot_general(
            p, v_blk.astype(jnp.float32), (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (T, G, hdv)
        acc_ref[...] = alpha * acc_ref[...] + pv
        m_ref[...] = m_new

    # -- phase 1: cache prefix.  Blocks whose first slot is at or past
    # the row's written prefix hold nothing attendable (full cache:
    # slots >= off unwritten; ring: min(off, C) covers the not-yet-
    # wrapped tail) — their DMA was elided by the index map, skip the
    # compute as well.
    @pl.when((ki < cache_steps) & (ki * bk_c < jnp.minimum(off, cache_size)))
    def _cache_phase():
        k_lo = ki * bk_c
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bk_c), 2)
        q_pos = off + q_idx                                # (T, 1, 1)
        if ring:
            last = off - 1
            pos = last - jnp.mod(last - cols, cache_size)
            valid = (pos >= 0) & (q_pos - pos < window)
        else:
            valid = jnp.broadcast_to(cols < off, (chunk, 1, bk_c))
        kb = kc_ref[0, :, 0, :]
        vb = vc_ref[0, :, 0, :]
        if quantized:
            kb = kb.astype(jnp.float32) * \
                kcs_ref[0, :, 0].astype(jnp.float32)[:, None]
            vb = vb.astype(jnp.float32) * \
                vcs_ref[0, :, 0].astype(jnp.float32)[:, None]
        fold(kb, vb, valid)

    # -- phase 2: the chunk's own keys (causal; every block holds a key
    # some query attends, so none are skippable).
    @pl.when(ki >= cache_steps)
    def _chunk_phase():
        j_lo = (ki - cache_steps) * bk_t
        cols = j_lo + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bk_t), 2)
        diff = q_idx - cols                                # (T, 1, bk_t)
        valid = diff >= 0
        if window is not None:
            valid &= diff < window
        fold(kx_ref[0, :, 0, :], vx_ref[0, :, 0, :], valid)

    @pl.when(ki == total_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_prefill_kernel(offs_ref, pt_ref, q_ref, kx_ref, vx_ref, kc_ref,
                          vc_ref, *refs, scale: float, window, softcap,
                          ps: int, bk_t: int, cache_steps: int,
                          total_steps: int, chunk: int,
                          quantized: bool = False):
    if quantized:
        kcs_ref, vcs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
        kcs_ref = vcs_ref = None
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    off = offs_ref[bi]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1, 1), 0)

    def fold(k_blk, v_blk, valid):
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (T, G, hdq)
        s = jax.lax.dot_general(
            q, k_blk.astype(jnp.float32), (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # (T, G, bk)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]                                # (T, G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1,
                                                  keepdims=True)
        pv = jax.lax.dot_general(
            p, v_blk.astype(jnp.float32), (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (T, G, hdv)
        acc_ref[...] = alpha * acc_ref[...] + pv
        m_ref[...] = m_new

    # -- phase 1: the paged cache prefix.  One block == one physical
    # page; unwrapped layout (slot == position), so beyond-prefix pages
    # and — windowed — pages wholly below the first query's window
    # start are both skippable (their DMA was elided by the index map).
    k_lo = ki * ps
    live = (ki < cache_steps) & (k_lo < off)
    if window is not None:
        live &= (k_lo + ps - 1) >= off - (window - 1)

    @pl.when(live)
    def _cache_phase():
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
        q_pos = off + q_idx                                # (T, 1, 1)
        valid = jnp.broadcast_to(cols < off, (chunk, 1, ps))
        if window is not None:
            valid &= (q_pos - cols) < window
        kb = kc_ref[0, :, 0, :]
        vb = vc_ref[0, :, 0, :]
        if quantized:
            kb = kb.astype(jnp.float32) * \
                kcs_ref[0, :, 0].astype(jnp.float32)[:, None]
            vb = vb.astype(jnp.float32) * \
                vcs_ref[0, :, 0].astype(jnp.float32)[:, None]
        fold(kb, vb, valid)

    # -- phase 2: the chunk's own keys (causal; identical to the
    # contiguous kernel — the chunk is not paged).
    @pl.when(ki >= cache_steps)
    def _chunk_phase():
        j_lo = (ki - cache_steps) * bk_t
        cols = j_lo + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bk_t), 2)
        diff = q_idx - cols                                # (T, 1, bk_t)
        valid = diff >= 0
        if window is not None:
            valid &= diff < window
        fold(kx_ref[0, :, 0, :], vx_ref[0, :, 0, :], valid)

    @pl.when(ki == total_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def prefill_attention_paged_pallas(q, k_chunk, v_chunk, k_pool, v_pool,
                                   page_table, offs, *, window=None,
                                   softcap=None, scale: float = 1.0,
                                   v_width=None, k_scale=None, v_scale=None,
                                   interpret: bool = False):
    """Paged chunked-prefill: q (B, KVH, T, G, hdq); chunk k/v
    (B, T, KVH, *); physical pools (P, page_size, KVH, *) addressed
    through page_table (B, NB) int32; offs (B,) int32.  The cache-phase
    BlockSpec index maps read the page table from scalar-prefetch SMEM
    (one block == one page) with the same clamp-to-elide-DMA trick as
    the contiguous kernel.  Paged caches are unwrapped: sliding windows
    arrive as the explicit ``window`` mask, never ``ring``.
    ``k_scale``/``v_scale``: (P, page_size, KVH) float32 per-row scale
    pools when the code pools are quantized (chunk k/v stay full
    precision).  Returns (B, KVH, T, G, hdv) in q.dtype."""
    b, kvh, t, g, hdq = q.shape
    ps = k_pool.shape[1]
    nb = page_table.shape[1]
    c = nb * ps
    hdv = v_width if v_width is not None else v_pool.shape[-1]
    bk_t = pick_block_k(t, ps)       # match the paged ref twin's blocking
    cache_steps = nb
    chunk_steps = t // bk_t
    total_steps = cache_steps + chunk_steps
    quantized = k_scale is not None
    if quantized and v_scale is None:
        v_scale = k_scale

    def q_map(bi, hi, ki, offs, pt):
        return (bi, hi, 0, 0, 0)

    def _page(bi, ki, offs, pt):
        # Clamp to the row's needed page range, then go through the
        # page table: revisited physical indices elide the HBM copy
        # (beyond-prefix pages, the whole chunk phase, and — windowed —
        # the below-window head).
        last = jnp.minimum(jnp.maximum(offs[bi] - 1, 0), c - 1) // ps
        j = jnp.minimum(ki, last)
        if window is not None:
            first = jnp.maximum(offs[bi] - (window - 1), 0) // ps
            j = jnp.maximum(j, jnp.minimum(first, last))
        return pt[bi, j]

    def cache_map(bi, hi, ki, offs, pt):
        return (_page(bi, ki, offs, pt), 0, hi, 0)

    def scale_map(bi, hi, ki, offs, pt):
        # Same physical page as the codes: scale DMAs elide together.
        return (_page(bi, ki, offs, pt), 0, hi)

    def chunk_map(bi, hi, ki, offs, pt):
        j = jnp.clip(ki - cache_steps, 0, chunk_steps - 1)
        return (bi, j, hi, 0)

    in_specs = [
        pl.BlockSpec((1, 1, t, g, hdq), q_map),
        pl.BlockSpec((1, bk_t, 1, hdq), chunk_map),
        pl.BlockSpec((1, bk_t, 1, hdv), chunk_map),
        pl.BlockSpec((1, ps, 1, hdq), cache_map),
        pl.BlockSpec((1, ps, 1, hdv), cache_map),
    ]
    operands = [q, k_chunk, v_chunk, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, ps, 1), scale_map),
                     pl.BlockSpec((1, ps, 1), scale_map)]
        operands += [k_scale, v_scale]

    kernel = functools.partial(
        _paged_prefill_kernel, scale=scale, window=window, softcap=softcap,
        ps=ps, bk_t=bk_t, cache_steps=cache_steps, total_steps=total_steps,
        chunk=t, quantized=quantized)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, total_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, t, g, hdv), q_map),
        scratch_shapes=[
            pltpu.VMEM((t, g, 1), jnp.float32),     # m: running row max
            pltpu.VMEM((t, g, 1), jnp.float32),     # l: running row sum
            pltpu.VMEM((t, g, hdv), jnp.float32),   # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, t, g, hdv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(offs.astype(jnp.int32), page_table.astype(jnp.int32), *operands)


def prefill_attention_pallas(q, k_chunk, v_chunk, k_cache, v_cache, offs, *,
                             ring: bool = False, window=None, softcap=None,
                             scale: float = 1.0, block_k: int = DEFAULT_BLOCK_K,
                             v_width=None, k_scale=None, v_scale=None,
                             interpret: bool = False):
    """q: (B, KVH, T, G, hdq); k_chunk/v_chunk: (B, T, KVH, hdq/hdv);
    k_cache/v_cache: (B, C, KVH, hdq/hdv); offs: (B,) int32 chunk start
    positions.  Returns (B, KVH, T, G, hdv) in q.dtype.  ``v_width``:
    read only the first lanes of both v operands (which may alias their
    k counterparts — the MLA concatenated latent cache).
    ``k_scale``/``v_scale``: (B, C, KVH) float32 per-row scales when the
    cache holds quantized codes (chunk k/v stay full precision)."""
    b, kvh, t, g, hdq = q.shape
    c = k_cache.shape[1]
    hdv = v_width if v_width is not None else v_cache.shape[-1]
    bk_c = pick_block_k(c, block_k)
    bk_t = pick_block_k(t, block_k)
    cache_steps = c // bk_c
    chunk_steps = t // bk_t
    total_steps = cache_steps + chunk_steps
    quantized = k_scale is not None
    if quantized and v_scale is None:
        v_scale = k_scale

    def q_map(bi, hi, ki, offs):
        return (bi, hi, 0, 0, 0)

    def cache_map(bi, hi, ki, offs):
        # Clamp beyond-prefix blocks (and the whole chunk phase) to the
        # row's last needed cache block: a revisited block index elides
        # the HBM->VMEM copy entirely.
        last = jnp.minimum(jnp.maximum(offs[bi] - 1, 0), c - 1) // bk_c
        return (bi, jnp.minimum(ki, last), hi, 0)

    def scale_map(bi, hi, ki, offs):
        # Code block and scale block share the clamp: both DMAs elide.
        last = jnp.minimum(jnp.maximum(offs[bi] - 1, 0), c - 1) // bk_c
        return (bi, jnp.minimum(ki, last), hi)

    def chunk_map(bi, hi, ki, offs):
        # Parked at block 0 during the cache phase (no copy after the
        # first revisit), then walks the chunk.
        j = jnp.clip(ki - cache_steps, 0, chunk_steps - 1)
        return (bi, j, hi, 0)

    in_specs = [
        pl.BlockSpec((1, 1, t, g, hdq), q_map),
        pl.BlockSpec((1, bk_t, 1, hdq), chunk_map),
        pl.BlockSpec((1, bk_t, 1, hdv), chunk_map),
        pl.BlockSpec((1, bk_c, 1, hdq), cache_map),
        pl.BlockSpec((1, bk_c, 1, hdv), cache_map),
    ]
    operands = [q, k_chunk, v_chunk, k_cache, v_cache]
    if quantized:
        in_specs += [pl.BlockSpec((1, bk_c, 1), scale_map),
                     pl.BlockSpec((1, bk_c, 1), scale_map)]
        operands += [k_scale, v_scale]

    kernel = functools.partial(
        _prefill_kernel, scale=scale, ring=ring, window=window,
        softcap=softcap, bk_c=bk_c, bk_t=bk_t, cache_steps=cache_steps,
        total_steps=total_steps, cache_size=c, chunk=t, quantized=quantized)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, total_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, t, g, hdv), q_map),
        scratch_shapes=[
            pltpu.VMEM((t, g, 1), jnp.float32),     # m: running row max
            pltpu.VMEM((t, g, 1), jnp.float32),     # l: running row sum
            pltpu.VMEM((t, g, hdv), jnp.float32),   # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, t, g, hdv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(offs.astype(jnp.int32), *operands)
