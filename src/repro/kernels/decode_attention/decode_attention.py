"""Flash-decode: length-aware fused decode attention for TPU.

The serve hot path is one new token against a full cache: decode is
memory-bound, so HBM bytes are joules.  Dense decode reads every cache
slot of every row regardless of how many tokens the row actually holds.
This kernel makes the cache read *length-aware*:

  * Grid (B, KVH, C/bk), kv blocks innermost with ``arbitrary``
    semantics; the (G, hdv) fp32 accumulator plus running row-max m and
    row-sum l live in VMEM scratch across the kv sweep (standard online
    softmax).
  * The per-row ``cur_len`` vector arrives via scalar prefetch and
    feeds the K/V BlockSpec index maps: blocks entirely beyond a row's
    valid prefix are clamped to the row's last needed block, so the
    pipeline revisits the same index and **never issues their HBM
    reads** — the bandwidth win a dense masked path cannot have.  A
    ``pl.when`` guard skips their MXU work too.
  * GQA is packed, not repeated: all G query heads of one kv head load
    as a single (G, hdq) q block, so each K block feeds one real
    (G, hdq) x (hdq, bk) MXU matmul instead of G vector products, and
    K/V are read once per kv head.
  * Sliding-window ring buffers, slot -> position arithmetic, never-
    written-slot validity, and logit soft-capping are handled in-kernel
    from ``cur_len`` alone — no (B, C) position/validity tensors are
    materialised in HBM per decode step.

``v`` may be the same array as ``k`` with ``v_width`` set: the V
BlockSpec then reads only the first ``v_width`` lanes (the MLA latent
cache stores [latent | rope] concatenated; scores use the full row,
values only the latent prefix).

Quantized caches (``k_scale``/``v_scale`` set): k/v hold int8 or
fp8_e4m3 codes and the scale arrays hold one float32 absmax scale per
(slot, kv head) row — see ``kernels/quant``.  The scale blocks ride the
*same clamped index maps* as their code blocks (minus the lane axis),
so dead blocks elide the scale DMA exactly like the code DMA, and the
kernel dequantizes in-register — ``codes.astype(f32) * scale[:, None]``
— right before each dot.  The contract keeps memory traffic at the
quantized width: nothing is ever materialised dequantized in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.constants import NEG_INF
from repro.kernels.decode_attention.ref import pick_block_k


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, *refs,
                   scale: float, ring: bool, softcap, bk: int,
                   kv_steps: int, cache_size: int,
                   quantized: bool = False):
    # Quantized call sites append two float32 scale operands after v —
    # the ref list is (ks, vs, o, m, l, acc) or (o, m, l, acc).
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    cur = lens_ref[bi]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_lo = ki * bk

    # Blocks whose first slot is past the row's new-token position hold
    # no valid key (full cache: slots > cur unwritten; ring: a not-yet-
    # wrapped tail) — their DMA was elided by the index map, skip the
    # compute as well.
    @pl.when(k_lo <= cur)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # (G, hdq)
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # (bk, hdq)
        if quantized:
            k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (G, bk)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if ring:
            # slot s holds position cur - ((cur - s) mod C); valid iff
            # that position is >= 0 (the window mask is subsumed: held
            # positions are within C - 1 <= window - 1 of the query).
            valid = jnp.mod(cur - cols, cache_size) <= cur
        else:
            valid = cols <= cur
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]                                   # (G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1,
                                                  keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)             # (bk, hdv)
        if quantized:
            v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
        acc_ref[...] = alpha * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_decode_kernel(lens_ref, pt_ref, q_ref, k_ref, v_ref, *refs,
                         scale: float, window, softcap, ps: int,
                         kv_steps: int, quantized: bool = False):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    cur = lens_ref[bi]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_lo = ki * ps

    # Paged caches are unwrapped (slot == position): pages beyond the
    # row's new-token position hold nothing, and — for sliding-window
    # layers — pages wholly below ``cur - window + 1`` are all masked.
    # Both ends had their DMA elided by the index-map clamp; skip the
    # compute too.
    live = k_lo <= cur
    if window is not None:
        live &= (k_lo + ps - 1) >= cur - (window - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # (G, hdq)
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # (ps, hdq)
        if quantized:
            k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (G, ps)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = cols <= cur
        if window is not None:
            valid &= (cur - cols) < window
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]                                   # (G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1,
                                                  keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)             # (ps, hdv)
        if quantized:
            v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
        acc_ref[...] = alpha * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_paged_pallas(q, k_pool, v_pool, page_table, lens, *,
                                  window=None, softcap=None,
                                  scale: float = 1.0, v_width=None,
                                  k_scale=None, v_scale=None,
                                  interpret: bool = False):
    """Paged flash-decode: q (B, KVH, G, hdq) against physical page
    pools k_pool/v_pool (P, page_size, KVH, hd*) through a
    page_table (B, NB) int32.  lens: (B,) int32 new-token positions.
    One kv block == one physical page; the K/V BlockSpec index maps
    read the page table from scalar-prefetch SMEM — the paged lookup is
    literally "the index map reads ``pt[b, block]`` instead of
    ``(b, block)``", with the same clamp-to-elide-DMA trick on both
    the beyond-``lens`` tail and (windowed) the below-window head.
    Returns (B, KVH, G, hdv) in q.dtype.  ``v_width``: read only the
    first lanes of v (``v_pool`` may alias ``k_pool`` — MLA).
    ``k_scale``/``v_scale``: (P, page_size, KVH) float32 per-row absmax
    scale pools for quantized code pools; they page through the same
    table and clamp, and the kernel dequantizes in-register."""
    b, kvh, g, hdq = q.shape
    ps = k_pool.shape[1]
    nb = page_table.shape[1]
    c = nb * ps
    hdv = v_width if v_width is not None else v_pool.shape[-1]
    quantized = k_scale is not None
    if quantized and v_scale is None:
        v_scale = k_scale

    def q_map(bi, hi, ki, lens, pt):
        return (bi, hi, 0, 0)

    def _page(bi, ki, lens, pt):
        # Clamp the sweep to the row's needed page range, then map the
        # logical page through the page table: a revisited *physical*
        # index elides the HBM->VMEM copy entirely.
        j = ki
        last = jnp.minimum(lens[bi], c - 1) // ps
        j = jnp.minimum(j, last)
        if window is not None:
            first = jnp.maximum(lens[bi] - (window - 1), 0) // ps
            j = jnp.maximum(j, jnp.minimum(first, last))
        return pt[bi, j]

    def kv_map(bi, hi, ki, lens, pt):
        return (_page(bi, ki, lens, pt), 0, hi, 0)

    def scale_map(bi, hi, ki, lens, pt):
        # Same physical page as the codes: the scale DMA is elided for
        # exactly the pages whose code DMA is elided.
        return (_page(bi, ki, lens, pt), 0, hi)

    in_specs = [
        pl.BlockSpec((1, 1, g, hdq), q_map),
        pl.BlockSpec((1, ps, 1, hdq), kv_map),
        pl.BlockSpec((1, ps, 1, hdv), kv_map),
    ]
    operands = [q, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, ps, 1), scale_map),
                     pl.BlockSpec((1, ps, 1), scale_map)]
        operands += [k_scale, v_scale]

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, window=window, softcap=softcap,
        ps=ps, kv_steps=nb, quantized=quantized)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hdv), q_map),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # m: running row max
            pltpu.VMEM((g, 1), jnp.float32),     # l: running row sum
            pltpu.VMEM((g, hdv), jnp.float32),   # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hdv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lens.astype(jnp.int32), page_table.astype(jnp.int32), *operands)


def decode_attention_pallas(q, k, v, lens, *, ring: bool = False,
                            softcap=None, scale: float = 1.0,
                            block_k: int = 128, v_width=None,
                            k_scale=None, v_scale=None,
                            interpret: bool = False):
    """q: (B, KVH, G, hdq), k: (B, C, KVH, hdq), v: (B, C, KVH, hdv),
    lens: (B,) int32 new-token positions.  Returns (B, KVH, G, hdv) in
    q.dtype.  ``v_width``: read only the first lanes of v (see module
    docstring; ``v`` may alias ``k``).  ``k_scale``/``v_scale``:
    (B, C, KVH) float32 per-row absmax scales when k/v hold quantized
    codes; the kernel dequantizes blocks in-register."""
    b, kvh, g, hdq = q.shape
    c = k.shape[1]
    hdv = v_width if v_width is not None else v.shape[-1]
    bk = pick_block_k(c, block_k)
    kv_steps = c // bk
    quantized = k_scale is not None
    if quantized and v_scale is None:
        v_scale = k_scale

    def q_map(bi, hi, ki, lens):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, ki, lens):
        # Clamp beyond-prefix blocks to the row's last needed block: a
        # revisited block index elides the HBM->VMEM copy entirely.
        last = jnp.minimum(lens[bi], c - 1) // bk
        return (bi, jnp.minimum(ki, last), hi, 0)

    def scale_map(bi, hi, ki, lens):
        # Code block and scale block share the clamp: both DMAs elide.
        last = jnp.minimum(lens[bi], c - 1) // bk
        return (bi, jnp.minimum(ki, last), hi)

    in_specs = [
        pl.BlockSpec((1, 1, g, hdq), q_map),
        pl.BlockSpec((1, bk, 1, hdq), kv_map),
        pl.BlockSpec((1, bk, 1, hdv), kv_map),
    ]
    operands = [q, k, v]
    if quantized:
        in_specs += [pl.BlockSpec((1, bk, 1), scale_map),
                     pl.BlockSpec((1, bk, 1), scale_map)]
        operands += [k_scale, v_scale]

    kernel = functools.partial(
        _decode_kernel, scale=scale, ring=ring, softcap=softcap, bk=bk,
        kv_steps=kv_steps, cache_size=c, quantized=quantized)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, kv_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hdv), q_map),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # m: running row max
            pltpu.VMEM((g, 1), jnp.float32),     # l: running row sum
            pltpu.VMEM((g, hdv), jnp.float32),   # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hdv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lens.astype(jnp.int32), *operands)
