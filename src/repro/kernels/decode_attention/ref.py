"""Pure-jnp oracle for the flash-decode kernel.

The oracle is the kernel's *blockwise twin*, not a dense softmax: it
sweeps the cache in the same ``block_k`` blocks, applies the same
masking, and folds each block into the same (m, l, acc) online-softmax
accumulator with the same operations in the same order.  Skipping a
fully-masked block and processing it are bit-identical updates (masked
scores are ``NEG_INF``, whose exp underflows to exactly 0.0 and leaves
m/l/acc untouched), so the oracle — which processes *every* block — is
an exact-parity reference for the Pallas kernel, which skips blocks
beyond ``cur_len`` (the kernel-vs-ref tests assert bitwise equality).

The segmented ``ops.decode_attention_lax`` fallback implements the same
masking semantics at segment granularity with a different (fused)
compute layout, so it is held to fp-reassociation tolerance against
this oracle rather than bitwise equality — see
tests/test_decode_attention.py.

Semantics (matching ``models.attention.decode_self_attention``):

  * ``lens[b]`` is the position of row ``b``'s new token == the count
    of tokens already in the cache; the cache has already absorbed the
    new k/v at its slot, so valid slots are exactly positions
    ``<= lens[b]``.
  * ``ring=False``: slot ``s`` holds position ``s``; valid iff
    ``s <= lens[b]``.
  * ``ring=True`` (sliding-window ring buffer of size ``C ==
    min(max_len, window)``): slot ``s`` holds the largest position
    ``p <= cur`` with ``p % C == s``; valid iff ``p >= 0``, i.e.
    ``(cur - s) mod C <= cur``.  The window mask itself is subsumed:
    every held position is within ``C - 1 <= window - 1`` of the query.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.constants import NEG_INF


def pick_block_k(cache_size: int, block_k: int) -> int:
    """Largest divisor of ``cache_size`` no bigger than ``block_k``.

    Cache sizes are normally powers of two (max_len / window), so this
    returns ``block_k`` itself; odd sizes degrade to a smaller even
    split instead of requiring padding.
    """
    return math.gcd(min(block_k, cache_size), cache_size)


def _block_step(q, k_blk, v_blk, k_lo, lens, m, l, acc, *,
                cache_size: int, ring: bool, softcap, window=None,
                ks_blk=None, vs_blk=None):
    """Fold one kv block into the online-softmax accumulator.

    q: (B, KVH, G, hdq) fp32, pre-scaled.  k_blk: (B, bk, KVH, hdq),
    v_blk: (B, bk, KVH, hdv) in cache dtype.  k_lo: first cache slot of
    the block (python int or traced scalar).  lens: (B,) int32.
    m, l: (B, KVH, G, 1) fp32 running max/sum.  acc: (B, KVH, G, hdv)
    fp32.  Returns the updated (m, l, acc).

    ``window`` (non-ring only) masks positions below ``cur - window + 1``
    — the *unwrapped* sliding-window layout the paged cache uses, where
    slot ``s`` always holds position ``s`` and the window is an explicit
    mask instead of a ring size.

    ``ks_blk``/``vs_blk``: (B, bk, KVH) float32 per-row absmax scales
    when k_blk/v_blk hold quantized codes — dequantized here with the
    exact op order of the kernel's in-register dequant, keeping the
    blockwise comparison bitwise in the quantized modes too.
    """
    bk = k_blk.shape[1]
    kf = k_blk.astype(jnp.float32)
    if ks_blk is not None:
        kf = kf * ks_blk[..., None].astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", q, kf)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    cols = k_lo + jnp.arange(bk, dtype=jnp.int32)[None, None, None, :]
    cur = lens.astype(jnp.int32)[:, None, None, None]
    if ring:
        valid = jnp.mod(cur - cols, cache_size) <= cur
    else:
        valid = cols <= cur
        if window is not None:
            valid &= (cur - cols) < window
    s = jnp.where(valid, s, NEG_INF)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m - m_new)
    l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
    vf = v_blk.astype(jnp.float32)
    if vs_blk is not None:
        vf = vf * vs_blk[..., None].astype(jnp.float32)
    acc_new = alpha * acc + jnp.einsum("bhgk,bkhd->bhgd", p, vf)
    return m_new, l_new, acc_new


def decode_attention_ref(q, k, v, lens, *, ring: bool = False,
                         softcap=None, scale: float = 1.0,
                         block_k: int = 128, k_scale=None, v_scale=None):
    """q: (B, KVH, G, hdq), k: (B, C, KVH, hdq), v: (B, C, KVH, hdv),
    lens: scalar or (B,) int32.  Returns (B, KVH, G, hdv) in q.dtype.
    ``k_scale``/``v_scale``: (B, C, KVH) float32 per-row absmax scales
    when k/v hold quantized codes (``v_scale`` defaults to ``k_scale``
    — the MLA aliased cache quantizes once)."""
    b, kvh, g, _ = q.shape
    c = k.shape[1]
    hdv = v.shape[-1]
    bk = pick_block_k(c, block_k)
    qs = q.astype(jnp.float32) * scale
    lens = jnp.broadcast_to(jnp.asarray(lens, jnp.int32), (b,))
    if k_scale is not None and v_scale is None:
        v_scale = k_scale

    def body(j, carry):
        m, l, acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, axis=1)
        ks_blk = vs_blk = None
        if k_scale is not None:
            ks_blk = jax.lax.dynamic_slice_in_dim(k_scale, j * bk, bk, axis=1)
            vs_blk = jax.lax.dynamic_slice_in_dim(v_scale, j * bk, bk, axis=1)
        return _block_step(qs, k_blk, v_blk, j * bk, lens, m, l, acc,
                           cache_size=c, ring=ring, softcap=softcap,
                           ks_blk=ks_blk, vs_blk=vs_blk)

    m = jnp.full((b, kvh, g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((b, kvh, g, 1), jnp.float32)
    acc = jnp.zeros((b, kvh, g, hdv), jnp.float32)
    # The oracle sweeps EVERY block (no length awareness) through the
    # same loop structure as the implementations, so the comparison is
    # exact: block skipping is the only thing the fast paths add.
    m, l, acc = jax.lax.fori_loop(0, c // bk, body, (m, l, acc))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def decode_attention_paged_ref(q, k_pool, v_pool, page_table, lens, *,
                               window=None, softcap=None, scale: float = 1.0,
                               v_width=None, k_scale=None, v_scale=None):
    """Blockwise twin of the *paged* flash-decode kernel.

    q: (B, KVH, G, hdq); k_pool/v_pool: (P, page_size, KVH, hd*)
    physical pages (``v_pool`` may be ``k_pool`` with ``v_width`` set —
    the MLA concatenated latent cache); page_table: (B, NB) int32;
    lens: (B,) int32.

    Gathers the logical (B, NB*page_size, KVH, *) view through the page
    table, then folds every page with ``block_k == page_size`` — the
    exact blocking the paged kernel uses, so skipped pages (beyond
    ``lens`` or wholly below the window) are bit-neutral updates and the
    comparison is bitwise, same as the contiguous pair.
    Paged caches are always *unwrapped* (slot == position): sliding
    windows arrive as the explicit ``window`` mask, never ``ring``.
    """
    b, kvh, g, _ = q.shape
    p, ps = k_pool.shape[0], k_pool.shape[1]
    nb = page_table.shape[1]
    c = nb * ps
    pt = page_table.astype(jnp.int32)
    k = jnp.take(k_pool, pt, axis=0).reshape(b, c, kvh, k_pool.shape[-1])
    if v_pool is k_pool:
        v = k
    else:
        v = jnp.take(v_pool, pt, axis=0).reshape(b, c, kvh, v_pool.shape[-1])
    if v_width is not None:
        v = v[..., :v_width]
    hdv = v.shape[-1]
    qs = q.astype(jnp.float32) * scale
    lens = jnp.broadcast_to(jnp.asarray(lens, jnp.int32), (b,))
    ks = vs = None
    if k_scale is not None:
        ks = jnp.take(k_scale, pt, axis=0).reshape(b, c, kvh)
        if v_scale is None or v_scale is k_scale:
            vs = ks
        else:
            vs = jnp.take(v_scale, pt, axis=0).reshape(b, c, kvh)

    def body(j, carry):
        m, l, acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k, j * ps, ps, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, j * ps, ps, axis=1)
        ks_blk = vs_blk = None
        if ks is not None:
            ks_blk = jax.lax.dynamic_slice_in_dim(ks, j * ps, ps, axis=1)
            vs_blk = jax.lax.dynamic_slice_in_dim(vs, j * ps, ps, axis=1)
        return _block_step(qs, k_blk, v_blk, j * ps, lens, m, l, acc,
                           cache_size=c, ring=False, softcap=softcap,
                           window=window, ks_blk=ks_blk, vs_blk=vs_blk)

    m = jnp.full((b, kvh, g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((b, kvh, g, 1), jnp.float32)
    acc = jnp.zeros((b, kvh, g, hdv), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nb, body, (m, l, acc))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
