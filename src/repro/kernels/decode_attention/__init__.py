from repro.kernels.decode_attention.decode_attention import \
    decode_attention_pallas
from repro.kernels.decode_attention.ops import (decode_attention,
                                                decode_attention_lax)
from repro.kernels.decode_attention.ref import decode_attention_ref

__all__ = ["decode_attention", "decode_attention_lax",
           "decode_attention_pallas", "decode_attention_ref"]
