"""Model-facing flash-decode wrapper.

``decode_attention`` accepts the framework's decode layout — new-token
queries (B, 1, H, hdq) against an already-updated cache
k: (B, C, KVH, hdq) / v: (B, C, KVH, hdv) — reshapes q to the kernel's
GQA-packed (B, KVH, G, hdq), and routes to:

  * ``pallas``           the flash-decode kernel (TPU),
  * ``pallas_interpret`` the same kernel in interpret mode (CPU parity
                         testing),
  * ``lax``              a length-aware masked XLA fallback: the cache
                         is cut into 8 static *segments*; each segment
                         computes a masked online-softmax partial
                         (m, l, acc) under a ``lax.cond`` that skips
                         segments entirely beyond the batch-max
                         ``cur_len``, and the partials merge with the
                         standard flash rescaling.  Static segment
                         slices fuse into clean batched GEMMs (better
                         cache locality than one cache-wide sweep), so
                         at fill f the path reads ~f bytes, not C —
                         the kernel's bandwidth saving expressed in
                         plain XLA.

``impl="auto"`` picks Pallas iff the default backend is TPU; the env
var ``PMT_DECODE_ATTENTION_DISPATCH`` (values: pallas /
pallas_interpret / lax) overrides "auto" for experiments.  This is the
*kernel dispatch* knob — the model-level dense-vs-flash choice is
``cfg.decode_attn_impl`` / ``PMT_DECODE_ATTN_IMPL`` (see
blocks.decode_attn_impl), which decides whether this module is called
at all.

Numerics: the Pallas kernel is bit-exact against the blockwise ref.py
oracle (same op-for-op online softmax; see ref.py).  The lax path uses
segment-sized blocks instead of ``block_k``-sized ones, so it matches
within fp reassociation (~1 ulp of fp32 softmax), and is invariant to
how many segments ran: a skipped segment's partial is the identity
under the merge.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.constants import NEG_INF
from repro.kernels.decode_attention.decode_attention import (
    decode_attention_paged_pallas, decode_attention_pallas)


def _resolve(impl: str) -> str:
    if impl == "auto":
        impl = os.environ.get("PMT_DECODE_ATTENTION_DISPATCH", "auto")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "lax"
    return impl


_LAX_SEGMENTS = 8


def decode_attention_lax(q, k, v, lens, *, ring: bool = False,
                         softcap=None, scale: float = 1.0,
                         block_k: int = 128, v_width=None,
                         k_scale=None, v_scale=None):
    """Length-aware masked decode attention in plain XLA.

    Same layout as the kernel: q (B, KVH, G, hdq), k/v (B, C, KVH, *),
    lens (B,).  The cache is cut into ``_LAX_SEGMENTS`` static
    segments; segments beyond the batch-max ``cur_len`` are skipped by
    ``lax.cond`` (their partial is the merge identity), so the read
    granularity is ~C/8 regardless of cache size.  ``block_k`` is the
    Pallas tiling knob and is unused here.

    K/V segments are transposed to (B, KVH, S, hd) fp32 before the
    score/value contractions — one fused cast+transpose copy of the
    *segment only*, turning both contractions into clean batched GEMMs
    (measurably faster than einsum-ing the strided cache layout, and
    segment-sized working sets stay cache-resident between the score
    and value passes).

    ``k_scale``/``v_scale``: (B, C, KVH) float32 per-row absmax scales
    when k/v hold quantized codes — each live segment dequantizes its
    own slice during the cast+transpose copy, so the skipped-segment
    bandwidth saving applies to quantized reads too.
    """
    del block_k                     # kernel tiling knob; segments are ~C/8
    b, kvh, g, _ = q.shape
    c = k.shape[1]
    hdv = v_width if v_width is not None else v.shape[-1]
    qs = q.astype(jnp.float32) * scale
    lens = jnp.asarray(lens, jnp.int32)
    alias = v is k
    quantized = k_scale is not None
    s_alias = v_scale is None or v_scale is k_scale
    if quantized and v_scale is None:
        v_scale = k_scale
    seg = -(-c // _LAX_SEGMENTS)

    def seg_partial(kp, vp, ksp, vsp, lo):
        kf = kp.transpose(0, 2, 1, 3).astype(jnp.float32)  # (B,KVH,S,hdq)
        if quantized:
            kf = kf * ksp.transpose(0, 2, 1).astype(jnp.float32)[..., None]
        if v_width is not None and vp is kp and (not quantized or s_alias):
            vf = kf[..., :v_width]
        else:
            vf = vp.transpose(0, 2, 1, 3).astype(jnp.float32)
            if quantized:
                vf = vf * vsp.transpose(0, 2, 1) \
                    .astype(jnp.float32)[..., None]
            if v_width is not None:
                vf = vf[..., :v_width]
        s = jnp.einsum("bhgd,bhkd->bhgk", qs, kf)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        cols = lo + jnp.arange(kp.shape[1], dtype=jnp.int32)[None, None,
                                                             None]
        cur = lens[:, None, None, None]
        if ring:
            valid = jnp.mod(cur - cols, c) <= cur
        else:
            valid = cols <= cur
        s = jnp.where(valid, s, NEG_INF)
        # a row fully masked within a live segment yields m == NEG_INF
        # and garbage l/acc — both are annihilated by exp(m - m_final)
        # underflowing to exactly 0.0 in the merge.
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        acc = jnp.einsum("bhgk,bhkd->bhgd", p, vf)
        return m, l, acc

    # every valid slot of every row lies below ``need``: a row's valid
    # positions are <= lens[b], and a wrapped ring (lens >= C) needs
    # the full cache, which min(lens, C-1) selects.
    need = jnp.minimum(jnp.max(lens), c - 1) + 1
    skip = (jnp.full((b, kvh, g, 1), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, 1), jnp.float32),
            jnp.zeros((b, kvh, g, hdv), jnp.float32))
    parts = []
    for lo in range(0, c, seg):
        kp = k[:, lo:lo + seg]
        vp = kp if alias else v[:, lo:lo + seg]
        if quantized:
            ksp = k_scale[:, lo:lo + seg]
            vsp = ksp if s_alias else v_scale[:, lo:lo + seg]
        else:
            ksp = vsp = None
        if lo == 0:                 # slot 0 is always valid
            parts.append(seg_partial(kp, vp, ksp, vsp, 0))
            continue
        parts.append(jax.lax.cond(
            need > lo,
            lambda kp_, vp_, lo_=lo, ks_=ksp, vs_=vsp:
                seg_partial(kp_, vp_, ks_, vs_, lo_),
            lambda kp_, vp_: skip, kp, vp))
    ms = jnp.stack([p[0] for p in parts])
    m = jnp.max(ms, axis=0)
    w = jnp.exp(ms - m)             # (S, B, KVH, G, 1); skipped -> 0.0
    l = jnp.sum(w * jnp.stack([p[1] for p in parts]), axis=0)
    acc = jnp.sum(w * jnp.stack([p[2] for p in parts]), axis=0)
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def decode_attention_paged_lax(q, k_pool, v_pool, page_table, lens, *,
                               window=None, softcap=None, scale: float = 1.0,
                               v_width=None, k_scale=None, v_scale=None):
    """Length-aware masked *paged* decode attention in plain XLA.

    q (B, KVH, G, hdq); pools (P, page_size, KVH, *); page_table
    (B, NB); lens (B,).  Same segment scheme as ``decode_attention_lax``
    but each live segment first gathers its pages through the page
    table (the gather is the XLA spelling of the kernel's index-map
    indirection, and — like the kernel's clamp — it only happens for
    segments the ``lax.cond`` actually runs, so the read/copy volume
    still tracks the batch-max fill, not the pool size).  Paged caches
    are unwrapped: sliding windows arrive as the explicit ``window``
    mask, which also lets segments wholly below the batch-min window
    start skip.
    """
    b, kvh, g, _ = q.shape
    ps = k_pool.shape[1]
    nb = page_table.shape[1]
    c = nb * ps
    hdv = v_width if v_width is not None else v_pool.shape[-1]
    qs = q.astype(jnp.float32) * scale
    lens = jnp.asarray(lens, jnp.int32)
    pt = page_table.astype(jnp.int32)
    alias = v_pool is k_pool
    quantized = k_scale is not None
    s_alias = v_scale is None or v_scale is k_scale
    if quantized and v_scale is None:
        v_scale = k_scale
    seg_pages = -(-nb // _LAX_SEGMENTS)

    def seg_partial(pages, lo):
        kp = jnp.take(k_pool, pages, axis=0)     # (B, sp, ps, KVH, hd)
        sp = pages.shape[1] * ps
        kf = kp.reshape(b, sp, kvh, -1).transpose(0, 2, 1, 3) \
            .astype(jnp.float32)                 # (B, KVH, S, hdq)
        if quantized:
            ksp = jnp.take(k_scale, pages, axis=0)
            kf = kf * ksp.reshape(b, sp, kvh).transpose(0, 2, 1) \
                .astype(jnp.float32)[..., None]
        if alias and (not quantized or s_alias):
            vf = kf[..., :hdv]
        else:
            vp = jnp.take(v_pool, pages, axis=0)
            vf = vp.reshape(b, sp, kvh, -1).transpose(0, 2, 1, 3) \
                .astype(jnp.float32)
            if quantized:
                vsp = jnp.take(v_scale, pages, axis=0)
                vf = vf * vsp.reshape(b, sp, kvh).transpose(0, 2, 1) \
                    .astype(jnp.float32)[..., None]
            vf = vf[..., :hdv]
        s = jnp.einsum("bhgd,bhkd->bhgk", qs, kf)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        cols = lo + jnp.arange(sp, dtype=jnp.int32)[None, None, None]
        cur = lens[:, None, None, None]
        valid = cols <= cur
        if window is not None:
            valid &= (cur - cols) < window
        s = jnp.where(valid, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        acc = jnp.einsum("bhgk,bhkd->bhgd", p, vf)
        return m, l, acc

    need = jnp.minimum(jnp.max(lens), c - 1) + 1
    front = None
    if window is not None:
        front = jnp.maximum(jnp.min(lens) - (window - 1), 0)
    skip = (jnp.full((b, kvh, g, 1), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, 1), jnp.float32),
            jnp.zeros((b, kvh, g, hdv), jnp.float32))
    parts = []
    for pg_lo in range(0, nb, seg_pages):
        pages = pt[:, pg_lo:pg_lo + seg_pages]
        lo = pg_lo * ps
        hi = lo + pages.shape[1] * ps - 1
        live = need > lo if lo else None
        if front is not None:
            f = front <= hi
            live = f if live is None else live & f
        if live is None:                # first segment, no window: always
            parts.append(seg_partial(pages, 0))
            continue
        parts.append(jax.lax.cond(
            live,
            lambda pages_, lo_=lo: seg_partial(pages_, lo_),
            lambda pages_: skip, pages))
    ms = jnp.stack([p[0] for p in parts])
    m = jnp.max(ms, axis=0)
    w = jnp.exp(ms - m)             # (S, B, KVH, G, 1); skipped -> 0.0
    l = jnp.sum(w * jnp.stack([p[1] for p in parts]), axis=0)
    acc = jnp.sum(w * jnp.stack([p[2] for p in parts]), axis=0)
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def decode_attention_paged(q, k_pool, v_pool, page_table, cur_len, *,
                           window=None, softcap=None, scale: float = 1.0,
                           v_width=None, k_scale=None, v_scale=None,
                           impl: str = "auto"):
    """One-token decode attention over a *paged* cache.

    q: (B, 1, H, hdq) new-token queries.  k_pool/v_pool:
    (P, page_size, KVH, hd*) physical pages shared by all rows, *after*
    the new token's k/v landed (``paged_cache_update``).  page_table:
    (B, NB) int32 logical block -> physical page.  cur_len: (B,) int32.
    Paged caches store sliding-window layers unwrapped, so ``window``
    is an explicit mask here (no ``ring``).  ``v_width`` as in
    ``decode_attention``.  ``k_scale``/``v_scale``: (P, page_size, KVH)
    float32 per-row scale pools when the code pools are quantized
    (``v_scale`` defaults to ``k_scale`` — the MLA aliased cache).
    Returns (B, 1, H, hdv) in q.dtype.
    """
    impl = _resolve(impl)
    b, sq, h, hdq = q.shape
    if sq != 1:
        raise ValueError(f"decode_attention_paged takes one query token, "
                         f"got Sq={sq}")
    kvh = k_pool.shape[2]
    if h % kvh:
        raise ValueError(f"H={h} not divisible by KVH={kvh}")
    g = h // kvh
    qg = q.reshape(b, kvh, g, hdq)
    lens = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    kw = dict(window=window, softcap=softcap, scale=scale, v_width=v_width,
              k_scale=k_scale, v_scale=v_scale)
    if impl == "lax":
        out = decode_attention_paged_lax(qg, k_pool, v_pool, page_table,
                                         lens, **kw)
    elif impl in ("pallas", "pallas_interpret"):
        out = decode_attention_paged_pallas(
            qg, k_pool, v_pool, page_table, lens,
            interpret=impl == "pallas_interpret", **kw)
    else:
        raise ValueError(f"unknown decode_attention impl {impl!r}")
    return out.reshape(b, 1, h, out.shape[-1])


def decode_attention(q, k, v, cur_len, *, ring: bool = False,
                     softcap=None, scale: float = 1.0,
                     block_k: int = 128, v_width=None,
                     k_scale=None, v_scale=None,
                     impl: str = "auto"):
    """One-token decode attention over a full cache.

    q: (B, 1, H, hdq) new-token queries.  k: (B, C, KVH, hdq) and
    v: (B, C, KVH, hdv): the cache *after* the new token's k/v landed at
    its slot.  cur_len: scalar or (B,) int32 — the new token's position
    == tokens already in the cache (valid cache positions are
    ``<= cur_len``).  ``ring=True`` for sliding-window ring-buffer
    caches.  ``v_width``: v is the first ``v_width`` lanes of the given
    array (which may be k itself — the MLA concatenated latent cache).
    ``k_scale``/``v_scale``: (B, C, KVH) float32 per-row absmax scales
    when k/v hold quantized codes (see ``kernels/quant``; ``v_scale``
    defaults to ``k_scale`` — the MLA aliased cache quantizes once).
    Returns (B, 1, H, hdv) in q.dtype; k/v are consumed in their own
    dtype (no cache-wide upcast copy, and quantized caches are
    dequantized blockwise in-register, never materialised).
    """
    impl = _resolve(impl)
    b, sq, h, hdq = q.shape
    if sq != 1:
        raise ValueError(f"decode_attention takes one query token, got "
                         f"Sq={sq}")
    kvh = k.shape[2]
    if h % kvh:
        raise ValueError(f"H={h} not divisible by KVH={kvh}")
    g = h // kvh
    qg = q.reshape(b, kvh, g, hdq)
    lens = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    kw = dict(ring=ring, softcap=softcap, scale=scale, block_k=block_k,
              v_width=v_width, k_scale=k_scale, v_scale=v_scale)
    if impl == "lax":
        out = decode_attention_lax(qg, k, v, lens, **kw)
    elif impl in ("pallas", "pallas_interpret"):
        out = decode_attention_pallas(
            qg, k, v, lens, interpret=impl == "pallas_interpret", **kw)
    else:
        raise ValueError(f"unknown decode_attention impl {impl!r}")
    return out.reshape(b, 1, h, out.shape[-1])
