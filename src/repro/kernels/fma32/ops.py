"""jit'd wrapper for fma32."""
import functools

import jax

from repro.kernels.fma32.fma32 import fma32_pallas


@functools.partial(jax.jit, static_argnames=("iters", "block", "interpret"))
def fma32(x, iters: int = 64, block: int = 256, interpret: bool = False):
    return fma32_pallas(x, iters=iters, block=block, interpret=interpret)
