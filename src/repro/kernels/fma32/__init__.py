from repro.kernels.fma32.ops import fma32
from repro.kernels.fma32.ref import fma32_ref

__all__ = ["fma32", "fma32_ref"]
