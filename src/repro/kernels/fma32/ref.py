"""Pure-jnp oracle for fma32."""
import jax
import jax.numpy as jnp


def fma32_ref(x: jnp.ndarray, iters: int = 64) -> jnp.ndarray:
    a = jnp.float32(1.0000001)
    b = jnp.float32(1e-7)

    def body(_, y):
        return y * a + b

    return jax.lax.fori_loop(0, iters, body, x)
