"""FMA32 — the paper's FLOP-burner benchmark kernel, on the TPU VPU.

Each grid step owns one VMEM block and chains ``iters`` dependent fused
multiply-adds on it (y = y*a + b), so arithmetic intensity grows linearly
with ``iters`` and the kernel walks up the compute roofline (the GPU
original does the same with CUDA-core FMAs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fma_kernel(x_ref, o_ref, *, iters: int):
    y = x_ref[...]
    a = jnp.float32(1.0000001)
    b = jnp.float32(1e-7)

    def body(_, y):
        return y * a + b

    o_ref[...] = jax.lax.fori_loop(0, iters, body, y)


def fma32_pallas(x: jnp.ndarray, iters: int = 64,
                 block: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: (M, N) float32, N a multiple of 128."""
    m, n = x.shape
    bm = min(block, m)
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_fma_kernel, iters=iters),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x)
