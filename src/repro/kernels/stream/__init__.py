from repro.kernels.stream.ops import stream_triad
from repro.kernels.stream.ref import stream_triad_ref

__all__ = ["stream_triad", "stream_triad_ref"]
