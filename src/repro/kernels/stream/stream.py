"""STREAM triad — the paper's device-memory-bandwidth benchmark.

c = a + s*b streamed through VMEM in (bm, N) blocks; arithmetic intensity
~1/12 FLOP/byte, so the kernel pins the HBM roofline by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _triad_kernel(a_ref, b_ref, o_ref, *, scalar: float):
    o_ref[...] = a_ref[...] + scalar * b_ref[...]


def stream_triad_pallas(a, b, scalar: float = 2.0, block: int = 512,
                        interpret: bool = False):
    m, n = a.shape
    bm = min(block, m)
    spec = pl.BlockSpec((bm, n), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_triad_kernel, scalar=scalar),
        grid=(m // bm,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)
