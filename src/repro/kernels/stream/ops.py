"""jit'd wrapper for the STREAM triad."""
import functools

import jax

from repro.kernels.stream.stream import stream_triad_pallas


@functools.partial(jax.jit,
                   static_argnames=("scalar", "block", "interpret"))
def stream_triad(a, b, scalar: float = 2.0, block: int = 512,
                 interpret: bool = False):
    return stream_triad_pallas(a, b, scalar=scalar, block=block,
                               interpret=interpret)
