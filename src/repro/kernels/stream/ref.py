"""Pure-jnp oracle for the STREAM triad."""


def stream_triad_ref(a, b, scalar: float = 2.0):
    return a + scalar * b
