"""Flash attention (blockwise online softmax) for TPU.

Grid (B*H, Sq/bq, Skv/bk), kv innermost with *arbitrary* semantics: the
(bq, hd) fp32 accumulator plus the running row-max m and row-sum l live in
VMEM scratch across the kv sweep; each step loads one (bk, hd) K/V block,
computes (bq, bk) scores on the MXU, applies causal/window masking and
optional logit soft-capping, and folds the block into (m, l, acc) with the
standard rescaling.  The final kv step writes acc / l.

GQA without materializing repeated K/V: K and V keep their (B*KVH, S, hd)
layout and the BlockSpec index map sends query-head h to kv-head
h // (H // KVH) — the repeat happens in the index map, not in HBM.

Fully-masked blocks above the causal diagonal (and outside the sliding
window) are skipped entirely: the mask bounds are block-static, so the
kernel issues no MXU work for them (the flash trick that halves causal
FLOPs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.constants import NEG_INF


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window, softcap,
                  bq: int, bk: int, kv_steps: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * bq
    k_lo = ki * bk
    # block-level skip: entirely above the diagonal / outside the window
    needed = True
    if causal:
        needed = k_lo <= q_lo + bq - 1
    if window is not None:
        needed = jnp.logical_and(
            needed, k_lo + bk - 1 >= q_lo - (window - 1)) \
            if causal else needed

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= rows >= cols
        if window is not None:
            mask &= rows - cols < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                  # (bk, hd)
        acc_ref[...] = alpha * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if isinstance(needed, bool):
        if needed:
            compute()
    else:
        jax.lax.cond(needed, compute, lambda: None)

    @pl.when(ki == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window=None, softcap=None, scale: float = 1.0,
                           block_q: int = 256, block_k: int = 256,
                           interpret: bool = False):
    """q: (BH, Sq, hd), k/v: (BKVH, Skv, hd); BH % BKVH == 0."""
    bh, sq, hd = q.shape
    bkvh, skv, _ = k.shape
    group = bh // bkvh
    bq, bk = min(block_q, sq), min(block_k, skv)
    grid = (bh, sq // bq, skv // bk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, kv_steps=grid[2])

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # m: running row max
            pltpu.VMEM((bq, 1), jnp.float32),    # l: running row sum
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
