"""Model-facing flash-attention wrapper.

Accepts the framework's (B, S, H, hd) layout, flattens to the kernel's
(B*H, S, hd), and — so the kernel is usable in training too — wraps the
Pallas forward in jax.custom_vjp with a reference-recompute backward
(flash backward kernels recompute the score blocks; here the recompute is
the jnp oracle, which XLA rematerializes blockwise under the caller's
checkpoint policy).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref

_INTERPRET_DEFAULT = jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _fa(q, k, v, causal, window, softcap, scale, block_q, block_k,
        interpret):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret)


def _fa_fwd(q, k, v, causal, window, softcap, scale, block_q, block_k,
            interpret):
    out = _fa(q, k, v, causal, window, softcap, scale, block_q, block_k,
              interpret)
    return out, (q, k, v)


def _fa_bwd(causal, window, softcap, scale, block_q, block_k, interpret,
            res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: flash_attention_ref(
            q_, k_, v_, causal=causal, window=window, softcap=softcap,
            scale=scale), q, k, v)
    return vjp(g)


_fa.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    softcap=None, scale: float = 1.0, block_q: int = 256,
                    block_k: int = 256, interpret=None):
    """q: (B, Sq, H, hd), k/v: (B, Skv, KVH, hd) -> (B, Sq, H, hd)."""
    if interpret is None:
        interpret = _INTERPRET_DEFAULT
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    of = _fa(qf, kf, vf, causal, window, softcap, scale,
             min(block_q, sq), min(block_k, skv), interpret)
    return of.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
