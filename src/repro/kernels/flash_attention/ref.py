"""Pure-jnp oracle for flash attention (GQA, causal, window, softcap)."""
import jax
import jax.numpy as jnp

from repro.kernels.constants import NEG_INF


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=1.0):
    """q: (BH, Sq, hd), k/v: (BKVH, Skv, hd)."""
    bh, sq, hd = q.shape
    bkvh, skv, _ = k.shape
    g = bh // bkvh
    k = jnp.repeat(k, g, axis=0)
    v = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= rows >= cols
    if window is not None:
        mask &= rows - cols < window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p.astype(v.dtype), v).astype(q.dtype)
