"""``TelemetryServer`` — stdlib HTTP/SSE endpoint over a
:class:`~repro.telemetry.recorder.PowerRecorder`.

Zero dependencies: ``http.server.ThreadingHTTPServer`` (one daemon
thread per connection) bound to an ephemeral port by default
(``port=0`` — read the real one back from :attr:`port`), fully
exercisable with ``urllib`` in tests.

Endpoints (all JSON unless noted):

  * ``GET /``          — endpoint index.
  * ``GET /timeline``  — per-backend power series
    ``{"series": {backend: [[t, watts], ...]}, "window_mean_watts": x}``.
    Query: ``backend=<name>``, ``since=<t>`` (sensor-clock seconds),
    ``window=<s>`` (smoothing window for the mean, default 1.0).
  * ``GET /requests``  — per-request energy with the prefill/decode
    split; each request carries its contributing ``RegionRecord``\\ s as
    ``as_json()`` strings (bit-faithful round-trip).  Query:
    ``tenant=<name>`` filters to one tenant's requests.
  * ``GET /stats``     — recorder counters merged with engine-provided
    counters (``stall_events``/``stall_p95``, compile counts, throttle
    decisions — whatever the attached stats providers contribute).
  * ``GET /health``    — measurement-plane health: per-backend
    sampler/supervisor state (ok/degraded/failed), coverage gaps,
    staleness, and recent health transitions.
  * ``GET /stream``    — ``text/event-stream`` (SSE): a ``hello`` event,
    then one ``record`` event per newly resolved region record and one
    ``health`` event per backend health transition, with
    ``: keepalive`` comments while idle.  ``curl -N <url>/stream``.

Malformed query values (non-numeric/non-finite ``window=``/``since=``,
ill-formed ``tenant=``) return HTTP 400 with a JSON error body; an
unexpected handler error returns HTTP 500 with a JSON error body — a
monitoring client never sees a bare HTML traceback.

The serving thread never touches the measurement plane: every read
goes through the recorder's locked snapshots, and the SSE fan-out is a
bounded drop-oldest queue per client (see :mod:`repro.telemetry.sse`).
"""
from __future__ import annotations

import http.server
import json
import math
import re
import threading
import urllib.parse
from typing import Optional

from repro.telemetry.recorder import HealthEvent, PowerRecorder
from repro.telemetry.sse import SSESubscriber, format_sse

_INDEX = {
    "endpoints": {
        "/timeline": "power series per backend "
                     "(?backend=, ?since=, ?window=)",
        "/requests": "per-request prefill/decode joules + raw records "
                     "(?tenant=)",
        "/stats": "engine + recorder counters",
        "/health": "per-backend sampler/supervisor health + transitions",
        "/stream": "SSE stream of resolved records + health events "
                   "(curl -N)",
    },
}

# Tenant names accepted on the query string: word chars, dot, dash.
_TENANT_RE = re.compile(r"^[\w.\-]{1,64}$")


class _BadQuery(ValueError):
    """A malformed query parameter (maps to HTTP 400)."""


def _parse_float(q, key, default=None, positive=False):
    """Parse a finite float query parameter or raise :class:`_BadQuery`."""
    if key not in q:
        return default
    raw = q[key]
    try:
        v = float(raw)
    except (TypeError, ValueError):
        raise _BadQuery(f"{key}={raw!r} is not a number")
    if not math.isfinite(v):
        raise _BadQuery(f"{key}={raw!r} must be finite")
    if positive and v <= 0:
        raise _BadQuery(f"{key}={raw!r} must be > 0")
    return v


class _Handler(http.server.BaseHTTPRequestHandler):
    # the server instance injects .recorder/.closing (see TelemetryServer)
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):  # quiet: telemetry, not access logs
        pass

    # -- plumbing -----------------------------------------------------------
    def _send_json(self, payload, status: int = 200) -> None:
        body = json.dumps(payload, sort_keys=True).encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _query(self):
        parsed = urllib.parse.urlsplit(self.path)
        return parsed.path, dict(urllib.parse.parse_qsl(parsed.query))

    # -- routes -------------------------------------------------------------
    def do_GET(self):  # noqa: N802 (BaseHTTPRequestHandler API)
        path, q = self._query()
        try:
            if path == "/":
                self._send_json(_INDEX)
            elif path == "/timeline":
                self._timeline(q)
            elif path == "/requests":
                self._requests(q)
            elif path == "/stats":
                self._send_json(self.server.recorder.stats())
            elif path == "/health":
                self._send_json(self.server.recorder.health())
            elif path == "/stream":
                self._stream()
            else:
                self._send_json({"error": f"unknown path {path!r}"},
                                status=404)
        except (BrokenPipeError, ConnectionResetError):
            pass                      # client went away mid-response
        except _BadQuery as e:
            try:
                self._send_json({"error": str(e)}, status=400)
            except (BrokenPipeError, ConnectionResetError):
                pass
        except Exception as e:        # noqa: BLE001 — JSON 500, not a
            try:                      # bare HTML traceback page
                self._send_json(
                    {"error": f"internal error: {type(e).__name__}: {e}"},
                    status=500)
            except (BrokenPipeError, ConnectionResetError):
                pass

    def _timeline(self, q) -> None:
        rec: PowerRecorder = self.server.recorder
        since = _parse_float(q, "since")
        window = _parse_float(q, "window", default=1.0, positive=True)
        backend = q.get("backend")
        self._send_json({
            "series": rec.watts_series(backend=backend, since=since),
            "window_s": window,
            "window_mean_watts": rec.mean_watts(window, backend=backend),
        })

    def _requests(self, q) -> None:
        rec: PowerRecorder = self.server.recorder
        tenant = q.get("tenant")
        if tenant is not None and not _TENANT_RE.match(tenant):
            raise _BadQuery(f"tenant={tenant!r} is not a valid tenant "
                            "name ([\\w.-], 1-64 chars)")
        reqs = {str(rid): d
                for rid, d in rec.request_energy(tenant=tenant).items()}
        self._send_json({"requests": reqs, "count": len(reqs),
                         "tenant": tenant})

    def _stream(self) -> None:
        rec: PowerRecorder = self.server.recorder
        sub = SSESubscriber()
        unsubscribe = rec.subscribe(lambda r: sub.put(r))
        unsubscribe_health = rec.subscribe_health(lambda ev: sub.put(ev))
        try:
            self.send_response(200)
            self.send_header("Content-Type", "text/event-stream")
            self.send_header("Cache-Control", "no-cache")
            # SSE is an unbounded stream: no Content-Length, close
            # delimits (keep-alive would have the client wait forever).
            self.send_header("Connection", "close")
            self.end_headers()
            self.wfile.write(format_sse(
                json.dumps({"records": rec.stats()["records"]}),
                event="hello"))
            self.wfile.flush()
            while not self.server.closing.is_set():
                item = sub.get(timeout=self.server.sse_keepalive_s)
                if item is None:
                    self.wfile.write(b": keepalive\n\n")
                else:
                    event = ("health" if isinstance(item, HealthEvent)
                             else "record")
                    self.wfile.write(format_sse(item.as_json(),
                                                event=event))
                self.wfile.flush()
        finally:
            unsubscribe()
            unsubscribe_health()


class TelemetryServer:
    """Threaded HTTP/SSE server over a recorder.

    Args:
      recorder: the :class:`PowerRecorder` to serve.
      host: bind address (default loopback — telemetry is unauthenticated,
        so exposing it beyond the host is an explicit opt-in).
      port: TCP port; 0 (default) binds an ephemeral port, read it back
        from :attr:`port` after construction.

    ``start()`` returns immediately (daemon serving thread);
    ``close()`` shuts the listener down and releases SSE clients within
    one keep-alive period.  Usable as a context manager.
    """

    def __init__(self, recorder: PowerRecorder, host: str = "127.0.0.1",
                 port: int = 0, sse_keepalive_s: float = 0.25):
        self.recorder = recorder
        self._httpd = http.server.ThreadingHTTPServer((host, port),
                                                      _Handler)
        self._httpd.daemon_threads = True
        self._httpd.recorder = recorder
        self._httpd.closing = threading.Event()
        self._httpd.sse_keepalive_s = float(sse_keepalive_s)
        self.host = host
        self.port = int(self._httpd.server_address[1])
        self.url = f"http://{host}:{self.port}"
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "TelemetryServer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever,
                kwargs={"poll_interval": 0.05},
                name="pmt-telemetry-http", daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        """Stop serving (idempotent): unblocks SSE handlers, shuts the
        accept loop down, and closes the listening socket."""
        self._httpd.closing.set()
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join(timeout=5.0)
            self._thread = None
        self._httpd.server_close()

    def __enter__(self) -> "TelemetryServer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
