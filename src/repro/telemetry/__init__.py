"""Live telemetry plane: the measurement -> observation half of the
energy control loop.

The serve engine *measures* (per-request, per-phase J/token through
``pmt.Session`` spans); this package makes those measurements
observable while the engine is still running, with zero dependencies
beyond the stdlib:

  * :class:`PowerRecorder` — append-only, bounded in-memory store fed
    by the session's ``MemoryExporter`` (resolved ``RegionRecord``\\ s),
    a ``PowerMonitor`` subscription (``StepEnergy`` records), and a
    non-perturbing poll of each backend's ring sampler (watts
    timelines).  The smoothing window the ``PowerGovernor`` reads lives
    here too.
  * :class:`TelemetryServer` — a stdlib ``http.server`` HTTP endpoint
    over a recorder: ``/timeline`` (power series), ``/requests``
    (per-request prefill/decode joules), ``/stats`` (engine counters),
    and ``/stream`` (live SSE feed of new records).
"""
from repro.telemetry.recorder import (HealthEvent, PowerRecorder,
                                      WattsSample)
from repro.telemetry.server import TelemetryServer
from repro.telemetry.sse import SSESubscriber, format_sse

__all__ = ["PowerRecorder", "WattsSample", "HealthEvent",
           "TelemetryServer", "SSESubscriber", "format_sse"]
