"""``PowerRecorder`` — the bounded in-memory store behind the telemetry
plane.

One recorder aggregates three live streams without perturbing any of
them:

  * **Resolved region records** — subscribe the recorder to a session's
    :class:`~repro.core.export.MemoryExporter` (:meth:`attach`); every
    ``RegionRecord`` the background resolver emits lands in an
    append-only bounded ring and fans out to the recorder's own
    subscribers (the SSE stream).  The callback obeys the
    subscriber-exporter contract: append + notify, no blocking work.
  * **Step/request energy** — :meth:`attach_monitor` taps a
    ``PowerMonitor.subscribe`` stream of ``StepEnergy`` records for
    engines measuring through a monitor instead of a raw session.
  * **Watts timelines** — a poll thread copies each backend ring
    sampler's seqlock-read ``timeline()`` tail into a per-backend
    bounded deque.  Readers of a ``RingSampler`` never block its
    writer, so polling is free of measurement-plane side effects.
    Tests (and the governor's deterministic unit tests) can bypass the
    poller entirely with :meth:`add_watts`.

The :class:`~repro.serve.governor.PowerGovernor` reads its control
signal here (:meth:`mean_watts` over a trailing window), and the
:class:`~repro.telemetry.server.TelemetryServer` serves every endpoint
straight off this object — the recorder is the single point of truth
between measurement and both consumers.
"""
from __future__ import annotations

import collections
import json
import math
import re
import threading
import warnings
from typing import (Any, Callable, Dict, List, NamedTuple, Optional,
                    Sequence, Tuple)

from repro.core.export import MemoryExporter, RegionRecord


class WattsSample(NamedTuple):
    backend: str
    timestamp_s: float
    watts: float


class HealthEvent(NamedTuple):
    """One backend health-state transition (ok/degraded/failed), as
    observed by the recorder's poll loop.  Fans out on the SSE stream
    (``event: health``) and is retained for the ``/health`` endpoint."""

    backend: str
    timestamp_s: float
    state: str           # ok | degraded | failed
    prev_state: str
    detail: str = ""

    def as_json(self) -> str:
        return json.dumps(self._asdict(), sort_keys=True)


_REQ_PATH = re.compile(r"^serve/req(\d+)(?:/(\w+))?$")


class PowerRecorder:
    """Bounded, thread-safe aggregation point for live power telemetry.

    Args:
      watts_capacity: per-backend bound on retained watts samples.
      record_capacity: bound on retained resolved records (region and
        step records each get their own ring of this size).  Older
        entries fall off the front; ``stats()`` counts total appends so
        truncation is visible, never silent.
      poll_period_s: sampler poll period for sessions attached via
        :meth:`attach` (clamped to >= 10 ms so a misconfigured poller
        cannot busy-spin against the seqlock).
    """

    def __init__(self, watts_capacity: int = 65536,
                 record_capacity: int = 8192,
                 poll_period_s: float = 0.05):
        self._lock = threading.Lock()
        self._watts_cap = int(watts_capacity)
        self._watts: "collections.OrderedDict[str, collections.deque]" = \
            collections.OrderedDict()
        self._records: collections.deque = \
            collections.deque(maxlen=int(record_capacity))
        self._steps: collections.deque = \
            collections.deque(maxlen=int(record_capacity))
        self._total_records = 0      # appends ever (ring may have dropped)
        self._total_steps = 0
        self._total_watts = 0
        self._subs: List[Callable[[RegionRecord], None]] = []
        # Health events get their own subscriber list: record
        # subscribers (e.g. the governor's quota accounting) index into
        # RegionRecord fields and would break on a HealthEvent.
        self._health_subs: List[Callable[[HealthEvent], None]] = []
        self._health_events: collections.deque = \
            collections.deque(maxlen=1024)
        self._total_health_events = 0
        self._last_health_state: Dict[str, str] = {}
        self._unsubs: List[Callable[[], None]] = []
        self._stats_providers: List[Callable[[], Dict[str, Any]]] = []
        self._engine = None
        self._poll_period_s = max(0.010, float(poll_period_s))
        self._poll_stop = threading.Event()
        self._poll_thread: Optional[threading.Thread] = None
        self._poll_sources: List[Tuple[str, Any]] = []
        self._poll_last_t: Dict[str, float] = {}
        self._closed = False

    # -- ingestion ----------------------------------------------------------
    def on_record(self, rec: RegionRecord) -> None:
        """Exporter-subscriber callback: append + fan out, never block."""
        with self._lock:
            self._records.append(rec)
            self._total_records += 1
            subs = list(self._subs)
        for fn in subs:
            try:
                fn(rec)
            except Exception as e:
                self._drop_subscriber(fn)
                warnings.warn(
                    f"PowerRecorder subscriber {fn!r} raised "
                    f"{type(e).__name__}: {e}; subscriber dropped")

    def on_step_energy(self, se) -> None:
        """``PowerMonitor.subscribe`` callback (StepEnergy stream)."""
        with self._lock:
            self._steps.append(se)
            self._total_steps += 1

    def add_watts(self, backend: str, timestamp_s: float,
                  watts: float) -> None:
        """Inject one watts sample directly (tests, synthetic traces)."""
        if not math.isfinite(watts):
            return
        with self._lock:
            ring = self._watts.get(backend)
            if ring is None:
                ring = self._watts[backend] = collections.deque(
                    maxlen=self._watts_cap)
            ring.append((float(timestamp_s), float(watts)))
            self._total_watts += 1

    # -- wiring -------------------------------------------------------------
    def attach(self, session, exporter: Optional[MemoryExporter] = None
               ) -> "PowerRecorder":
        """Wire this recorder to ``session``: subscribe to a
        ``MemoryExporter`` (added to the session if not supplied) and
        start polling the session's ring samplers for watts timelines.
        Idempotent per session is *not* attempted — attach once.
        """
        if exporter is None:
            exporter = session.add_exporter(MemoryExporter())
        self._unsubs.append(exporter.subscribe(self.on_record))
        with self._lock:
            self._poll_sources.extend(session.samplers())
        self._ensure_poll_thread()
        return self

    def attach_monitor(self, monitor) -> "PowerRecorder":
        """Tap a ``PowerMonitor``'s settled StepEnergy stream."""
        self._unsubs.append(monitor.subscribe(self.on_step_energy))
        return self

    def attach_engine(self, engine) -> "PowerRecorder":
        """Bind a ``ServeEngine``: its counters join :meth:`stats` and
        its per-request tenant map labels :meth:`request_energy`.  An
        engine exposing ``on_record`` (paged mode's prefill
        joules-per-token estimator behind ``saved_prefill_joules``) is
        additionally subscribed to the resolved-record stream."""
        self._engine = engine
        self.add_stats_provider(engine.stats)
        if hasattr(engine, "on_record"):
            self._unsubs.append(self.subscribe(engine.on_record))
        return self

    def add_stats_provider(self, fn: Callable[[], Dict[str, Any]]) -> None:
        """Register a callable contributing keys to :meth:`stats` (the
        serve engine's counters ride in this way)."""
        with self._lock:
            self._stats_providers.append(fn)

    def subscribe(self, fn: Callable[[RegionRecord], None]
                  ) -> Callable[[], None]:
        """Register ``fn`` for every future region record (SSE fan-out);
        returns an unsubscribe.  Same contract as the exporter's:
        called on the resolving thread, must not block, dropped with a
        warning if it raises."""
        with self._lock:
            self._subs.append(fn)

        def unsubscribe() -> None:
            self._drop_subscriber(fn)

        return unsubscribe

    def subscribe_health(self, fn: Callable[[HealthEvent], None]
                         ) -> Callable[[], None]:
        """Register ``fn`` for backend health transitions (SSE fan-out);
        returns an unsubscribe.  Same non-blocking contract as
        :meth:`subscribe`."""
        with self._lock:
            self._health_subs.append(fn)

        def unsubscribe() -> None:
            with self._lock:
                for i, sub in enumerate(self._health_subs):
                    if sub is fn:
                        del self._health_subs[i]
                        break

        return unsubscribe

    def _drop_subscriber(self, fn) -> None:
        with self._lock:
            for i, sub in enumerate(self._subs):
                if sub is fn:
                    del self._subs[i]
                    break

    # -- sampler polling ----------------------------------------------------
    def _ensure_poll_thread(self) -> None:
        with self._lock:
            if self._poll_thread is not None or self._closed:
                return
            self._poll_thread = threading.Thread(
                target=self._poll_loop, name="pmt-telemetry-poll",
                daemon=True)
        self._poll_thread.start()

    def _poll_loop(self) -> None:
        while not self._poll_stop.wait(self._poll_period_s):
            self.poll_once()

    def poll_once(self) -> int:
        """Copy each attached sampler's new watts samples in; returns
        how many samples were ingested (also callable directly for
        deterministic tests)."""
        with self._lock:
            sources = list(self._poll_sources)
        n = 0
        for name, sampler in sources:
            try:
                ts, _js, ws = sampler.timeline()
            except Exception:
                continue          # sampler stopped underneath us: stale
            last = self._poll_last_t.get(name, float("-inf"))
            for t, w in zip(ts, ws):
                if t > last and math.isfinite(w):
                    self.add_watts(name, float(t), float(w))
                    n += 1
            if len(ts):
                self._poll_last_t[name] = float(ts[-1])
        self._poll_health(sources)
        return n

    def _poll_health(self, sources) -> None:
        """Watch each sampler's health state; emit a :class:`HealthEvent`
        on every transition (first observation included when not ok)."""
        for name, sampler in sources:
            health_fn = getattr(sampler, "health", None)
            if not callable(health_fn):
                continue
            try:
                h = health_fn()
            except Exception:
                continue          # sampler stopped underneath us
            state = h.get("state", "ok")
            with self._lock:
                prev = self._last_health_state.get(name)
                if prev == state:
                    continue
                self._last_health_state[name] = state
                if prev is None and state == "ok":
                    continue      # don't announce the healthy baseline
                sup = h.get("supervisor") or {}
                ev = HealthEvent(
                    backend=name,
                    timestamp_s=float(sampler.last_ts())
                    if math.isfinite(sampler.last_ts()) else 0.0,
                    state=state, prev_state=prev or "ok",
                    detail=f"read_errors={h.get('read_errors', 0)} "
                           f"gaps={h.get('gaps', 0)} "
                           f"active={sup.get('active_backend', name)}")
                self._health_events.append(ev)
                self._total_health_events += 1
                subs = list(self._health_subs)
            for fn in subs:
                try:
                    fn(ev)
                except Exception as e:
                    warnings.warn(
                        f"PowerRecorder health subscriber {fn!r} raised "
                        f"{type(e).__name__}: {e}")

    # -- reads --------------------------------------------------------------
    def watts_series(self, backend: Optional[str] = None,
                     since: Optional[float] = None
                     ) -> Dict[str, List[List[float]]]:
        """``{backend: [[timestamp_s, watts], ...]}`` power series."""
        with self._lock:
            items = [(b, list(ring)) for b, ring in self._watts.items()
                     if backend is None or b == backend]
        out: Dict[str, List[List[float]]] = {}
        for b, samples in items:
            if since is not None:
                samples = [s for s in samples if s[0] > since]
            out[b] = [[t, w] for t, w in samples]
        return out

    def mean_watts(self, window_s: float, backend: Optional[str] = None,
                   now: Optional[float] = None) -> Optional[float]:
        """Smoothed power over the trailing ``window_s`` seconds —
        the governor's control signal.

        Per backend: the mean of samples newer than ``now - window_s``
        (falling back to the single newest sample when the window is
        empty, so a slow-ticking backend still reports).  Multiple
        backends sum — the cap is a budget on total draw.  Returns
        ``None`` when no backend has any sample yet.
        """
        with self._lock:
            items = [(b, list(ring)) for b, ring in self._watts.items()
                     if backend is None or b == backend]
        total = None
        for _b, samples in items:
            if not samples:
                continue
            if now is None:
                t_now = samples[-1][0]
            else:
                t_now = now
            cut = t_now - window_s
            win = [w for t, w in samples if t >= cut]
            mean = (sum(win) / len(win)) if win else samples[-1][1]
            total = mean if total is None else total + mean
        return total

    def last_watts_ts(self, backend: Optional[str] = None
                      ) -> Optional[float]:
        """Timestamp of the newest watts sample (``None`` if none yet) —
        the governor's signal-TTL staleness check.  With multiple
        backends summed into one control signal, the *oldest* newest
        sample governs: the summed signal is only as fresh as its most
        stale contributor."""
        with self._lock:
            newest = [ring[-1][0] for b, ring in self._watts.items()
                      if ring and (backend is None or b == backend)]
        return min(newest) if newest else None

    def health(self) -> Dict[str, Any]:
        """Measurement-plane health for the ``/health`` endpoint:
        per-backend sampler/supervisor snapshots + recent transitions."""
        with self._lock:
            sources = list(self._poll_sources)
            events = list(self._health_events)
        backends: Dict[str, Any] = {}
        worst = "ok"
        rank = {"ok": 0, "degraded": 1, "failed": 2}
        for name, sampler in sources:
            health_fn = getattr(sampler, "health", None)
            if not callable(health_fn):
                continue
            try:
                h = health_fn()
            except Exception as e:
                h = {"state": "failed", "error": f"{type(e).__name__}: {e}"}
            backends[name] = h
            state = h.get("state", "ok")
            if rank.get(state, 0) > rank[worst]:
                worst = state
        return {
            "state": worst,
            "backends": backends,
            "events": [ev._asdict() for ev in events],
            "health_events": self._total_health_events,
        }

    def health_events(self) -> List[HealthEvent]:
        with self._lock:
            return list(self._health_events)

    def records(self) -> List[RegionRecord]:
        with self._lock:
            return list(self._records)

    def step_records(self) -> List[Any]:
        with self._lock:
            return list(self._steps)

    def request_energy(self, tenant: Optional[str] = None
                       ) -> Dict[int, Dict[str, Any]]:
        """Per-request energy as seen through the recorder.

        Aggregates ``serve/req<N>`` (and ``.../prefill``, ``.../decode``)
        region records — and, for monitor-driven engines, StepEnergy
        records with ``scope == "request"`` — into
        ``{request_id: {joules, seconds, tokens, j_per_token,
        prefill_joules, decode_joules, records: [...]}}``.  ``records``
        holds each contributing region record's ``as_json()`` string, so
        a client can round-trip the exact resolved records
        (``RegionRecord.from_json``) bit-faithfully.

        When an engine is attached (:meth:`attach_engine`) each bucket
        carries the request's ``tenant``, and ``tenant=`` filters the
        result to that tenant's requests.
        """
        out: Dict[int, Dict[str, Any]] = {}
        engine = self._engine
        tenants: Dict[int, str] = {}
        if engine is not None:
            tenants = dict(getattr(engine, "request_tenants", {}))

        def bucket(rid: int) -> Dict[str, Any]:
            return out.setdefault(rid, {
                "joules": 0.0, "seconds": 0.0, "tokens": 0,
                "prefill_joules": 0.0, "decode_joules": 0.0,
                "tenant": tenants.get(rid),
                "records": []})

        for rec in self.records():
            m = _REQ_PATH.match(rec.path)
            if not m:
                continue
            rid, phase = int(m.group(1)), m.group(2)
            d = bucket(rid)
            d["records"].append(rec.as_json())
            if phase is None:
                d["joules"] += rec.joules
                d["seconds"] = max(d["seconds"], rec.seconds)
                d["tokens"] = rec.tokens or d["tokens"]
            else:
                d[f"{phase}_joules"] = d.get(f"{phase}_joules", 0.0) \
                    + rec.joules
        for se in self.step_records():
            if getattr(se, "scope", None) != "request":
                continue
            d = bucket(se.step)
            if se.phase is None:
                d["joules"] += se.joules
                d["seconds"] = max(d["seconds"], se.seconds)
                d["tokens"] = se.tokens or d["tokens"]
            else:
                d[f"{se.phase}_joules"] = d.get(f"{se.phase}_joules", 0.0) \
                    + se.joules
        for d in out.values():
            d["j_per_token"] = d["joules"] / max(d["tokens"], 1)
        if tenant is not None:
            out = {rid: d for rid, d in out.items()
                   if d["tenant"] == tenant}
        return out

    def stats(self) -> Dict[str, Any]:
        """Recorder counters merged with every registered stats
        provider's dict (provider keys win on collision)."""
        with self._lock:
            out: Dict[str, Any] = {
                "records": self._total_records,
                "records_retained": len(self._records),
                "step_records": self._total_steps,
                "watts_samples": self._total_watts,
                "watts_backends": {b: len(ring)
                                   for b, ring in self._watts.items()},
                "subscribers": len(self._subs),
                "health_events": self._total_health_events,
                "backend_health": dict(self._last_health_state),
            }
            providers = list(self._stats_providers)
        for fn in providers:
            try:
                out.update(fn())
            except Exception as e:
                out.setdefault("stats_provider_errors", []).append(
                    f"{type(e).__name__}: {e}")
        return out

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        """Stop the poll thread and detach every subscription
        (idempotent).  Retained data stays readable after close."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            thread = self._poll_thread
            self._poll_thread = None
            unsubs, self._unsubs = self._unsubs, []
        self._poll_stop.set()
        if thread is not None:
            thread.join(timeout=5.0)
        for unsub in unsubs:
            try:
                unsub()
            except Exception:
                pass

    def __enter__(self) -> "PowerRecorder":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
