"""Server-Sent Events plumbing for the telemetry server.

SSE (``text/event-stream``) is the zero-dependency live-push channel:
one long-lived HTTP response the server appends ``event:``/``data:``
framed messages to, consumable with ``curl -N`` or a browser
``EventSource`` — no websocket library required.

The piece that matters for correctness is :class:`SSESubscriber`: the
recorder's fan-out callback runs on the *resolver* thread and must not
block (see the Session subscriber-exporter contract), while the HTTP
handler writes on its own per-client thread at whatever pace the
client drains.  The subscriber decouples the two with a bounded queue
that drops the *oldest* event on overflow — a slow client loses old
records (counted, surfaced in ``/stats``) instead of back-pressuring
the measurement plane.
"""
from __future__ import annotations

import collections
import threading
from typing import Optional


def format_sse(data: str, event: Optional[str] = None,
               event_id: Optional[str] = None) -> bytes:
    """Frame one SSE message.  ``data`` may span lines; each line gets
    its own ``data:`` field per the spec."""
    out = []
    if event_id is not None:
        out.append(f"id: {event_id}")
    if event is not None:
        out.append(f"event: {event}")
    for line in data.splitlines() or [""]:
        out.append(f"data: {line}")
    return ("\n".join(out) + "\n\n").encode("utf-8")


class SSESubscriber:
    """Bounded hand-off queue between the resolver-thread producer and
    one SSE client's writer thread.

    ``put`` never blocks: on overflow the oldest queued event is
    dropped and counted.  ``get`` blocks up to ``timeout`` so the
    writer loop can interleave keep-alive comments and notice server
    shutdown promptly.
    """

    def __init__(self, maxlen: int = 1024):
        self._buf: collections.deque = collections.deque()
        self._maxlen = int(maxlen)
        self._lock = threading.Lock()
        self._ready = threading.Event()
        self.dropped = 0

    def put(self, item) -> None:
        with self._lock:
            if len(self._buf) >= self._maxlen:
                self._buf.popleft()
                self.dropped += 1
            self._buf.append(item)
            self._ready.set()

    def get(self, timeout: float):
        """Next queued item, or ``None`` after ``timeout`` seconds."""
        if not self._ready.wait(timeout):
            return None
        with self._lock:
            if not self._buf:
                self._ready.clear()
                return None
            item = self._buf.popleft()
            if not self._buf:
                self._ready.clear()
            return item

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)
