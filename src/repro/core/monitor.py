"""PowerMonitor — PMT integrated into the training/serving loop.

This is the framework-facing layer (DESIGN.md §3): per-step energy
attribution over one or more sensors, a CSV energy log, cumulative
accounting that survives checkpoint/restart, and power-based straggler
detection for the fault-tolerance stack.

JAX-awareness: dispatch is asynchronous, so a step is only attributed the
energy between explicit ``block_until_ready`` boundaries — the caller (or
the provided ``measure_step`` context manager, which blocks on exit if
given outputs) must fence, otherwise readings would attribute a step's
tail to its successor.
"""
from __future__ import annotations

import contextlib
import dataclasses
import statistics
import threading
from typing import Dict, List, Optional, Sequence, TextIO, Union

from repro.core import registry
from repro.core.metrics import EfficiencyReport
from repro.core.sensor import Sensor
from repro.core.state import State


@dataclasses.dataclass(frozen=True)
class StepEnergy:
    """Energy record for one step, one sensor."""

    step: int
    sensor: str
    kind: str
    joules: float
    seconds: float
    watts: float
    flops: Optional[float] = None
    tokens: Optional[int] = None

    def report(self) -> EfficiencyReport:
        return EfficiencyReport(joules=self.joules, seconds=self.seconds,
                                flops=self.flops, tokens=self.tokens)


class PowerMonitor:
    """Attributes per-step energy across a set of sensors.

    Args:
      sensors: backend names or Sensor instances (stacked like the paper's
        multi-decorator usage — e.g. ["cpuutil", "tpu"]).
      log_path: optional CSV energy log (append mode, crash-tolerant:
        one flushed line per step).
      initial_joules: cumulative joules carried over from a checkpoint.
    """

    CSV_HEADER = ("step,sensor,kind,joules,seconds,watts,flops,tokens,"
                  "gflops_per_watt,edp\n")

    def __init__(self, sensors: Sequence[Union[str, Sensor]],
                 log_path: Optional[str] = None,
                 initial_joules: float = 0.0):
        self.sensors: List[Sensor] = [
            s if isinstance(s, Sensor) else registry.create(s)
            for s in sensors]
        if not self.sensors:
            raise ValueError("PowerMonitor needs at least one sensor")
        self._records: List[StepEnergy] = []
        self._cumulative_joules = float(initial_joules)
        self._lock = threading.Lock()
        self._log: Optional[TextIO] = None
        if log_path:
            self._log = open(log_path, "a", buffering=1)
            if self._log.tell() == 0:
                self._log.write(self.CSV_HEADER)

    # -- per-step measurement --------------------------------------------
    @contextlib.contextmanager
    def measure_step(self, step: int, flops: Optional[float] = None,
                     tokens: Optional[int] = None):
        """Context manager measuring one fenced step across all sensors.

        The caller must ensure device work is complete before the block
        exits (``jax.block_until_ready`` on the step outputs).
        """
        starts = [s.read() for s in self.sensors]
        box = _StepBox()
        try:
            yield box
        finally:
            ends = [s.read() for s in self.sensors]
            recs = []
            for sensor, st, en in zip(self.sensors, starts, ends):
                recs.append(StepEnergy(
                    step=step, sensor=sensor.name, kind=sensor.kind,
                    joules=Sensor.joules(st, en),
                    seconds=Sensor.seconds(st, en),
                    watts=Sensor.watts(st, en),
                    flops=flops, tokens=tokens))
            with self._lock:
                self._records.extend(recs)
                self._cumulative_joules += sum(r.joules for r in recs)
            for r in recs:
                self._write_log(r)
            box.records = recs

    def _write_log(self, r: StepEnergy) -> None:
        if self._log is None:
            return
        rep = r.report()
        g = rep.gflops_per_watt
        self._log.write(
            f"{r.step},{r.sensor},{r.kind},{r.joules:.6f},{r.seconds:.6f},"
            f"{r.watts:.3f},{'' if r.flops is None else f'{r.flops:.0f}'},"
            f"{'' if r.tokens is None else r.tokens},"
            f"{'' if g is None else f'{g:.3f}'},{rep.edp:.6f}\n")

    # -- cumulative accounting (checkpointable) -----------------------------
    @property
    def cumulative_joules(self) -> float:
        with self._lock:
            return self._cumulative_joules

    def state_dict(self) -> Dict[str, float]:
        """Energy state persisted inside checkpoints (DESIGN.md §3)."""
        with self._lock:
            recent = self._records[-32:]
            j_per_step = (statistics.fmean(r.joules for r in recent)
                          if recent else 0.0)
            return {"cumulative_joules": self._cumulative_joules,
                    "joules_per_step_ema": j_per_step}

    def records(self) -> List[StepEnergy]:
        with self._lock:
            return list(self._records)

    def close(self) -> None:
        if self._log is not None:
            self._log.close()
            self._log = None


class _StepBox:
    """Filled with the step's records when measure_step exits."""

    records: List[StepEnergy] = ()


# -- fleet-level straggler detection (fault-tolerance integration) ---------

@dataclasses.dataclass(frozen=True)
class StragglerVerdict:
    host: int
    power_w: float
    step_s: float
    power_z: float
    time_z: float
    is_straggler: bool


def detect_stragglers(host_power_w: Sequence[float],
                      host_step_s: Sequence[float],
                      power_sigma: float = 3.0,
                      time_sigma: float = 3.0) -> List[StragglerVerdict]:
    """Flag hosts whose power deviates while their step time lags.

    A host that is *slow* and *anomalous in power* (low → throttling or a
    dead accelerator; high → a runaway/thermal issue) is a straggler
    candidate.  Power alone is not enough (data skew changes power
    legitimately); time alone is the classic detector — requiring both
    cuts false positives.  Uses robust (median/MAD) z-scores.
    """
    if len(host_power_w) != len(host_step_s):
        raise ValueError("power and step-time vectors must align")
    n = len(host_power_w)
    if n == 0:
        return []

    def robust_z(xs: Sequence[float]) -> List[float]:
        med = statistics.median(xs)
        mad = statistics.median([abs(x - med) for x in xs])
        scale = 1.4826 * mad
        if scale == 0.0:
            # MAD degenerates when >50% of hosts are identical (the
            # common healthy-fleet case) — fall back to the std so a
            # single outlier is still visible.
            scale = statistics.pstdev(xs) if len(xs) > 1 else 0.0
        if scale == 0.0:
            return [0.0] * len(xs)
        return [(x - med) / scale for x in xs]

    pz = robust_z(host_power_w)
    tz = robust_z(host_step_s)
    out = []
    for i in range(n):
        slow = tz[i] > time_sigma
        odd_power = abs(pz[i]) > power_sigma
        out.append(StragglerVerdict(
            host=i, power_w=host_power_w[i], step_s=host_step_s[i],
            power_z=pz[i], time_z=tz[i], is_straggler=bool(slow and odd_power)))
    return out
