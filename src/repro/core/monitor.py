"""PowerMonitor — PMT integrated into the training/serving loop.

This is the framework-facing layer (DESIGN.md §3): per-step energy
attribution over one or more sensors, a CSV energy log, cumulative
accounting that survives checkpoint/restart, and power-based straggler
detection for the fault-tolerance stack.

Since the ``pmt.Session`` redesign the monitor no longer polls sensors
itself: ``measure_step`` opens a session region, so step energy resolves
against the shared background ring sampler.  A monitor can run on its own
session (default; sensors still shared via the process pool) or be handed
an existing one, in which case the serve engine, the train loop, and the
monitor all attach to the same sampler per backend instead of
double-polling.  ``measure_step(..., blocking=False)`` keeps even
resolution off the loop: step exit enqueues the span, the monitor's
records/CSV/cumulative counters update when the session's background
resolver finishes it, and reads of accumulated state settle in-flight
steps first.

JAX-awareness: dispatch is asynchronous, so a step is only attributed the
energy between explicit ``block_until_ready`` boundaries — the caller (or
the provided ``measure_step`` context manager, which blocks on exit if
given outputs) must fence, otherwise readings would attribute a step's
tail to its successor.
"""
from __future__ import annotations

import contextlib
import dataclasses
import statistics
import threading
import warnings
from typing import Callable, Dict, List, Optional, Sequence, TextIO, Union

from repro.core.metrics import EfficiencyReport
from repro.core.sensor import Sensor, SensorError
from repro.core.session import Session


@dataclasses.dataclass(frozen=True)
class StepEnergy:
    """Energy record for one step (or serve request), one sensor.

    ``scope`` distinguishes training/serving *steps* from per-request
    serve spans (``measure_request``); ``step`` holds the request id for
    the latter.
    """

    step: int
    sensor: str
    kind: str
    joules: float
    seconds: float
    watts: float
    flops: Optional[float] = None
    tokens: Optional[int] = None
    scope: str = "step"
    # serve phase split: "prefill" / "decode" child spans of a request
    # (None = the whole-request span)
    phase: Optional[str] = None

    def report(self) -> EfficiencyReport:
        return EfficiencyReport(joules=self.joules, seconds=self.seconds,
                                flops=self.flops, tokens=self.tokens)


class PowerMonitor:
    """Attributes per-step energy across a set of sensors.

    Args:
      sensors: backend names or Sensor instances (stacked like the paper's
        multi-decorator usage — e.g. ["cpuutil", "tpu"]).  May be empty
        when ``session`` already has backends attached.
      log_path: optional CSV energy log (append mode, crash-tolerant:
        one flushed line per step).
      initial_joules: cumulative joules carried over from a checkpoint.
      session: an existing :class:`pmt.Session` to measure through; the
        monitor attaches its sensors to it and does NOT close it.  When
        omitted the monitor owns a private session on the shared pool.
    """

    CSV_HEADER = ("step,sensor,kind,joules,seconds,watts,flops,tokens,"
                  "gflops_per_watt,edp\n")

    def __init__(self, sensors: Sequence[Union[str, Sensor]] = (),
                 log_path: Optional[str] = None,
                 initial_joules: float = 0.0,
                 session: Optional[Session] = None):
        self._owns_session = session is None
        self._session = session if session is not None else Session()
        try:
            for s in sensors:
                self._session.attach(s)
        except BaseException:
            if self._owns_session:
                self._session.close()
            raise
        self.sensors: List[Sensor] = self._session.sensors
        if not self.sensors:
            raise ValueError("PowerMonitor needs at least one sensor")
        self._records: List[StepEnergy] = []
        self._cumulative_joules = float(initial_joules)
        self._inflight: set = set()      # non-blocking boxes not yet settled
        self._subs: List[Callable[[StepEnergy], None]] = []
        self._lock = threading.Lock()
        self._log: Optional[TextIO] = None
        if log_path:
            self._log = open(log_path, "a", buffering=1)
            if self._log.tell() == 0:
                self._log.write(self.CSV_HEADER)

    @property
    def session(self) -> Session:
        return self._session

    # -- live record stream -------------------------------------------------
    def subscribe(self, fn: Callable[[StepEnergy], None]):
        """Register ``fn`` for every :class:`StepEnergy` as it settles
        (step *and* request/phase records); returns an unsubscribe.

        The callback runs on whichever thread resolves the span —
        usually the session's background resolver — so it must not
        block; if it raises it is dropped with a warning (mirroring the
        :class:`~repro.core.export.MemoryExporter` subscriber contract).
        The telemetry plane's :class:`~repro.telemetry.PowerRecorder`
        hangs off this to stream per-step/per-request energy live.
        """
        with self._lock:
            self._subs.append(fn)

        def unsubscribe() -> None:
            with self._lock:
                for i, sub in enumerate(self._subs):
                    if sub is fn:
                        del self._subs[i]
                        break

        return unsubscribe

    def _fanout(self, recs: List[StepEnergy]) -> None:
        """Deliver settled records to subscribers (no locks held)."""
        with self._lock:
            subs = list(self._subs)
        for fn in subs:
            for r in recs:
                try:
                    fn(r)
                except Exception as e:
                    with self._lock:
                        for i, sub in enumerate(self._subs):
                            if sub is fn:
                                del self._subs[i]
                                break
                    warnings.warn(
                        f"PowerMonitor subscriber {fn!r} raised "
                        f"{type(e).__name__}: {e}; subscriber dropped")
                    break

    # -- per-step measurement --------------------------------------------
    def measure_step(self, step: int, flops: Optional[float] = None,
                     tokens: Optional[int] = None, blocking: bool = True):
        """Context manager measuring one fenced step across all sensors.

        A thin wrapper over ``session.region(...)`` — entry/exit touch no
        sensors on this thread.  With ``blocking=True`` (the classic
        contract) the step resolves against the shared ring buffer at
        exit and ``box.records`` is materialised before the ``with``
        block returns.  With ``blocking=False`` exit is O(1): the span
        resolves on the session's background resolver thread, the
        monitor's accounting/CSV update when it does, and ``box.records``
        only blocks (resolving synchronously) if actually read — the
        hot-loop mode ``make_measured_train_step`` and the serve engine
        use.

        The caller must ensure device work is complete before the block
        exits (``jax.block_until_ready`` on the step outputs).
        """
        return self._measure(f"step{step}", step, flops, tokens, blocking,
                             nested=True, scope="step")

    def measure_request(self, request_id: int,
                        flops: Optional[float] = None,
                        tokens: Optional[int] = None,
                        blocking: bool = False,
                        phase: Optional[str] = None):
        """Measure one *serve request* end to end (admission -> last token),
        or — with ``phase="prefill"``/``"decode"`` — one phase of it.

        Unlike ``measure_step`` this opens a flat (non-nested) session
        span: the serve engine holds many request spans open at once and
        closes them in completion order, which the thread-local nesting
        stack cannot express.  Records land with ``scope="request"`` and
        ``step=request_id`` (phase spans additionally carry
        ``phase``, under the ``req<N>/<phase>`` label); read them back
        via :meth:`request_records` or :meth:`per_request_energy`
        (J/token per request, with the prefill/decode J split).

        Request spans overlap each other *and* the aggregate
        ``measure_step`` region covering the same wall-clock window, so
        they are attribution views, not additional energy: they are
        excluded from :attr:`cumulative_joules` and the per-step CSV
        log (which both account each joule exactly once, via steps).
        The two phase spans tile the request span, so their joules sum
        to the request total (within sampler interpolation).
        """
        label = f"req{request_id}" + (f"/{phase}" if phase else "")
        return self._measure(label, request_id, flops, tokens,
                             blocking, nested=False, scope="request",
                             phase=phase)

    @contextlib.contextmanager
    def _measure(self, label: str, step: int, flops: Optional[float],
                 tokens: Optional[int], blocking: bool, nested: bool,
                 scope: str, phase: Optional[str] = None):
        box = _StepBox()

        def finish(measurements):
            recs = [StepEnergy(
                step=step, sensor=m.sensor, kind=m.kind, joules=m.joules,
                seconds=m.seconds, watts=m.watts, flops=flops,
                tokens=tokens, scope=scope, phase=phase)
                for m in measurements]
            with self._lock:
                self._records.extend(recs)
                if scope == "step":
                    # request spans overlap the step region measuring
                    # the same window — counting both would double-book
                    # joules in the checkpointable total and the CSV
                    self._cumulative_joules += sum(r.joules for r in recs)
                    for r in recs:
                        self._write_log(r)
                self._inflight.discard(box)
            box._records = recs
            self._fanout(recs)

        handle = self._session.region(label, flops=flops, tokens=tokens,
                                      on_resolved=finish, nested=nested)
        box._handle = handle
        handle.__enter__()
        try:
            yield box
        finally:
            with self._lock:
                self._inflight.add(box)
            handle.__exit__(None, None, None)
            if blocking:
                handle.measurements     # forces resolution -> finish()

    def _write_log(self, r: StepEnergy) -> None:
        # Caller holds self._lock (records may finish on the resolver
        # thread and a user thread concurrently).
        if self._log is None:
            return
        rep = r.report()
        g = rep.gflops_per_watt
        self._log.write(
            f"{r.step},{r.sensor},{r.kind},{r.joules:.6f},{r.seconds:.6f},"
            f"{r.watts:.3f},{'' if r.flops is None else f'{r.flops:.0f}'},"
            f"{'' if r.tokens is None else r.tokens},"
            f"{'' if g is None else f'{g:.3f}'},{rep.edp:.6f}\n")

    def _settle(self) -> None:
        """Resolve any outstanding non-blocking steps (before reading
        accumulated state).  Takes the session resolve path, so call
        *outside* ``self._lock``.  Boxes whose span errored (sampler
        stopped) or fell off the session's auto-resolve queue are
        settled here too — forcing via the handle either recovers the
        records or retires the box, so the in-flight set cannot leak.
        """
        with self._lock:
            boxes = list(self._inflight)
        for box in boxes:
            box.records                  # forces resolution (or [] on error)
            with self._lock:
                self._inflight.discard(box)

    # -- cumulative accounting (checkpointable) -----------------------------
    @property
    def cumulative_joules(self) -> float:
        self._settle()
        with self._lock:
            return self._cumulative_joules

    def state_dict(self) -> Dict[str, float]:
        """Energy state persisted inside checkpoints (DESIGN.md §3)."""
        self._settle()
        with self._lock:
            recent = self._records[-32:]
            j_per_step = (statistics.fmean(r.joules for r in recent)
                          if recent else 0.0)
            return {"cumulative_joules": self._cumulative_joules,
                    "joules_per_step_ema": j_per_step}

    def records(self) -> List[StepEnergy]:
        self._settle()
        with self._lock:
            return list(self._records)

    # -- per-request accounting (serve path) -----------------------------
    def request_records(self) -> List[StepEnergy]:
        """Resolved ``measure_request`` records (scope == "request")."""
        return [r for r in self.records() if r.scope == "request"]

    def per_request_energy(self) -> Dict[int, Dict[str, float]]:
        """Aggregate per-request accounting across sensors.

        Returns ``{request_id: {"joules", "seconds", "tokens",
        "j_per_token", "prefill_joules", "decode_joules"}}`` — joules
        summed over sensors, seconds the max (sensors cover the same
        wall interval), J/token against the request's generated-token
        count.  The phase keys come from the ``serve/req<N>/prefill``
        and ``.../decode`` child spans, which tile the request span:
        their sum matches the request total (within sampler
        interpolation).
        """
        out: Dict[int, Dict[str, float]] = {}
        for r in self.request_records():
            d = out.setdefault(r.step, {"joules": 0.0, "seconds": 0.0,
                                        "tokens": 0,
                                        "prefill_joules": 0.0,
                                        "decode_joules": 0.0})
            if r.phase is None:
                d["joules"] += r.joules
                d["seconds"] = max(d["seconds"], r.seconds)
                d["tokens"] = r.tokens or d["tokens"]
            else:
                key = f"{r.phase}_joules"
                d[key] = d.get(key, 0.0) + r.joules
        for d in out.values():
            d["j_per_token"] = d["joules"] / max(d["tokens"], 1)
        return out

    def close(self) -> None:
        try:
            self._settle()         # flush in-flight async steps first
        except SensorError:        # session already torn down
            pass
        with self._lock:
            if self._log is not None:
                self._log.close()
                self._log = None
        if self._owns_session:
            self._session.close()


class _StepBox:
    """Carries one step's :class:`StepEnergy` records.

    Blocking steps fill it before ``measure_step`` exits.  Non-blocking
    steps fill it when the background resolver finishes the span —
    reading :attr:`records` earlier forces resolution on the calling
    thread (future-style), so a loop that logs every Nth step only pays
    resolution on those steps.
    """

    def __init__(self):
        # Instance attributes, not shared class-level defaults: two
        # concurrent steps must never see each other's records.
        self._records: Optional[List[StepEnergy]] = None
        self._handle = None

    @property
    def records(self) -> List[StepEnergy]:
        if self._records is None:
            if self._handle is not None:
                try:
                    self._handle.measurements   # triggers finish() callback
                except SensorError:
                    pass                        # still open / sampler gone
            if self._records is None:
                self._records = []
        return self._records

    @records.setter
    def records(self, value: List[StepEnergy]) -> None:
        self._records = value


# -- fleet-level straggler detection (fault-tolerance integration) ---------

@dataclasses.dataclass(frozen=True)
class StragglerVerdict:
    host: int
    power_w: float
    step_s: float
    power_z: float
    time_z: float
    is_straggler: bool


def detect_stragglers(host_power_w: Sequence[float],
                      host_step_s: Sequence[float],
                      power_sigma: float = 3.0,
                      time_sigma: float = 3.0) -> List[StragglerVerdict]:
    """Flag hosts whose power deviates while their step time lags.

    A host that is *slow* and *anomalous in power* (low → throttling or a
    dead accelerator; high → a runaway/thermal issue) is a straggler
    candidate.  Power alone is not enough (data skew changes power
    legitimately); time alone is the classic detector — requiring both
    cuts false positives.  Uses robust (median/MAD) z-scores.
    """
    if len(host_power_w) != len(host_step_s):
        raise ValueError("power and step-time vectors must align")
    n = len(host_power_w)
    if n == 0:
        return []

    def robust_z(xs: Sequence[float]) -> List[float]:
        med = statistics.median(xs)
        mad = statistics.median([abs(x - med) for x in xs])
        scale = 1.4826 * mad
        if scale == 0.0:
            # MAD degenerates when >50% of hosts are identical (the
            # common healthy-fleet case) — fall back to the std so a
            # single outlier is still visible.
            scale = statistics.pstdev(xs) if len(xs) > 1 else 0.0
        if scale == 0.0:
            return [0.0] * len(xs)
        return [(x - med) / scale for x in xs]

    pz = robust_z(host_power_w)
    tz = robust_z(host_step_s)
    out = []
    for i in range(n):
        slow = tz[i] > time_sigma
        odd_power = abs(pz[i]) > power_sigma
        out.append(StragglerVerdict(
            host=i, power_w=host_power_w[i], step_s=host_step_s[i],
            power_z=pz[i], time_z=tz[i], is_straggler=bool(slow and odd_power)))
    return out
