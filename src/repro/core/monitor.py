"""PowerMonitor — PMT integrated into the training/serving loop.

This is the framework-facing layer (DESIGN.md §3): per-step energy
attribution over one or more sensors, a CSV energy log, cumulative
accounting that survives checkpoint/restart, and power-based straggler
detection for the fault-tolerance stack.

Since the ``pmt.Session`` redesign the monitor no longer polls sensors
itself: ``measure_step`` opens a session region, so step energy resolves
against the shared background ring sampler.  A monitor can run on its own
session (default; sensors still shared via the process pool) or be handed
an existing one, in which case the serve engine, the train loop, and the
monitor all attach to the same sampler per backend instead of
double-polling.

JAX-awareness: dispatch is asynchronous, so a step is only attributed the
energy between explicit ``block_until_ready`` boundaries — the caller (or
the provided ``measure_step`` context manager, which blocks on exit if
given outputs) must fence, otherwise readings would attribute a step's
tail to its successor.
"""
from __future__ import annotations

import contextlib
import dataclasses
import statistics
import threading
from typing import Dict, List, Optional, Sequence, TextIO, Union

from repro.core.metrics import EfficiencyReport
from repro.core.sensor import Sensor
from repro.core.session import Session


@dataclasses.dataclass(frozen=True)
class StepEnergy:
    """Energy record for one step, one sensor."""

    step: int
    sensor: str
    kind: str
    joules: float
    seconds: float
    watts: float
    flops: Optional[float] = None
    tokens: Optional[int] = None

    def report(self) -> EfficiencyReport:
        return EfficiencyReport(joules=self.joules, seconds=self.seconds,
                                flops=self.flops, tokens=self.tokens)


class PowerMonitor:
    """Attributes per-step energy across a set of sensors.

    Args:
      sensors: backend names or Sensor instances (stacked like the paper's
        multi-decorator usage — e.g. ["cpuutil", "tpu"]).  May be empty
        when ``session`` already has backends attached.
      log_path: optional CSV energy log (append mode, crash-tolerant:
        one flushed line per step).
      initial_joules: cumulative joules carried over from a checkpoint.
      session: an existing :class:`pmt.Session` to measure through; the
        monitor attaches its sensors to it and does NOT close it.  When
        omitted the monitor owns a private session on the shared pool.
    """

    CSV_HEADER = ("step,sensor,kind,joules,seconds,watts,flops,tokens,"
                  "gflops_per_watt,edp\n")

    def __init__(self, sensors: Sequence[Union[str, Sensor]] = (),
                 log_path: Optional[str] = None,
                 initial_joules: float = 0.0,
                 session: Optional[Session] = None):
        self._owns_session = session is None
        self._session = session if session is not None else Session()
        try:
            for s in sensors:
                self._session.attach(s)
        except BaseException:
            if self._owns_session:
                self._session.close()
            raise
        self.sensors: List[Sensor] = self._session.sensors
        if not self.sensors:
            raise ValueError("PowerMonitor needs at least one sensor")
        self._records: List[StepEnergy] = []
        self._cumulative_joules = float(initial_joules)
        self._lock = threading.Lock()
        self._log: Optional[TextIO] = None
        if log_path:
            self._log = open(log_path, "a", buffering=1)
            if self._log.tell() == 0:
                self._log.write(self.CSV_HEADER)

    @property
    def session(self) -> Session:
        return self._session

    # -- per-step measurement --------------------------------------------
    @contextlib.contextmanager
    def measure_step(self, step: int, flops: Optional[float] = None,
                     tokens: Optional[int] = None):
        """Context manager measuring one fenced step across all sensors.

        A thin wrapper over ``session.region(...)`` — entry/exit touch no
        sensors on this thread; the step resolves against the shared ring
        buffer at exit (at most one closing sample per backend).

        The caller must ensure device work is complete before the block
        exits (``jax.block_until_ready`` on the step outputs).
        """
        handle = self._session.region(f"step{step}", flops=flops,
                                      tokens=tokens)
        box = _StepBox()
        handle.__enter__()
        try:
            yield box
        finally:
            handle.__exit__(None, None, None)
            recs = [StepEnergy(
                step=step, sensor=m.sensor, kind=m.kind, joules=m.joules,
                seconds=m.seconds, watts=m.watts, flops=flops,
                tokens=tokens) for m in handle.measurements]
            with self._lock:
                self._records.extend(recs)
                self._cumulative_joules += sum(r.joules for r in recs)
            for r in recs:
                self._write_log(r)
            box.records = recs

    def _write_log(self, r: StepEnergy) -> None:
        if self._log is None:
            return
        rep = r.report()
        g = rep.gflops_per_watt
        self._log.write(
            f"{r.step},{r.sensor},{r.kind},{r.joules:.6f},{r.seconds:.6f},"
            f"{r.watts:.3f},{'' if r.flops is None else f'{r.flops:.0f}'},"
            f"{'' if r.tokens is None else r.tokens},"
            f"{'' if g is None else f'{g:.3f}'},{rep.edp:.6f}\n")

    # -- cumulative accounting (checkpointable) -----------------------------
    @property
    def cumulative_joules(self) -> float:
        with self._lock:
            return self._cumulative_joules

    def state_dict(self) -> Dict[str, float]:
        """Energy state persisted inside checkpoints (DESIGN.md §3)."""
        with self._lock:
            recent = self._records[-32:]
            j_per_step = (statistics.fmean(r.joules for r in recent)
                          if recent else 0.0)
            return {"cumulative_joules": self._cumulative_joules,
                    "joules_per_step_ema": j_per_step}

    def records(self) -> List[StepEnergy]:
        with self._lock:
            return list(self._records)

    def close(self) -> None:
        if self._log is not None:
            self._log.close()
            self._log = None
        if self._owns_session:
            self._session.close()


class _StepBox:
    """Filled with the step's records when measure_step exits."""

    def __init__(self):
        # Instance attribute, not a shared class-level default: two
        # concurrent steps must never see each other's records.
        self.records: List[StepEnergy] = []


# -- fleet-level straggler detection (fault-tolerance integration) ---------

@dataclasses.dataclass(frozen=True)
class StragglerVerdict:
    host: int
    power_w: float
    step_s: float
    power_z: float
    time_z: float
    is_straggler: bool


def detect_stragglers(host_power_w: Sequence[float],
                      host_step_s: Sequence[float],
                      power_sigma: float = 3.0,
                      time_sigma: float = 3.0) -> List[StragglerVerdict]:
    """Flag hosts whose power deviates while their step time lags.

    A host that is *slow* and *anomalous in power* (low → throttling or a
    dead accelerator; high → a runaway/thermal issue) is a straggler
    candidate.  Power alone is not enough (data skew changes power
    legitimately); time alone is the classic detector — requiring both
    cuts false positives.  Uses robust (median/MAD) z-scores.
    """
    if len(host_power_w) != len(host_step_s):
        raise ValueError("power and step-time vectors must align")
    n = len(host_power_w)
    if n == 0:
        return []

    def robust_z(xs: Sequence[float]) -> List[float]:
        med = statistics.median(xs)
        mad = statistics.median([abs(x - med) for x in xs])
        scale = 1.4826 * mad
        if scale == 0.0:
            # MAD degenerates when >50% of hosts are identical (the
            # common healthy-fleet case) — fall back to the std so a
            # single outlier is still visible.
            scale = statistics.pstdev(xs) if len(xs) > 1 else 0.0
        if scale == 0.0:
            return [0.0] * len(xs)
        return [(x - med) / scale for x in xs]

    pz = robust_z(host_power_w)
    tz = robust_z(host_step_s)
    out = []
    for i in range(n):
        slow = tz[i] > time_sigma
        odd_power = abs(pz[i]) > power_sigma
        out.append(StragglerVerdict(
            host=i, power_w=host_power_w[i], step_s=host_step_s[i],
            power_z=pz[i], time_z=tz[i], is_straggler=bool(slow and odd_power)))
    return out
