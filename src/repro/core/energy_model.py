"""Analytical energy model for accelerators without a power API.

This is the TPU-native adaptation of the paper's "built-in sensor" idea
(DESIGN.md §2): where NVML exposes measured watts, a TPU chip exposes an
exact *compiled cost profile* (XLA ``cost_analysis()``), and energy is
modeled from it:

    E_step = flops * pj_per_flop
           + hbm_bytes * pj_per_hbm_byte
           + ici_bytes * pj_per_ici_byte        (dynamic energy)
    E_wall = idle_w * seconds * chips           (static energy)
    E      = E_wall + E_step_total

The same FLOPs/bytes terms feed the roofline analysis (repro.roofline), so
the §Roofline deliverable and the energy numbers are one set of facts.

Coefficients are order-of-magnitude literature values for a 5nm-class
accelerator, and are explicitly *modeled* quantities — every consumer of
this module carries the ``kind="modeled"`` label.  A site with physical
calibration (the paper's PowerSensor2 role) can construct a custom
:class:`EnergyModel`.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip performance envelope (roofline peaks) + power envelope."""

    name: str
    peak_flops: float          # FLOP/s (bf16 matmul)
    hbm_bw: float              # bytes/s
    ici_bw: float              # bytes/s per link
    hbm_bytes: float           # HBM capacity per chip
    idle_w: float              # static board power
    peak_w: float              # max sustained board power


# Roofline constants fixed by the brief: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI. HBM 16 GB per v5e chip.
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16 * 2 ** 30,
    idle_w=60.0,
    peak_w=200.0,
)


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Energy coefficients on top of a :class:`HardwareSpec`."""

    hw: HardwareSpec = TPU_V5E
    pj_per_flop: float = 0.55       # bf16 MXU FLOP, incl. datapath
    pj_per_hbm_byte: float = 15.0   # HBM3-class access energy
    pj_per_ici_byte: float = 30.0   # serdes + switch energy

    def dynamic_joules(self, flops: float, hbm_bytes: float,
                       ici_bytes: float = 0.0) -> float:
        """Dynamic (activity-proportional) energy of one step, one chip."""
        return (flops * self.pj_per_flop
                + hbm_bytes * self.pj_per_hbm_byte
                + ici_bytes * self.pj_per_ici_byte) * 1e-12

    def static_joules(self, seconds: float, chips: int = 1) -> float:
        """Idle-floor energy over a wall-clock interval."""
        return self.hw.idle_w * seconds * chips

    def step_joules(self, flops: float, hbm_bytes: float, ici_bytes: float,
                    seconds: float, chips: int = 1) -> float:
        """Total modeled energy for a step spanning ``seconds`` wall time.

        The dynamic component is capped so implied average power never
        exceeds the board envelope — the model must not claim power the
        hardware cannot draw.
        """
        dyn = self.dynamic_joules(flops, hbm_bytes, ici_bytes)
        static = self.static_joules(seconds, chips)
        if seconds > 0:
            cap = (self.hw.peak_w - self.hw.idle_w) * seconds * chips
            dyn = min(dyn, cap)
        return static + dyn

    def step_watts(self, flops: float, hbm_bytes: float, ici_bytes: float,
                   seconds: float, chips: int = 1) -> float:
        if seconds <= 0:
            return self.hw.idle_w * chips
        return self.step_joules(flops, hbm_bytes, ici_bytes, seconds,
                                chips) / seconds
