"""Energy-efficiency metrics (paper §III).

"Users can extract measurements with PMT and derive energy efficiency
metrics such as energy-delay product (EDP) ... and the FLOPs efficiency,
which can be expressed in GFLOP/s/W. Note that the last metric requires
the number of FLOPs computed."

In this framework the FLOP count comes from XLA ``cost_analysis()`` of the
compiled step (exact), replacing the paper's PAPI/LIKWID counters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def edp(joules: float, seconds: float) -> float:
    """Energy-delay product, J*s. Lower is better."""
    return joules * seconds


def ed2p(joules: float, seconds: float) -> float:
    """Energy-delay-squared product, J*s^2 (latency-weighted variant)."""
    return joules * seconds * seconds


def gflops_per_watt(flops: float, joules: float) -> float:
    """FLOPs efficiency in GFLOP/s/W.

    GFLOP/s/W == (flops/seconds)/watts / 1e9 == flops/joules / 1e9 —
    the seconds cancel, so only energy and work are needed.
    """
    if joules <= 0:
        return 0.0
    return flops / joules / 1e9


def joules_per_token(joules: float, tokens: int) -> float:
    if tokens <= 0:
        return 0.0
    return joules / tokens


def tokens_per_joule(joules: float, tokens: int) -> float:
    if joules <= 0:
        return 0.0
    return tokens / joules


@dataclasses.dataclass(frozen=True)
class EfficiencyReport:
    """Bundle of the paper's §III metrics for one region/step."""

    joules: float
    seconds: float
    flops: Optional[float] = None
    tokens: Optional[int] = None

    @property
    def watts(self) -> float:
        return self.joules / self.seconds if self.seconds > 0 else 0.0

    @property
    def edp(self) -> float:
        return edp(self.joules, self.seconds)

    @property
    def ed2p(self) -> float:
        return ed2p(self.joules, self.seconds)

    @property
    def gflops_per_watt(self) -> Optional[float]:
        if self.flops is None:
            return None
        return gflops_per_watt(self.flops, self.joules)

    @property
    def joules_per_token(self) -> Optional[float]:
        if self.tokens is None:
            return None
        return joules_per_token(self.joules, self.tokens)

    def as_csv_row(self) -> str:
        g = self.gflops_per_watt
        jt = self.joules_per_token
        return (f"{self.joules:.6f},{self.seconds:.6f},{self.watts:.3f},"
                f"{self.edp:.6f},"
                f"{'' if g is None else f'{g:.3f}'},"
                f"{'' if jt is None else f'{jt:.9f}'}")

    CSV_HEADER = "joules,seconds,watts,edp,gflops_per_watt,joules_per_token"
