"""TPU cost-model backend — the TPU-native "built-in sensor".

TPUs expose no portable instantaneous-power API to user code, so the
TPU analogue of NVML is an analytical sensor (DESIGN.md §2): the
framework *accounts* compiled workload activity (FLOPs, HBM bytes, ICI
bytes — straight from the XLA compiled artifact) as it executes, and the
sensor integrates a modeled power trace:

  * between accounted steps the chip draws ``idle_w``;
  * an accounted step spreads its dynamic energy over its wall duration.

``read()`` therefore behaves exactly like any other PMT backend — a
cumulative joules counter — and all of measurement-mode, dump-mode, the
decorators and the PowerMonitor work unmodified on top of it.

kind = "modeled", and every report downstream carries that label.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional

from repro.core.energy_model import EnergyModel
from repro.core.registry import register_backend
from repro.core.sensor import Sample, Sensor


class TpuCostModelSensor(Sensor):
    name = "tpu"
    kind = "modeled"
    native_period_s = 0.001  # the model can be sampled arbitrarily fast

    def __init__(self, model: Optional[EnergyModel] = None, chips: int = 1,
                 clock: Optional[Callable[[], float]] = None):
        super().__init__(clock=clock)
        self._model = model or EnergyModel()
        self._chips = int(chips)
        self._acc_lock = threading.Lock()
        self._dynamic_joules = 0.0      # total accounted dynamic energy
        self._active_until: float = -1.0  # end of current accounted burst
        self._active_watts: float = 0.0   # dynamic watts during the burst
        self._t_origin: Optional[float] = None

    @classmethod
    def is_available(cls) -> bool:
        return True  # purely analytical

    @property
    def model(self) -> EnergyModel:
        return self._model

    # -- framework-facing accounting API ---------------------------------
    def account(self, flops: float, hbm_bytes: float, ici_bytes: float,
                seconds: float) -> float:
        """Account one executed step.

        ``flops``/``hbm_bytes``/``ici_bytes`` are per-chip quantities (as
        reported by ``cost_analysis()`` of the per-device program);
        ``seconds`` is the measured wall duration of the step.  Returns the
        modeled dynamic joules added (all chips).
        """
        dyn = self._model.step_joules(flops, hbm_bytes, ici_bytes, seconds,
                                      self._chips) \
            - self._model.static_joules(seconds, self._chips)
        dyn = max(0.0, dyn)
        with self._acc_lock:
            self._dynamic_joules += dyn
            now = self._clock()
            self._active_until = now
            self._active_watts = dyn / seconds if seconds > 0 else 0.0
        return dyn

    # -- Sensor hook -------------------------------------------------------
    def _sample(self) -> Sample:
        now = self._clock()
        with self._acc_lock:
            if self._t_origin is None:
                self._t_origin = now
            elapsed = now - self._t_origin
            static = self._model.static_joules(elapsed, self._chips)
            joules = static + self._dynamic_joules
            # Instantaneous watts: idle floor, plus the dynamic rate if a
            # burst was accounted within the last native period.
            watts = self._model.hw.idle_w * self._chips
            if now - self._active_until <= self.native_period_s * 2:
                watts += self._active_watts
        return Sample(joules=joules, watts=watts)


register_backend("tpu", TpuCostModelSensor)
