"""Generic sysfs backend.

The paper: "Some other architectures expose their power usage information
through files in sysfs (the /sys folder)."  This backend reads arbitrary
hwmon-style files:

  * ``power*_input``  — instantaneous power in micro-watts, or
  * ``energy*_input`` — cumulative energy in micro-joules.

By default it scans ``/sys/class/hwmon/hwmon*/`` for both kinds; a file
list can be passed explicitly (also used by the unit tests with a fixture
tree).  Power files are integrated by the Sensor base class; energy files
are summed directly.
"""
from __future__ import annotations

import glob
import os
from typing import Callable, List, Optional, Sequence

from repro.core.registry import register_backend
from repro.core.sensor import Sample, Sensor, SensorError

DEFAULT_HWMON_GLOBS = (
    "/sys/class/hwmon/hwmon*/power*_input",
    "/sys/class/hwmon/hwmon*/energy*_input",
    "/sys/class/hwmon/hwmon*/device/power*_input",
)


def _discover(globs: Sequence[str]) -> List[str]:
    files: List[str] = []
    for pattern in globs:
        files.extend(sorted(glob.glob(pattern)))
    return files


class SysfsSensor(Sensor):
    name = "sysfs"
    kind = "measured"
    native_period_s = 0.100

    def __init__(self, files: Optional[Sequence[str]] = None,
                 globs: Sequence[str] = DEFAULT_HWMON_GLOBS,
                 clock: Optional[Callable[[], float]] = None):
        super().__init__(clock=clock)
        self._files = list(files) if files is not None else _discover(globs)
        if not self._files:
            raise SensorError("no sysfs power/energy files found")
        for f in self._files:
            base = os.path.basename(f)
            if not (base.startswith("power") or base.startswith("energy")):
                raise SensorError(
                    f"unrecognised sysfs power file name {f!r} "
                    "(expected power*_input or energy*_input)")

    @classmethod
    def is_available(cls) -> bool:
        return bool(_discover(DEFAULT_HWMON_GLOBS))

    def _sample(self) -> Sample:
        watts_total = 0.0
        joules_total = 0.0
        have_power = False
        have_energy = False
        rails = {}
        for f in self._files:
            with open(f, "r") as fh:
                val = float(fh.read().strip())
            base = os.path.basename(f)
            if base.startswith("power"):  # micro-watts
                watts_total += val * 1e-6
                have_power = True
            else:  # energy*_input, micro-joules cumulative
                joules_total += val * 1e-6
                rails[f] = val * 1e-6
                have_energy = True
        if have_energy and not have_power:
            return Sample(joules=joules_total, rails=rails)
        if have_power and not have_energy:
            return Sample(watts=watts_total)
        # Mixed trees: prefer the energy counters (exact), report power too.
        return Sample(joules=joules_total, watts=watts_total, rails=rails)


register_backend("sysfs", SysfsSensor)
