"""NVML backend — NVIDIA GPUs via ``pynvml`` when present.

The paper's primary GPU backend.  On hosts without NVIDIA hardware (or
without pynvml) the backend reports unavailable; nothing is faked.  The
paper's observed NVML behaviour is preserved: instantaneous power is the
native quantity (integrated to joules by the Sensor base class) and the
sustainable sampling period is ~10 ms.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.registry import register_backend
from repro.core.sensor import Sample, Sensor, SensorError

try:  # pragma: no cover - depends on host
    import pynvml  # type: ignore

    _HAVE_PYNVML = True
except Exception:  # pragma: no cover
    pynvml = None
    _HAVE_PYNVML = False


class NvmlSensor(Sensor):
    name = "nvml"
    kind = "measured"
    native_period_s = 0.010  # paper: "NVML is able to sustain up to 10 ms"

    def __init__(self, device_index: int = 0,
                 clock: Optional[Callable[[], float]] = None):
        super().__init__(clock=clock)
        if not _HAVE_PYNVML:
            raise SensorError("pynvml not importable; NVML backend unavailable")
        pynvml.nvmlInit()
        self._handle = pynvml.nvmlDeviceGetHandleByIndex(device_index)

    @classmethod
    def is_available(cls) -> bool:
        if not _HAVE_PYNVML:
            return False
        try:  # pragma: no cover - depends on host
            pynvml.nvmlInit()
            return pynvml.nvmlDeviceGetCount() > 0
        except Exception:  # pragma: no cover
            return False

    def _sample(self) -> Sample:  # pragma: no cover - depends on host
        mw = pynvml.nvmlDeviceGetPowerUsage(self._handle)  # milliwatts
        return Sample(watts=mw * 1e-3)


register_backend("nvml", NvmlSensor)
