"""Deterministic dummy backend.

Used by tests, examples, and as the stand-in "physical meter" slot (the
paper's PowerSensor2 interface point).  Produces power from a programmable
waveform ``watts_fn(t_rel)``; with the default constant waveform and an
injected virtual clock the whole PMT stack becomes exactly reproducible.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.registry import register_backend
from repro.core.sensor import Sample, Sensor


class DummySensor(Sensor):
    name = "dummy"
    kind = "modeled"
    native_period_s = 0.001

    def __init__(self, watts: float = 42.0,
                 watts_fn: Optional[Callable[[float], float]] = None,
                 clock: Optional[Callable[[], float]] = None):
        super().__init__(clock=clock)
        self._watts_const = float(watts)
        self._watts_fn = watts_fn
        self._t0: Optional[float] = None

    def _sample(self) -> Sample:
        t = self._clock()
        if self._t0 is None:
            self._t0 = t
        if self._watts_fn is not None:
            w = float(self._watts_fn(t - self._t0))
        else:
            w = self._watts_const
        return Sample(watts=w)


register_backend("dummy", DummySensor)
