"""RAPL backend — Linux powercap sysfs energy counters.

Reads ``<powercap_root>/intel-rapl:<i>/energy_uj`` cumulative micro-joule
counters (one per package-level domain), handling counter wraparound via
``max_energy_range_uj`` exactly as the C++ PMT RAPL backend does.

Per-rail readings (package, dram, psys, sub-domains like core/uncore) are
exposed in ``State.rails``; the sensor total sums only *top-level* domains
to avoid double counting parent+child zones.

The powercap root is injectable so the parser is unit-testable on hosts
(like this container) that expose no powercap tree.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.registry import register_backend
from repro.core.sensor import Sample, Sensor, SensorError

DEFAULT_ROOT = "/sys/class/powercap"


def _read_file(path: str) -> str:
    with open(path, "r") as f:
        return f.read().strip()


class RaplSensor(Sensor):
    name = "rapl"
    kind = "measured"
    # Paper: "RAPL up to 500 ms" sustained sampling period.
    native_period_s = 0.500

    def __init__(self, root: str = DEFAULT_ROOT,
                 clock: Optional[Callable[[], float]] = None):
        super().__init__(clock=clock)
        self._root = root
        self._domains = self._discover(root)
        if not self._domains:
            raise SensorError(f"no RAPL domains under {root!r}")
        # Per-domain unwrap state: (last_raw_uj, accumulated_wraps_uj).
        self._unwrap: Dict[str, Tuple[float, float]] = {}

    # -- discovery -------------------------------------------------------
    @staticmethod
    def _discover(root: str) -> List[dict]:
        """Find RAPL zones. Top-level zones look like ``intel-rapl:0``;
        sub-zones like ``intel-rapl:0:1`` (child of package 0)."""
        domains = []
        if not os.path.isdir(root):
            return domains
        for entry in sorted(os.listdir(root)):
            if not entry.startswith("intel-rapl:"):
                continue
            zone = os.path.join(root, entry)
            energy = os.path.join(zone, "energy_uj")
            if not os.path.isfile(energy):
                continue
            try:
                label = _read_file(os.path.join(zone, "name"))
            except OSError:
                label = entry
            try:
                max_range = float(_read_file(
                    os.path.join(zone, "max_energy_range_uj")))
            except OSError:
                max_range = 2.0 ** 32  # conservative default
            # ``intel-rapl:0`` has one ':', subzones have two.
            top_level = entry.count(":") == 1
            domains.append(dict(entry=entry, path=energy, label=label,
                                max_range_uj=max_range, top=top_level))
        return domains

    @classmethod
    def is_available(cls) -> bool:
        return bool(cls._discover(DEFAULT_ROOT))

    # -- sampling ----------------------------------------------------------
    def _read_domain_uj(self, dom: dict) -> float:
        """Read one domain's cumulative counter, unwrapped, in uJ."""
        raw = float(_read_file(dom["path"]))
        key = dom["entry"]
        last_raw, wraps = self._unwrap.get(key, (raw, 0.0))
        if raw < last_raw:  # counter wrapped since last read
            wraps += dom["max_range_uj"]
        self._unwrap[key] = (raw, wraps)
        return raw + wraps

    def _sample(self) -> Sample:
        rails: Dict[str, float] = {}
        total_uj = 0.0
        for dom in self._domains:
            uj = self._read_domain_uj(dom)
            rail_name = f"{dom['entry']}:{dom['label']}"
            rails[rail_name] = uj * 1e-6
            if dom["top"]:
                total_uj += uj
        return Sample(joules=total_uj * 1e-6, rails=rails)


register_backend("rapl", RaplSensor)
