"""CPU-utilization backend (``cpuutil``) — measured activity x TDP model.

On hosts without powercap/RAPL access (unprivileged containers, most
cloud VMs — including this one), the only live CPU activity signal is
``/proc/stat``.  This backend converts utilization into power with a
standard affine model:

    P = idle_w + (tdp_w - idle_w) * utilization

which is the same class of model RAPL itself applies to non-core domains.
``kind = "hybrid"``: the activity is *measured*, the coefficients are
*modeled* — reports always carry that label (DESIGN.md §2).

The procfs root is injectable for unit tests.
"""
from __future__ import annotations

import os
from typing import Callable, Optional, Tuple

from repro.core.registry import register_backend
from repro.core.sensor import Sample, Sensor, SensorError


def _read_proc_stat(path: str) -> Tuple[float, float]:
    """Return (busy_jiffies, total_jiffies) from the aggregate cpu line."""
    with open(path, "r") as f:
        first = f.readline().split()
    if not first or first[0] != "cpu":
        raise SensorError(f"malformed {path}: {first[:3]}")
    vals = [float(v) for v in first[1:]]
    # user nice system idle iowait irq softirq steal [guest guest_nice]
    idle = vals[3] + (vals[4] if len(vals) > 4 else 0.0)  # idle + iowait
    total = sum(vals[:8]) if len(vals) >= 8 else sum(vals)
    return total - idle, total


class CpuUtilSensor(Sensor):
    name = "cpuutil"
    kind = "hybrid"
    native_period_s = 0.050  # jiffy granularity ~10ms; 50ms is robust

    def __init__(self, tdp_w: float = 95.0, idle_w: float = 10.0,
                 procfs: str = "/proc",
                 clock: Optional[Callable[[], float]] = None):
        super().__init__(clock=clock)
        if tdp_w <= idle_w:
            raise ValueError("tdp_w must exceed idle_w")
        self._tdp_w = float(tdp_w)
        self._idle_w = float(idle_w)
        self._stat_path = os.path.join(procfs, "stat")
        # Prime the delta so the first read() has a baseline.
        self._last = _read_proc_stat(self._stat_path)

    @classmethod
    def is_available(cls) -> bool:
        try:
            _read_proc_stat("/proc/stat")
            return True
        except (OSError, SensorError):
            return False

    def utilization(self) -> float:
        """Fraction of CPU time spent busy since the previous call."""
        busy, total = _read_proc_stat(self._stat_path)
        last_busy, last_total = self._last
        self._last = (busy, total)
        dt = total - last_total
        if dt <= 0:
            return 0.0
        return min(1.0, max(0.0, (busy - last_busy) / dt))

    def _sample(self) -> Sample:
        util = self.utilization()
        watts = self._idle_w + (self._tdp_w - self._idle_w) * util
        return Sample(watts=watts)


register_backend("cpuutil", CpuUtilSensor)
