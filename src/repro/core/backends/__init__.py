"""PMT backends.

Each module provides one :class:`repro.core.sensor.Sensor` subclass and
registers it with the backend registry at import time (see
``repro.core.registry``).  The set mirrors the paper's Fig. 1 back ends,
adapted to the TPU/JAX deployment target (see DESIGN.md §2):

  rapl     — Linux powercap sysfs energy counters (host CPUs).   measured
  sysfs    — generic hwmon power/energy files.                   measured
  cpuutil  — /proc/stat utilization x calibrated TDP model.      hybrid
  nvml     — NVIDIA via pynvml when importable.                  measured
  tpu      — analytical XLA-cost-model sensor (TPU adaptation).  modeled
  dummy    — deterministic waveform, for tests and examples.     modeled
"""
from repro.core.backends import cpuutil, dummy, nvml, rapl, sysfs, tpu  # noqa: F401
