"""Background sampling thread — PMT's core runtime mechanism.

"PMT library's core consists of a background thread to the profiled
application that communicates and gathers power consumption information
from the selected back end."

Two consumers:

  * :class:`DumpThread` — dump-mode: sample at the backend's native period
    and append records to a dump file (see repro.core.dumpfile).
  * :class:`RingSampler` — in-memory timeline with a preallocated NumPy
    ring buffer, the shared sampling service behind ``pmt.Session``.

Both honour the backend's ``native_period_s`` floor: sampling faster than
the backend updates only duplicates values (the paper's NVML-10ms /
RAPL-500ms observation), so requests below the floor are clamped.

The array core
--------------

:class:`RingSampler` stores samples in a fixed-capacity structured NumPy
ring (columns ``timestamp_s``, ``joules``, ``watts``) written in place by
the background thread.  After warm-up the tick retains **zero** Python
allocations: ``Sensor.read_raw()`` hands back bare floats and the writer
assigns them into preallocated columns — no ``State`` objects, no list
appends, no compaction.

Readers never take a lock the writer holds across sensor I/O.  Instead
they use a seqlock-style retry: read the write sequence counter, copy the
live region, and re-check the counter — if the writer published a row in
between, retry the copy.  The writer bumps the counter to odd before a
row write and back to even after, so a torn row is always detected.

Compaction disappeared with the list core: a sample survives until the
ring genuinely wraps (``capacity`` samples later), instead of the old
"delete the older half" policy that could evict a still-open span's
bracketing sample at half capacity.  Open spans *pin* their ``t0``
(:meth:`RingSampler.pin`); a pin cannot stop a fixed-capacity ring from
eventually wrapping over a span that outlives ``capacity * period_s``,
but it makes that eviction detectable: the writer marks affected pins as
it overwrites their bracket, and resolution raises a clear
``window_evicted`` flag (and a :class:`SamplerWindowEvicted` warning)
instead of silently under-reporting energy.

The list-of-``State`` core from the previous revision is kept as
:class:`LegacyRingSampler` behind ``PMT_LEGACY_RING=1`` for A/B
benchmarking (see benchmarks/bench_overhead.py); it will be removed once
the perf trajectory has a few array-core data points.
"""
from __future__ import annotations

import bisect
import collections
import itertools
import math
import os
import threading
import time
import warnings
from typing import List, Optional, Tuple

import numpy as np

from repro.core.dumpfile import DumpWriter
from repro.core.sensor import Sensor
from repro.core.state import State
from repro.core.supervisor import DEGRADED, FAILED, OK


class SamplerWindowEvicted(UserWarning):
    """A span outlived the ring: its bracketing start sample was
    overwritten before resolution, so its energy resolves from a
    truncated window (flagged ``window_evicted`` on the measurement)."""


class SamplerReadError(UserWarning):
    """A background sampler read raised; the tick was skipped.  The
    sampler thread survives and keeps ticking — the failed interval is
    recorded as a coverage gap (see :class:`SamplerCoverageGap`).
    Warned once per failure streak, not once per tick."""


class SamplerCoverageGap(UserWarning):
    """A resolved span straddles a sampler coverage gap (a stretch of
    failed reads).  Its energy interpolates *across* the blackout, so
    the measurement is flagged ``degraded`` instead of being silently
    reported as trustworthy."""


class _PeriodicThread(threading.Thread):
    """Base: call ``self._tick()`` every ``period_s`` until stopped."""

    def __init__(self, period_s: float):
        super().__init__(daemon=True)
        self._period_s = period_s
        self._stop_evt = threading.Event()

    def run(self) -> None:
        # Sample immediately, then on the period; a final sample on stop
        # closes the interval so short regions still get >= 2 records.
        self._tick()
        while not self._stop_evt.wait(self._period_s):
            self._tick()
        self._tick()

    def stop(self, join: bool = True) -> None:
        self._stop_evt.set()
        if join and self.is_alive():
            self.join(timeout=10.0)

    def _tick(self) -> None:  # pragma: no cover - overridden
        raise NotImplementedError


def clamp_period(sensor: Sensor, period_s: Optional[float]) -> float:
    """Clamp a requested period to the backend's sustainable floor."""
    if period_s is None:
        return sensor.native_period_s
    return max(float(period_s), sensor.native_period_s)


class DumpThread(_PeriodicThread):
    """Dump-mode engine behind ``Sensor.start_dump_thread``."""

    def __init__(self, sensor: Sensor, filename: str,
                 period_s: Optional[float] = None):
        super().__init__(clamp_period(sensor, period_s))
        self._sensor = sensor
        self._writer = DumpWriter(filename, sensor.name, sensor.kind)
        self._first: Optional[State] = None
        self._prev: Optional[State] = None
        self.read_errors = 0
        self._in_error_streak = False

    def _tick(self) -> None:
        # A transient read failure skips this row (with one warning per
        # failure streak) instead of killing the dump thread mid-file.
        try:
            st = self._sensor.read()
        except Exception as e:   # noqa: BLE001 — any backend fault
            self.read_errors += 1
            if not self._in_error_streak:
                self._in_error_streak = True
                warnings.warn(f"dump read failed ({e!r}); skipping row",
                              SamplerReadError, stacklevel=2)
            return
        self._in_error_streak = False
        if self._first is None:
            self._first = st
        if st.watts is not None:
            w = st.watts
        elif self._prev is not None:
            w = Sensor.watts(self._prev, st)
        else:
            w = 0.0
        self._writer.write(st.timestamp_s - self._first.timestamp_s, w,
                           st.joules)
        self._prev = st

    def stop(self, join: bool = True) -> None:
        super().stop(join=join)
        self._writer.close()


# Logical record schema of one ring row.  The storage is columnar —
# three contiguous float64 arrays, one per field — rather than an
# interleaved structured array: ``np.searchsorted`` (the resolver's
# workhorse) silently copies a strided field view in full on every call,
# which would turn each O(log n) bracket search into an O(n) copy.
RING_DTYPE = np.dtype([("timestamp_s", np.float64),
                       ("joules", np.float64),
                       ("watts", np.float64)])

DEFAULT_RING_CAPACITY = 100_000


class RingSampler(_PeriodicThread):
    """Array-core in-memory sampler (see module docstring).

    Writer side: the background thread (and the rare ``sample_now``
    caller) appends rows in timestamp order.  Writes are serialised by
    ``_write_mutex`` — held across the sensor read *and* the row publish
    so two concurrent ``sample_now`` calls cannot land out of order —
    but readers never touch that mutex, so a slow RAPL/NVML read (~ms)
    can never stall a ``timeline()``/``window_arrays()`` caller.

    Reader side: seqlock retry against ``_wseq``.  ``timeline()`` copies
    the live region seam-unrolled into time order; ``window_arrays``
    slices the copy down to the samples bracketing ``[t0, t1]``.

    ``VECTORIZED`` marks the NumPy interface for the span resolver
    (:mod:`repro.core.resolver`); the legacy core advertises the scalar
    path instead.
    """

    VECTORIZED = True

    def __init__(self, sensor: Sensor, period_s: Optional[float] = None,
                 capacity: int = DEFAULT_RING_CAPACITY):
        super().__init__(clamp_period(sensor, period_s))
        if capacity < 2:
            raise ValueError(f"ring capacity must be >= 2, got {capacity}")
        self._sensor = sensor
        self._cap = int(capacity)
        # Preallocated columns (see RING_DTYPE note); per-tick writes
        # are scalar stores, wraparound overwrites in place.
        self._ts_col = np.zeros(self._cap, np.float64)
        self._j_col = np.zeros(self._cap, np.float64)
        self._w_col = np.zeros(self._cap, np.float64)
        self._count = 0          # total rows ever published
        self._wseq = 0           # seqlock: odd while a row write is in flight
        self._write_mutex = threading.Lock()
        # Pins: open spans register their t0 so wraparound over a span's
        # bracketing sample is detected (not prevented — the ring is
        # fixed-capacity) and surfaced as window_evicted at resolution.
        # Lock-free: single dict/set operations are atomic under the GIL
        # and the writer snapshots items() before iterating; pin/unpin
        # stay cheap enough for the region-open hot path.
        self._pins = {}
        self._pin_ids = itertools.count(1)
        self._evicted_pins = set()
        self._evictions = 0
        # Fault tolerance: failed reads never kill the thread — they
        # open a *coverage gap* from the last good sample until the next
        # successful read, so resolution can mark spans that straddle a
        # blackout as degraded instead of silently interpolating.
        # Mutated only under _write_mutex; read lock-free (GIL-atomic
        # deque/scalar ops) by gap_overlaps()/health().
        self.read_errors = 0
        self._gaps = collections.deque(maxlen=256)   # closed (t0, t1)
        self._gap_open_ts: Optional[float] = None
        self._in_error_streak = False

    @property
    def sensor(self) -> Sensor:
        return self._sensor

    @property
    def capacity(self) -> int:
        return self._cap

    # -- writer side -------------------------------------------------------
    def _tick(self) -> None:
        with self._write_mutex:
            try:
                t, j, w = self._sensor.read_raw()
            except Exception as e:   # noqa: BLE001 — any backend fault
                self._note_read_failure(e)
                return
            self._note_read_success(t)
            self._publish(t, j, w)

    def _note_read_failure(self, e: Exception) -> None:
        """Record one failed read (caller holds ``_write_mutex``)."""
        self.read_errors += 1
        if self._gap_open_ts is None:
            self._gap_open_ts = self.last_ts()
        if not self._in_error_streak:
            self._in_error_streak = True
            warnings.warn(
                f"sampler read failed ({e!r}); coverage gap opened",
                SamplerReadError, stacklevel=3)

    def _note_read_success(self, t: float) -> None:
        """Close any open coverage gap (caller holds ``_write_mutex``)."""
        if self._gap_open_ts is not None:
            self._gaps.append((self._gap_open_ts, t))
            self._gap_open_ts = None
        self._in_error_streak = False

    def _publish(self, t: float, j: float, w: float) -> None:
        """Write one row (caller holds ``_write_mutex``)."""
        cnt = self._count
        idx = cnt - self._cap * (cnt // self._cap)     # cnt % cap
        if cnt >= self._cap and self._pins:
            self._note_overwrite(idx)
        self._wseq += 1          # odd: row write in flight
        self._ts_col[idx] = t
        self._j_col[idx] = j
        self._w_col[idx] = w
        self._count = cnt + 1
        self._wseq += 1          # even: row published

    def _note_overwrite(self, idx: int) -> None:
        """The full ring is about to overwrite slot ``idx`` (the oldest
        sample).  Any pin whose bracketing sample disappears with it —
        i.e. no remaining sample at/before the pinned t0 — is marked
        evicted (sticky until unpinned)."""
        nxt = idx + 1
        if nxt == self._cap:
            nxt = 0
        next_oldest_ts = self._ts_col[nxt]
        for tok, t0 in list(self._pins.items()):
            if t0 < next_oldest_ts and tok not in self._evicted_pins:
                self._evicted_pins.add(tok)
                self._evictions += 1

    def sample_now(self) -> State:
        """Take one sample on the calling thread, off the period.

        Used by span resolution to close an interval the background
        thread has not reached yet.  The sensor read happens inside the
        writer mutex (two concurrent ``sample_now`` calls must publish in
        timestamp order) but outside any reader-visible critical section:
        ``timeline()``/``window_arrays()`` callers never wait on sensor
        I/O, they seqlock-retry around the final row publish only.
        """
        with self._write_mutex:
            try:
                t, j, w = self._sensor.read_raw()
            except Exception as e:   # noqa: BLE001 — any backend fault
                # Record the gap (the caller's span will resolve
                # degraded) but re-raise: the *caller* asked for a
                # sample and must know it didn't get one.
                self._note_read_failure(e)
                raise
            self._note_read_success(t)
            self._publish(t, j, w)
        return State(timestamp_s=t, joules=j,
                     watts=None if math.isnan(w) else w)

    # -- pins --------------------------------------------------------------
    def pin(self, t0: float) -> int:
        """Pin ``t0`` as a live span start; returns a token for unpin."""
        tok = next(self._pin_ids)
        self._pins[tok] = t0
        return tok

    def unpin(self, token: int) -> None:
        self._pins.pop(token, None)
        self._evicted_pins.discard(token)

    def pin_evicted(self, token: int) -> bool:
        """Whether the ring wrapped over this pin's bracketing sample."""
        return token in self._evicted_pins

    @property
    def evictions(self) -> int:
        """Total pinned-bracket evictions observed by the writer."""
        return self._evictions

    # -- reader side (seqlock, never blocks on the writer) -----------------
    def timeline(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Copy of the live region as ``(timestamps, joules, watts)``
        arrays in time order (the ring seam is unrolled).  Consistent
        snapshot via seqlock retry; never waits on sensor I/O."""
        spins = 0
        while True:
            s1 = self._wseq
            cnt = self._count
            if not (s1 & 1):
                if cnt <= self._cap:
                    ts = self._ts_col[:cnt].copy()
                    js = self._j_col[:cnt].copy()
                    ws = self._w_col[:cnt].copy()
                else:
                    head = cnt % self._cap
                    ts = np.concatenate((self._ts_col[head:],
                                         self._ts_col[:head]))
                    js = np.concatenate((self._j_col[head:],
                                         self._j_col[:head]))
                    ws = np.concatenate((self._w_col[head:],
                                         self._w_col[:head]))
                if self._wseq == s1 and self._count == cnt:
                    return ts, js, ws
            spins += 1
            if spins > 64:       # writer mid-row; yield rather than spin
                time.sleep(0.0001)

    def window_arrays(self, t0: float, t1: float
                      ) -> Tuple[np.ndarray, np.ndarray, bool]:
        """``(timestamps, joules, evicted)`` bracketing ``[t0, t1]``: the
        last sample at/before t0 through the first after t1.

        O(log capacity + window): binary-searches the live ring (two
        segments when wrapped) under seqlock retry and copies only the
        bracketing slice — resolution never copies the whole buffer.
        ``evicted`` is True when the ring has wrapped and the oldest
        retained sample is already newer than ``t0`` (the left bracket
        was overwritten)."""
        cap = self._cap
        spins = 0
        while True:
            s1 = self._wseq
            cnt = self._count
            if not (s1 & 1):
                evicted = False
                if cnt == 0:
                    ts = js = np.empty(0, np.float64)
                elif cnt <= cap:
                    seg = self._ts_col[:cnt]
                    lo = int(seg.searchsorted(t0, side="right")) - 1
                    if lo < 0:
                        lo = 0       # never wrapped: nothing was lost
                    hi = min(int(seg.searchsorted(t1, side="right")) + 1,
                             cnt)
                    ts = seg[lo:hi].copy()
                    js = self._j_col[lo:hi].copy()
                else:
                    head = cnt % cap
                    a_ts = self._ts_col[head:]     # oldest segment
                    b_ts = self._ts_col[:head]     # newest segment
                    la = cap - head

                    def vsearch(t):
                        p = int(a_ts.searchsorted(t, side="right"))
                        if p < la:
                            return p
                        return la + int(b_ts.searchsorted(t, side="right"))

                    lo = vsearch(t0) - 1
                    if lo < 0:
                        evicted = True
                        lo = 0
                    hi = min(vsearch(t1) + 1, cap)
                    if hi <= la:
                        ts = a_ts[lo:hi].copy()
                        js = self._j_col[head + lo:head + hi].copy()
                    elif lo >= la:
                        ts = b_ts[lo - la:hi - la].copy()
                        js = self._j_col[lo - la:hi - la].copy()
                    else:
                        ts = np.concatenate((a_ts[lo:], b_ts[:hi - la]))
                        js = np.concatenate((self._j_col[head + lo:],
                                             self._j_col[:hi - la]))
                if self._wseq == s1 and self._count == cnt:
                    return ts, js, evicted
            spins += 1
            if spins > 64:       # writer mid-row; yield rather than spin
                time.sleep(0.0001)

    def last_ts(self) -> float:
        """Timestamp of the newest published sample (``-inf`` if none).
        Lock-free; may trail the writer by one in-flight row."""
        while True:
            s1 = self._wseq
            cnt = self._count
            if not (s1 & 1):
                if cnt == 0:
                    return float("-inf")
                t = float(self._ts_col[(cnt - 1) % self._cap])
                if self._wseq == s1:
                    return t

    # -- fault-tolerance readers ------------------------------------------
    def gap_overlaps(self, t0: float, t1: float) -> bool:
        """Whether ``[t0, t1]`` straddles a coverage gap (a stretch of
        failed reads), including a still-open gap.  Spans for which this
        is true interpolate across a blackout and resolve ``degraded``.
        """
        open_ts = self._gap_open_ts
        if open_ts is not None and t1 > open_ts:
            return True
        for g0, g1 in tuple(self._gaps):
            if g0 < t1 and g1 > t0:
                return True
        return False

    def staleness_s(self, now: Optional[float] = None) -> float:
        """Age of the newest sample on the sensor clock (``inf`` if the
        ring is empty) — the watchdog signal behind governor signal-TTL
        and the ``/health`` endpoint."""
        if now is None:
            now = self._sensor.now()
        return now - self.last_ts()

    def health(self) -> dict:
        """Sampler health snapshot, merged with the sensor's own
        (supervisor) health when the backend exposes one."""
        in_gap = self._gap_open_ts is not None
        h = {"state": FAILED if in_gap else OK,
             "read_errors": self.read_errors,
             "in_gap": in_gap,
             "gaps": len(self._gaps),
             "staleness_s": self.staleness_s()}
        sensor_health = getattr(self._sensor, "health", None)
        if callable(sensor_health):
            sup = sensor_health()
            h["supervisor"] = sup
            if not in_gap and sup.get("state") in (DEGRADED, FAILED):
                h["state"] = sup["state"]
        return h

    # -- State-compat readers (off the hot path) ---------------------------
    def window(self, t0: float, t1: float
               ) -> Tuple[List[State], List[float]]:
        """Samples bracketing ``[t0, t1]`` as ``State`` objects (legacy
        interface; resolution uses :meth:`window_arrays`)."""
        ts, js, ws = self.timeline()
        lo = int(np.searchsorted(ts, t0, side="right")) - 1
        if lo < 0:
            lo = 0
        hi = int(np.searchsorted(ts, t1, side="right")) + 1
        states = [State(timestamp_s=float(t), joules=float(j),
                        watts=None if math.isnan(w) else float(w))
                  for t, j, w in zip(ts[lo:hi], js[lo:hi], ws[lo:hi])]
        return states, [float(t) for t in ts[lo:hi]]

    def snapshot(self) -> List[State]:
        ts, js, ws = self.timeline()
        return [State(timestamp_s=float(t), joules=float(j),
                      watts=None if math.isnan(w) else float(w))
                for t, j, w in zip(ts, js, ws)]

    def last(self) -> Optional[State]:
        states = self.snapshot()
        return states[-1] if states else None

    def __enter__(self) -> "RingSampler":
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False


class LegacyRingSampler(_PeriodicThread):
    """The previous list-of-``State`` core, kept behind
    ``PMT_LEGACY_RING=1`` for A/B benchmarking only.

    The buffer holds samples in non-decreasing timestamp order — the
    read *and* the append are serialised by ``_sample_lock``, so a slow
    sensor read stalls concurrent ``sample_now`` callers (one of the
    costs the array core removes).  ``_buf_lock`` guards only the list
    mutation.  When the buffer exceeds ``maxlen`` the older half is
    compacted away (amortised O(1)/append) — which can evict a
    still-open span's bracketing start sample at half capacity.
    """

    VECTORIZED = False

    def __init__(self, sensor: Sensor, period_s: Optional[float] = None,
                 maxlen: int = DEFAULT_RING_CAPACITY):
        super().__init__(clamp_period(sensor, period_s))
        self._sensor = sensor
        self._maxlen = maxlen
        self._buf: List[State] = []
        self._ts: List[float] = []
        self._sample_lock = threading.Lock()
        self._buf_lock = threading.Lock()

    @property
    def sensor(self) -> Sensor:
        return self._sensor

    def _tick(self) -> None:
        with self._sample_lock:
            st = self._sensor.read()
            with self._buf_lock:
                self._buf.append(st)
                self._ts.append(st.timestamp_s)
                if len(self._buf) > self._maxlen:
                    half = len(self._buf) // 2
                    del self._buf[:half]
                    del self._ts[:half]

    def sample_now(self) -> State:
        self._tick()
        with self._buf_lock:
            return self._buf[-1]

    # Pins are a no-op on the legacy core: half-compaction evicts
    # regardless, which is exactly the behaviour the A/B measures.
    def pin(self, t0: float) -> int:
        return 0

    def unpin(self, token: int) -> None:
        pass

    def pin_evicted(self, token: int) -> bool:
        return False

    def last_ts(self) -> float:
        with self._buf_lock:
            return self._ts[-1] if self._ts else float("-inf")

    # Coverage-gap tracking is an array-core feature; the legacy core
    # answers the duck-typed API with "no gaps observed".
    def gap_overlaps(self, t0: float, t1: float) -> bool:
        return False

    def staleness_s(self, now: Optional[float] = None) -> float:
        if now is None:
            now = self._sensor.now()
        return now - self.last_ts()

    def health(self) -> dict:
        return {"state": OK, "read_errors": 0, "in_gap": False,
                "gaps": 0, "staleness_s": self.staleness_s()}

    def window(self, t0: float, t1: float
               ) -> Tuple[List[State], List[float]]:
        """Samples bracketing ``[t0, t1]``: the last one at/before t0
        through the first one after t1.  O(log n + window)."""
        with self._buf_lock:
            lo = bisect.bisect_right(self._ts, t0) - 1
            if lo < 0:
                lo = 0
            hi = bisect.bisect_right(self._ts, t1) + 1
            return self._buf[lo:hi], self._ts[lo:hi]

    def window_arrays(self, t0: float, t1: float
                      ) -> Tuple[np.ndarray, np.ndarray, bool]:
        samples, ts = self.window(t0, t1)
        arr_ts = np.array(ts, dtype=np.float64)
        arr_js = np.array([s.joules for s in samples], dtype=np.float64)
        evicted = bool(arr_ts.size and arr_ts[0] > t0)
        return arr_ts, arr_js, evicted

    def snapshot(self) -> List[State]:
        with self._buf_lock:
            return list(self._buf)

    def last(self) -> Optional[State]:
        with self._buf_lock:
            return self._buf[-1] if self._buf else None

    def __enter__(self) -> "LegacyRingSampler":
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False


def make_ring_sampler(sensor: Sensor, period_s: Optional[float] = None,
                      capacity: Optional[int] = None):
    """Construct the configured ring sampler implementation.

    ``PMT_LEGACY_RING=1`` selects the list core (A/B benchmarking);
    ``PMT_RING_CAPACITY`` overrides the default ring capacity.  Checked
    per construction so a benchmark can flip cores between sessions
    without subprocesses.
    """
    if capacity is None:
        capacity = int(os.environ.get("PMT_RING_CAPACITY",
                                      DEFAULT_RING_CAPACITY))
    if os.environ.get("PMT_LEGACY_RING", "") == "1":
        return LegacyRingSampler(sensor, period_s=period_s, maxlen=capacity)
    return RingSampler(sensor, period_s=period_s, capacity=capacity)
