"""Background sampling thread — PMT's core runtime mechanism.

"PMT library's core consists of a background thread to the profiled
application that communicates and gathers power consumption information
from the selected back end."

Two consumers:

  * :class:`DumpThread` — dump-mode: sample at the backend's native period
    and append records to a dump file (see repro.core.dumpfile).
  * :class:`RingSampler` — in-memory timeline with a bounded ring buffer,
    used by the PowerMonitor and the sampling-rate benchmark.

Both honour the backend's ``native_period_s`` floor: sampling faster than
the backend updates only duplicates values (the paper's NVML-10ms /
RAPL-500ms observation), so requests below the floor are clamped.
"""
from __future__ import annotations

import bisect
import threading
from typing import List, Optional, Tuple

from repro.core.dumpfile import DumpWriter
from repro.core.sensor import Sensor
from repro.core.state import State


class _PeriodicThread(threading.Thread):
    """Base: call ``self._tick()`` every ``period_s`` until stopped."""

    def __init__(self, period_s: float):
        super().__init__(daemon=True)
        self._period_s = period_s
        self._stop_evt = threading.Event()

    def run(self) -> None:
        # Sample immediately, then on the period; a final sample on stop
        # closes the interval so short regions still get >= 2 records.
        self._tick()
        while not self._stop_evt.wait(self._period_s):
            self._tick()
        self._tick()

    def stop(self, join: bool = True) -> None:
        self._stop_evt.set()
        if join and self.is_alive():
            self.join(timeout=10.0)

    def _tick(self) -> None:  # pragma: no cover - overridden
        raise NotImplementedError


def clamp_period(sensor: Sensor, period_s: Optional[float]) -> float:
    """Clamp a requested period to the backend's sustainable floor."""
    if period_s is None:
        return sensor.native_period_s
    return max(float(period_s), sensor.native_period_s)


class DumpThread(_PeriodicThread):
    """Dump-mode engine behind ``Sensor.start_dump_thread``."""

    def __init__(self, sensor: Sensor, filename: str,
                 period_s: Optional[float] = None):
        super().__init__(clamp_period(sensor, period_s))
        self._sensor = sensor
        self._writer = DumpWriter(filename, sensor.name, sensor.kind)
        self._first: Optional[State] = None
        self._prev: Optional[State] = None

    def _tick(self) -> None:
        st = self._sensor.read()
        if self._first is None:
            self._first = st
        if st.watts is not None:
            w = st.watts
        elif self._prev is not None:
            w = Sensor.watts(self._prev, st)
        else:
            w = 0.0
        self._writer.write(st.timestamp_s - self._first.timestamp_s, w,
                           st.joules)
        self._prev = st

    def stop(self, join: bool = True) -> None:
        super().stop(join=join)
        self._writer.close()


class RingSampler(_PeriodicThread):
    """In-memory sampler with a bounded buffer of timestamp-ordered States.

    This is the shared sampling service behind ``pmt.Session``: one ring
    per backend, many consumers resolving their region spans against it
    by timestamp instead of issuing synchronous reads on their own hot
    paths (see repro.core.session).

    The buffer holds samples in non-decreasing timestamp order — the
    read *and* the append are serialised by ``_sample_lock``, otherwise
    two concurrent ``sample_now`` calls could append out of order and
    break the bisect-based span resolution.  ``_buf_lock`` guards only
    the list mutation, so ``window``/``snapshot`` readers never wait on
    sensor I/O (RAPL/NVML reads take milliseconds).  When the buffer
    exceeds ``maxlen`` the older half is compacted away (amortised
    O(1)/append).
    """

    def __init__(self, sensor: Sensor, period_s: Optional[float] = None,
                 maxlen: int = 100_000):
        super().__init__(clamp_period(sensor, period_s))
        self._sensor = sensor
        self._maxlen = maxlen
        self._buf: List[State] = []
        self._ts: List[float] = []
        self._sample_lock = threading.Lock()
        self._buf_lock = threading.Lock()

    @property
    def sensor(self) -> Sensor:
        return self._sensor

    def _tick(self) -> None:
        with self._sample_lock:
            st = self._sensor.read()
            with self._buf_lock:
                self._buf.append(st)
                self._ts.append(st.timestamp_s)
                if len(self._buf) > self._maxlen:
                    half = len(self._buf) // 2
                    del self._buf[:half]
                    del self._ts[:half]

    def sample_now(self) -> State:
        """Take one sample on the calling thread, off the period.

        Used by span resolution to close an interval the background
        thread has not reached yet; safe to call concurrently with the
        thread (read + append are a single critical section).
        """
        self._tick()
        with self._buf_lock:
            return self._buf[-1]

    def window(self, t0: float, t1: float
               ) -> Tuple[List[State], List[float]]:
        """Samples bracketing ``[t0, t1]``: the last one at/before t0
        through the first one after t1.  O(log n + window) — resolution
        never copies the whole buffer."""
        with self._buf_lock:
            lo = bisect.bisect_right(self._ts, t0) - 1
            if lo < 0:
                lo = 0
            hi = bisect.bisect_right(self._ts, t1) + 1
            return self._buf[lo:hi], self._ts[lo:hi]

    def snapshot(self) -> List[State]:
        with self._buf_lock:
            return list(self._buf)

    def last(self) -> Optional[State]:
        with self._buf_lock:
            return self._buf[-1] if self._buf else None

    def __enter__(self) -> "RingSampler":
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False
