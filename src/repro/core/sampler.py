"""Background sampling thread — PMT's core runtime mechanism.

"PMT library's core consists of a background thread to the profiled
application that communicates and gathers power consumption information
from the selected back end."

Two consumers:

  * :class:`DumpThread` — dump-mode: sample at the backend's native period
    and append records to a dump file (see repro.core.dumpfile).
  * :class:`RingSampler` — in-memory timeline with a bounded ring buffer,
    used by the PowerMonitor and the sampling-rate benchmark.

Both honour the backend's ``native_period_s`` floor: sampling faster than
the backend updates only duplicates values (the paper's NVML-10ms /
RAPL-500ms observation), so requests below the floor are clamped.
"""
from __future__ import annotations

import collections
import threading
from typing import Deque, List, Optional

from repro.core.dumpfile import DumpWriter
from repro.core.sensor import Sensor
from repro.core.state import State


class _PeriodicThread(threading.Thread):
    """Base: call ``self._tick()`` every ``period_s`` until stopped."""

    def __init__(self, period_s: float):
        super().__init__(daemon=True)
        self._period_s = period_s
        self._stop_evt = threading.Event()

    def run(self) -> None:
        # Sample immediately, then on the period; a final sample on stop
        # closes the interval so short regions still get >= 2 records.
        self._tick()
        while not self._stop_evt.wait(self._period_s):
            self._tick()
        self._tick()

    def stop(self, join: bool = True) -> None:
        self._stop_evt.set()
        if join and self.is_alive():
            self.join(timeout=10.0)

    def _tick(self) -> None:  # pragma: no cover - overridden
        raise NotImplementedError


def clamp_period(sensor: Sensor, period_s: Optional[float]) -> float:
    """Clamp a requested period to the backend's sustainable floor."""
    if period_s is None:
        return sensor.native_period_s
    return max(float(period_s), sensor.native_period_s)


class DumpThread(_PeriodicThread):
    """Dump-mode engine behind ``Sensor.start_dump_thread``."""

    def __init__(self, sensor: Sensor, filename: str,
                 period_s: Optional[float] = None):
        super().__init__(clamp_period(sensor, period_s))
        self._sensor = sensor
        self._writer = DumpWriter(filename, sensor.name, sensor.kind)
        self._first: Optional[State] = None
        self._prev: Optional[State] = None

    def _tick(self) -> None:
        st = self._sensor.read()
        if self._first is None:
            self._first = st
        if st.watts is not None:
            w = st.watts
        elif self._prev is not None:
            w = Sensor.watts(self._prev, st)
        else:
            w = 0.0
        self._writer.write(st.timestamp_s - self._first.timestamp_s, w,
                           st.joules)
        self._prev = st

    def stop(self, join: bool = True) -> None:
        super().stop(join=join)
        self._writer.close()


class RingSampler(_PeriodicThread):
    """In-memory sampler with a bounded ring buffer of States."""

    def __init__(self, sensor: Sensor, period_s: Optional[float] = None,
                 maxlen: int = 100_000):
        super().__init__(clamp_period(sensor, period_s))
        self._sensor = sensor
        self._buf: Deque[State] = collections.deque(maxlen=maxlen)
        self._buf_lock = threading.Lock()

    def _tick(self) -> None:
        st = self._sensor.read()
        with self._buf_lock:
            self._buf.append(st)

    def snapshot(self) -> List[State]:
        with self._buf_lock:
            return list(self._buf)

    def __enter__(self) -> "RingSampler":
        self.start()
        return self

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False
