"""PMT ``State`` — a single sensor reading.

Mirrors the C++ PMT ``pmt::State``: a timestamp plus the cumulative energy
counter at read time.  The three derivations the paper exposes —
``joules(start, end)``, ``watts(start, end)``, ``seconds(start, end)`` —
are pure functions of two ``State``s and live here so they can be tested
independently of any backend.

Some backends report *per-rail* readings (e.g. RAPL package-0 / dram);
those are carried in ``rails`` as cumulative joules per rail name, with
``joules`` always equal to the backend's chosen total.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class State:
    """One reading of a power sensor.

    Attributes:
      timestamp_s: seconds since an arbitrary (per-sensor, monotonic) epoch.
      joules: cumulative energy counter at read time, in joules.  Backends
        that natively report instantaneous power integrate it into this
        counter (trapezoidal) so that ``joules(a, b)`` always works.
      watts: instantaneous power at read time, if the backend knows it
        (may be ``None`` for pure energy-counter backends such as RAPL,
        where average power must come from ``watts(a, b)``).
      rails: per-rail cumulative joules (empty when the backend is
        single-rail).
    """

    timestamp_s: float
    joules: float
    watts: Optional[float] = None
    rails: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.joules < 0:
            raise ValueError(f"cumulative joules must be >= 0, got {self.joules}")


def seconds(start: State, end: State) -> float:
    """Elapsed wall time between two readings, in seconds."""
    return end.timestamp_s - start.timestamp_s


def joules(start: State, end: State) -> float:
    """Energy consumed between two readings, in joules.

    Counter wraparound is a *backend* concern (backends unwrap before
    constructing the ``State``), so this is a plain difference.
    """
    return end.joules - start.joules


def watts(start: State, end: State) -> float:
    """Average power between two readings, in watts.

    Returns 0.0 for a zero-length interval (rather than dividing by zero),
    matching the behaviour expected when two reads race each other.
    """
    dt = seconds(start, end)
    if dt <= 0.0:
        return 0.0
    return joules(start, end) / dt


def rail_joules(start: State, end: State, rail: str) -> float:
    """Energy consumed on a single named rail between two readings."""
    if rail not in start.rails or rail not in end.rails:
        raise KeyError(f"rail {rail!r} not present in both states")
    return end.rails[rail] - start.rails[rail]
