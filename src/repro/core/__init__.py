"""PMT — Power Measurement Toolkit, reproduced in Python/JAX.

The paper's primary contribution (Corda, Veenboer, Tolley, 2022): a
high-level library with a standard interface for measuring the energy use
of devices in critical application sections.

Usage mirrors the paper's Listings 1 and 2::

    import repro.core as pmt

    # C++-style measurement mode (Listing 1)
    sensor = pmt.create("cpuutil")
    start = sensor.read(); work(); end = sensor.read()
    print(sensor.joules(start, end), "J")
    print(sensor.watts(start, end), "W")
    print(sensor.seconds(start, end), "s")

    # Python decorator mode (Listing 2), stacked backends
    @pmt.measure("tpu")
    @pmt.measure("cpuutil")
    def my_application(): ...
    measures = my_application()
    for m in measures: print(m)

    # dump mode
    sensor.start_dump_thread("timeline.pmt"); work()
    sensor.stop_dump_thread()

Backends: rapl, sysfs, cpuutil, nvml, tpu (analytical XLA-cost sensor —
the TPU adaptation), dummy. See DESIGN.md §2 for measured-vs-modeled
labeling.
"""
from repro.core.decorators import (Measurement, Measurements, Region, dump,
                                   measure)
from repro.core.dumpfile import (DumpHeader, DumpRecord, average_watts, read_dump,
                             total_joules)
from repro.core.energy_model import TPU_V5E, EnergyModel, HardwareSpec
from repro.core.metrics import (EfficiencyReport, ed2p, edp, gflops_per_watt,
                                joules_per_token, tokens_per_joule)
from repro.core.monitor import (PowerMonitor, StepEnergy, StragglerVerdict,
                                detect_stragglers)
from repro.core.registry import (available_backend_names, backend_names,
                                 create, get_backend, register_backend)
from repro.core.sampler import DumpThread, RingSampler
from repro.core.sensor import Sample, Sensor, SensorError
from repro.core.state import State, joules, rail_joules, seconds, watts

__all__ = [
    # state & sensor
    "State", "Sample", "Sensor", "SensorError",
    "joules", "watts", "seconds", "rail_joules",
    # registry
    "create", "get_backend", "register_backend",
    "backend_names", "available_backend_names",
    # modes
    "measure", "dump", "Region", "Measurement", "Measurements",
    "DumpThread", "RingSampler",
    "DumpHeader", "DumpRecord", "read_dump", "total_joules", "average_watts",
    # energy model & metrics
    "EnergyModel", "HardwareSpec", "TPU_V5E",
    "EfficiencyReport", "edp", "ed2p", "gflops_per_watt",
    "joules_per_token", "tokens_per_joule",
    # framework integration
    "PowerMonitor", "StepEnergy", "detect_stragglers", "StragglerVerdict",
]
