"""PMT — Power Measurement Toolkit, reproduced in Python/JAX.

The paper's primary contribution (Corda, Veenboer, Tolley, 2022): a
high-level library with a standard interface for measuring the energy use
of devices in critical application sections.

The unified entry point is :class:`pmt.Session`: one refcounted
:class:`SensorPool` of shared sensors, one lazily-started background
:class:`RingSampler` per backend, non-blocking nested regions that
resolve against the ring buffer, and pluggable exporters::

    import repro.core as pmt

    with pmt.Session(["cpuutil", "tpu"]) as sess:
        sess.add_exporter(pmt.JsonlExporter("energy.jsonl"))
        with sess.region("prefill"):
            ...
        with sess.region("decode", tokens=128) as r:
            ...
        print(r.measurements.total_joules(), "J")

Region entry/exit never touch a sensor on the caller's thread — exit is
an O(1) span enqueue, and a background resolver batch-resolves spans
against the sampler's preallocated NumPy ring (one vectorized
``np.searchsorted`` pass per backend, exporter fan-out off-path) — so
concurrent serve requests, the train loop, and the decorators below can
all measure through one sampler per backend without waiting on each
other.  ``measurements`` is future-style: it blocks (resolving
synchronously) only when the number is actually asked for.

``pmt.region("roi", backends=["x"])`` opens a region on the implicit
default session for quick scripts.  Classic surfaces (paper Listings
1/2) remain as shims drawing shared sensors from the default pool:

    ======================================  =================================
    old call                                new (Session) call
    ======================================  =================================
    ``sensor = pmt.create("x")``            ``sess = pmt.Session(["x"])``
    ``a = sensor.read(); ...; b = read()``  ``with sess.region("roi") as r:``
    ``sensor.joules(a, b)``                 ``r.measurement.joules``
    ``@pmt.measure("x")``                   ``with sess.region("roi"):``
    ``with pmt.Region("x") as r:``          ``with sess.region("roi") as r:``
    ``sensor.start_dump_thread(f)``         ``sess.add_exporter(CsvExporter(f))``
    ``pmt.PowerMonitor(["x"])``             ``pmt.PowerMonitor(["x"], session=s)``
    ======================================  =================================

Backends: rapl, sysfs, cpuutil, nvml, tpu (analytical XLA-cost sensor —
the TPU adaptation), dummy. See DESIGN.md §2 for measured-vs-modeled
labeling.
"""
from repro.core.decorators import (Measurement, Measurements, Region, dump,
                                   measure)
from repro.core.dumpfile import (DumpHeader, DumpRecord, average_watts, read_dump,
                             total_joules)
from repro.core.energy_model import TPU_V5E, EnergyModel, HardwareSpec
from repro.core.export import (CsvExporter, Exporter, JsonlExporter,
                               MemoryExporter, RegionRecord, read_jsonl)
from repro.core.metrics import (EfficiencyReport, ed2p, edp, gflops_per_watt,
                                joules_per_token, tokens_per_joule)
from repro.core.monitor import (PowerMonitor, StepEnergy, StragglerVerdict,
                                detect_stragglers)
from repro.core.registry import (available_backend_names, backend_names,
                                 create, get_backend, register_backend)
from repro.core.faults import FAULT_KINDS, Fault, FaultInjectingSensor
from repro.core.resolver import SpanResolver, batch_joules_at
from repro.core.sampler import (DumpThread, LegacyRingSampler, RingSampler,
                                SamplerCoverageGap, SamplerReadError,
                                SamplerWindowEvicted, make_ring_sampler)
from repro.core.sensor import Sample, Sensor, SensorError
from repro.core.supervisor import DEGRADED, FAILED, OK, SensorSupervisor
from repro.core.session import (RegionHandle, SensorLease, SensorPool,
                                Session, default_pool, default_session,
                                region, set_default_session)
from repro.core.state import State, joules, rail_joules, seconds, watts

__all__ = [
    # state & sensor
    "State", "Sample", "Sensor", "SensorError",
    "joules", "watts", "seconds", "rail_joules",
    # registry
    "create", "get_backend", "register_backend",
    "backend_names", "available_backend_names",
    # session facade
    "Session", "SensorPool", "SensorLease", "RegionHandle", "region",
    "default_session", "set_default_session", "default_pool",
    # exporters
    "Exporter", "RegionRecord", "CsvExporter", "JsonlExporter",
    "MemoryExporter", "read_jsonl",
    # classic modes (shims over the default session)
    "measure", "dump", "Region", "Measurement", "Measurements",
    "DumpThread", "RingSampler", "LegacyRingSampler", "make_ring_sampler",
    "SamplerWindowEvicted", "SamplerReadError", "SamplerCoverageGap",
    "SpanResolver", "batch_joules_at",
    # fault tolerance
    "SensorSupervisor", "OK", "DEGRADED", "FAILED",
    "Fault", "FaultInjectingSensor", "FAULT_KINDS",
    "DumpHeader", "DumpRecord", "read_dump", "total_joules", "average_watts",
    # energy model & metrics
    "EnergyModel", "HardwareSpec", "TPU_V5E",
    "EfficiencyReport", "edp", "ed2p", "gflops_per_watt",
    "joules_per_token", "tokens_per_joule",
    # framework integration
    "PowerMonitor", "StepEnergy", "detect_stragglers", "StragglerVerdict",
]
