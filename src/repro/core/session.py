"""``pmt.Session`` — the unified measurement facade.

The paper exposes three modes (read-pairs, decorators, dump files); this
reproduction additionally grew a ``PowerMonitor`` for the training loop.
Each of those constructed and polled its own sensors, which means (a)
blocking ``_sample()`` calls on the caller's hot path and (b) N private
copies of the same backend when the serve engine, train loop, and a
decorator all measure at once.

A :class:`Session` inverts that: sensors live in a refcounted
:class:`SensorPool` (one shared, lazily-started background
:class:`~repro.core.sampler.RingSampler` per backend), and consumers open
*regions*::

    with pmt.Session(["cpuutil", "tpu"]) as sess:
        with sess.region("prefill"):
            ...
        with sess.region("decode", tokens=128) as r:
            ...
    print(r.measurements.total_joules())

Region entry/exit only reads the sensor clock and appends a span — no
sensor I/O on the caller's thread.  Spans resolve lazily against the ring
buffer (linear interpolation of the cumulative-joules counter at the two
span timestamps; one on-demand closing sample if the background thread
has not covered the span yet).  Regions nest (paths like
``"serve/wave0/prefill"``) and are thread-safe, so concurrent serve
requests can each open their own span against the same sampler.

Resolved regions flow to pluggable exporters (see repro.core.export).

The classic surfaces — ``@pmt.measure``, ``pmt.Region``, ``@pmt.dump``,
``pmt.PowerMonitor`` — are thin shims drawing their sensors from the
process-wide :func:`default_pool`, so everything in one process shares
one sampler per backend.  :func:`default_session` is the implicit
session behind the module-level :func:`region` convenience (and
swappable via :func:`set_default_session`)::

    pmt.region("roi", backends=["cpuutil"])   # implicit-session region
"""
from __future__ import annotations

import atexit
import bisect
import collections
import itertools
import threading
from typing import (Any, Deque, Dict, List, Optional, Sequence, Tuple,
                    Union)

from repro.core import registry
from repro.core.export import Exporter, RegionRecord
from repro.core.sampler import RingSampler
from repro.core.sensor import Sensor, SensorError
from repro.core.state import State

BackendSpec = Union[str, Sensor]


# ---------------------------------------------------------------------------
# SensorPool — refcounted shared sensors + ring samplers
# ---------------------------------------------------------------------------

class SensorLease:
    """A consumer's handle on a pooled sensor.

    Holding a lease pins the sensor (and, for sampling leases, its
    background ring sampler) alive; ``release()`` — or releasing the
    owning session — lets the pool stop the sampler once the last
    sampling consumer detaches.
    """

    def __init__(self, pool: "SensorPool", key: Any, sensor: Sensor,
                 sampling: bool):
        self._pool = pool
        self._key = key
        self.sensor = sensor
        self.sampling = sampling
        self._released = False

    @property
    def sampler(self) -> Optional[RingSampler]:
        return self._pool._sampler_for(self._key)

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._pool._release(self._key, self.sampling)

    def __repr__(self):
        return (f"<SensorLease {self.sensor.name!r} "
                f"sampling={self.sampling}>")


class _PoolEntry:
    __slots__ = ("sensor", "sampler", "refs", "sampling_refs", "period_s")

    def __init__(self, sensor: Sensor, period_s: Optional[float]):
        self.sensor = sensor
        self.sampler: Optional[RingSampler] = None
        self.refs = 0
        self.sampling_refs = 0
        self.period_s = period_s


class SensorPool:
    """Refcounted registry of live sensors and their ring samplers.

    Keyed by ``(backend name, construction kwargs)`` — two consumers
    asking for ``"cpuutil"`` get the *same* sensor and the same background
    sampler; passing an existing :class:`Sensor` instance pools by
    identity so framework-owned sensors can be shared too.  The sampler
    starts lazily with the first sampling consumer and stops (joined)
    when the last one releases.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: Dict[Any, _PoolEntry] = {}

    @staticmethod
    def _key_for(spec: BackendSpec, kwargs: Dict[str, Any]) -> Any:
        if isinstance(spec, Sensor):
            return ("instance", id(spec))
        try:
            return (spec, tuple(sorted(kwargs.items())))
        except TypeError:
            # unhashable kwarg (rare): fall back to a repr key so at
            # least identical reprs still share.
            return (spec, repr(sorted(kwargs.items(), key=lambda kv: kv[0])))

    def acquire(self, spec: BackendSpec, *, sampling: bool = True,
                period_s: Optional[float] = None,
                **backend_kwargs) -> SensorLease:
        """Check out a shared sensor (and its sampler when ``sampling``)."""
        key = self._key_for(spec, backend_kwargs)
        start_sampler: Optional[RingSampler] = None
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                sensor = (spec if isinstance(spec, Sensor)
                          else registry.create(spec, **backend_kwargs))
                entry = _PoolEntry(sensor, period_s)
                self._entries[key] = entry
            entry.refs += 1
            if sampling:
                entry.sampling_refs += 1
                if entry.sampler is None:
                    entry.sampler = RingSampler(
                        entry.sensor, period_s=period_s or entry.period_s)
                    start_sampler = entry.sampler
        if start_sampler is not None:
            # Start outside the pool lock; seed one synchronous sample so
            # every span opened after acquire has a left bracket.
            start_sampler.start()
            start_sampler.sample_now()
        return SensorLease(self, key, entry.sensor, sampling)

    def _sampler_for(self, key: Any) -> Optional[RingSampler]:
        with self._lock:
            entry = self._entries.get(key)
            return entry.sampler if entry is not None else None

    def _release(self, key: Any, sampling: bool) -> None:
        stop_sampler: Optional[RingSampler] = None
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                return
            entry.refs -= 1
            if sampling:
                entry.sampling_refs -= 1
                if entry.sampling_refs <= 0 and entry.sampler is not None:
                    stop_sampler = entry.sampler
                    entry.sampler = None
            if entry.refs <= 0:
                del self._entries[key]
        if stop_sampler is not None:
            stop_sampler.stop(join=True)

    def live_sampler_count(self) -> int:
        with self._lock:
            return sum(1 for e in self._entries.values()
                       if e.sampler is not None)

    def close(self) -> None:
        """Force-stop every sampler (process shutdown path)."""
        with self._lock:
            samplers = [e.sampler for e in self._entries.values()
                        if e.sampler is not None]
            self._entries.clear()
        for s in samplers:
            s.stop(join=True)


_default_pool = SensorPool()


def default_pool() -> SensorPool:
    """The process-wide pool the implicit default session draws from."""
    return _default_pool


# ---------------------------------------------------------------------------
# Span resolution — interpolate the cumulative-joules counter
# ---------------------------------------------------------------------------

def _joules_at(samples: Sequence[State], ts: Sequence[float], t: float
               ) -> float:
    """Cumulative joules at sensor-clock time ``t``, linearly interpolated.

    Clamps outside the sampled range (the resolver takes a closing sample
    first, so clamping only under-counts by less than one period at the
    open end).  Duplicate timestamps (virtual clocks) collapse to the
    later sample, which carries the up-to-date counter.
    """
    if not samples:
        raise SensorError("ring buffer empty; sampler not started?")
    i = bisect.bisect_right(ts, t)
    if i <= 0:
        return samples[0].joules
    if i >= len(samples):
        return samples[-1].joules
    lo, hi = samples[i - 1], samples[i]
    dt = hi.timestamp_s - lo.timestamp_s
    if dt <= 0.0:
        return hi.joules
    frac = (t - lo.timestamp_s) / dt
    return lo.joules + frac * (hi.joules - lo.joules)


class _Span:
    """An unresolved region interval: timestamps only, no sensor data."""

    __slots__ = ("path", "label", "depth", "flops", "tokens",
                 "t0", "t1", "snap", "resolved")

    def __init__(self, path: str, label: str, depth: int,
                 flops: Optional[float], tokens: Optional[int],
                 t0: Dict[Any, float], snap):
        self.path = path
        self.label = label
        self.depth = depth
        self.flops = flops
        self.tokens = tokens
        self.t0 = t0                      # pool key -> entry timestamp
        self.t1: Dict[Any, float] = {}    # pool key -> exit timestamp
        self.snap = snap                  # clock snapshot at entry
        self.resolved: Optional["Measurements"] = None


class RegionHandle:
    """Context manager for one region; resolves lazily after exit.

    Entry/exit are non-blocking (clock reads + list append).  Accessing
    :attr:`measurements` after exit resolves the span against the ring
    buffers — taking at most one closing sample per sensor — caches the
    result, and emits one :class:`RegionRecord` per sensor to the
    session's exporters.
    """

    def __init__(self, session: "Session", label: Optional[str],
                 flops: Optional[float], tokens: Optional[int]):
        self._session = session
        self._label = label
        self._flops = flops
        self._tokens = tokens
        self._span: Optional[_Span] = None

    def __enter__(self) -> "RegionHandle":
        self._span = self._session._open_span(self._label, self._flops,
                                              self._tokens)
        return self

    def __exit__(self, *exc) -> bool:
        self._session._close_span(self._span)
        return False

    @property
    def measurements(self) -> "Measurements":
        if self._span is None:
            raise SensorError("region never entered")
        if not self._span.t1:
            raise SensorError("region still open; exit it before resolving")
        return self._session._resolve(self._span)

    @property
    def measurement(self) -> "Measurement":
        """First sensor's measurement (single-backend convenience)."""
        return self.measurements[0]


class Session:
    """Shared-sampler measurement facade (see module docstring).

    Args:
      backends: backend names or Sensor instances this session measures
        by default.  More can be attached later via :meth:`attach`.
      pool: the SensorPool to draw sensors from; defaults to the
        process-wide pool so independent sessions share samplers.
      period_s: sampling period request, clamped per backend to its
        ``native_period_s`` floor.
      exporters: initial exporter sinks (see :mod:`repro.core.export`).
      max_pending: bound on unresolved spans retained for ``flush()``;
        oldest spans drop first (their handles still resolve — the bound
        only limits what an eventual flush will export).
    """

    def __init__(self, backends: Sequence[BackendSpec] = (),
                 *, pool: Optional[SensorPool] = None,
                 period_s: Optional[float] = None,
                 exporters: Sequence[Exporter] = (),
                 max_pending: int = 65536):
        self._pool = pool if pool is not None else default_pool()
        self._period_s = period_s
        self._lock = threading.Lock()
        self._leases: "collections.OrderedDict[Any, SensorLease]" = \
            collections.OrderedDict()
        self._exporters: List[Exporter] = list(exporters)
        # Serialises span resolution: two threads racing handle.measurements
        # against flush() must not both compute/emit the same span.
        self._resolve_lock = threading.Lock()
        self._pending: Deque[_Span] = collections.deque(maxlen=max_pending)
        self._tls = threading.local()
        self._anon = itertools.count(1)
        self._closed = False
        # Hot-path snapshots: regions open/close without the session lock
        # (tuple replacement is atomic; a momentarily stale snapshot just
        # measures the backend set as of region entry).  The clock
        # snapshot pre-binds each sensor's clock callable so a span
        # timestamp is one call, no attribute dispatch.
        self._lease_snapshot: Tuple[SensorLease, ...] = ()
        self._clock_snapshot: Tuple[Tuple[Any, Any], ...] = ()
        try:
            for b in backends:
                self.attach(b)
        except BaseException:
            # A later backend failed (typo'd name, probe error): release
            # what was already acquired or its sampler outlives us.
            self._release_leases()
            raise

    def _release_leases(self) -> None:
        with self._lock:
            leases = list(self._leases.values())
            self._leases.clear()
            self._lease_snapshot = ()
            self._clock_snapshot = ()
        for lease in leases:
            lease.release()

    # -- sensor management ---------------------------------------------------
    def attach(self, backend: BackendSpec, **backend_kwargs) -> Sensor:
        """Attach a backend to this session (idempotent), return its sensor."""
        if self._closed:
            raise SensorError("session is closed")
        key = SensorPool._key_for(backend, backend_kwargs)
        with self._lock:
            lease = self._leases.get(key)
            if lease is None:
                lease = self._pool.acquire(
                    backend, sampling=True, period_s=self._period_s,
                    **backend_kwargs)
                self._leases[key] = lease
                self._lease_snapshot = tuple(self._leases.values())
                self._clock_snapshot = tuple(
                    (l._key, l.sensor._clock) for l in self._lease_snapshot)
            return lease.sensor

    @property
    def sensors(self) -> List[Sensor]:
        with self._lock:
            return [lease.sensor for lease in self._leases.values()]

    def add_exporter(self, exporter: Exporter) -> Exporter:
        with self._lock:
            self._exporters.append(exporter)
        return exporter

    # -- regions -------------------------------------------------------------
    def region(self, label: Optional[str] = None, *,
               flops: Optional[float] = None,
               tokens: Optional[int] = None) -> RegionHandle:
        """Open a (nestable, thread-safe, non-blocking) measured region."""
        return RegionHandle(self, label, flops, tokens)

    def _label_stack(self) -> List[str]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _open_span(self, label: Optional[str], flops: Optional[float],
                   tokens: Optional[int]) -> _Span:
        if self._closed:
            raise SensorError("session is closed")
        leases = self._lease_snapshot
        if not leases:
            raise SensorError(
                "session has no backends; pass them to Session(...) or "
                "call session.attach(...)")
        if label is None:
            label = f"region{next(self._anon)}"
        stack = self._label_stack()
        path = "/".join(stack + [label]) if stack else label
        # Spans key their timestamps by pool key, not sensor name — two
        # pooled sensors may share a name (same backend, different kwargs).
        snap = self._clock_snapshot
        span = _Span(path, label, len(stack), flops, tokens,
                     {k: clk() for k, clk in snap}, snap)
        stack.append(label)
        return span

    def _close_span(self, span: Optional[_Span]) -> None:
        if span is None:
            return
        snap = self._clock_snapshot
        if snap is span.snap:        # common case: backend set unchanged
            span.t1 = {k: clk() for k, clk in snap}
        else:                        # a backend attached mid-span
            t0 = span.t0
            span.t1 = {k: clk() for k, clk in snap if k in t0}
        stack = self._label_stack()
        if stack and stack[-1] == span.label:
            stack.pop()
        self._pending.append(span)

    def _resolve(self, span: _Span) -> "Measurements":
        from repro.core.decorators import Measurement, Measurements

        with self._resolve_lock:
            if span.resolved is not None:
                return span.resolved
            with self._lock:
                leases = [l for l in self._leases.values()
                          if l._key in span.t1]
            out = Measurements()
            records: List[RegionRecord] = []
            for lease in leases:
                name = lease.sensor.name
                t0, t1 = span.t0[lease._key], span.t1[lease._key]
                sampler = lease.sampler
                if sampler is None:
                    raise SensorError(f"sampler for {name!r} already stopped")
                samples, ts = sampler.window(t0, t1)
                if not samples or ts[-1] < t1:
                    sampler.sample_now()
                    samples, ts = sampler.window(t0, t1)
                j0 = _joules_at(samples, ts, t0)
                j1 = _joules_at(samples, ts, t1)
                joules = max(0.0, j1 - j0)
                secs = t1 - t0
                watts = joules / secs if secs > 0 else 0.0
                # States synthesized at the span endpoints, so downstream
                # code written against read()-pair results keeps working.
                start = State(timestamp_s=t0, joules=j0)
                end = State(timestamp_s=t1, joules=j1)
                out.append(Measurement(
                    sensor=name, kind=lease.sensor.kind, joules=joules,
                    watts=watts, seconds=secs, start=start, end=end,
                    label=span.path))
                records.append(RegionRecord(
                    path=span.path, label=span.label, depth=span.depth,
                    sensor=name, kind=lease.sensor.kind, start_s=t0, end_s=t1,
                    seconds=secs, joules=joules, watts=watts,
                    flops=span.flops, tokens=span.tokens))
            span.resolved = out
            with self._lock:
                exporters = list(self._exporters)
            for exp in exporters:
                for rec in records:
                    exp.emit(rec)
            return out

    def flush(self) -> List["Measurements"]:
        """Resolve every pending span (emitting to exporters); drain them.

        Spans join the pending queue only when their region exits, so
        everything here is closed and resolvable.
        """
        out = []
        while True:
            try:
                span = self._pending.popleft()
            except IndexError:
                return out
            out.append(self._resolve(span))

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Flush, close exporters, release every lease (idempotent)."""
        if self._closed:
            return
        self.flush()
        self._closed = True
        with self._lock:
            exporters = list(self._exporters)
            self._exporters.clear()
        self._release_leases()
        for exp in exporters:
            exp.close()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def __repr__(self):
        names = [s.name for s in self.sensors]
        return f"<Session backends={names} closed={self._closed}>"


# ---------------------------------------------------------------------------
# Implicit default session — what the legacy shims ride on
# ---------------------------------------------------------------------------

_default_session: Optional[Session] = None
_default_lock = threading.Lock()


def default_session() -> Session:
    """The process-wide implicit session behind module-level ``region``.

    Created lazily with no backends (``region(..., backends=...)``
    attaches what it needs) and torn down at interpreter exit.  It
    draws from the same :func:`default_pool` as the classic shims, so
    everything shares one sampler per backend either way.
    """
    global _default_session
    with _default_lock:
        if _default_session is None or _default_session._closed:
            _default_session = Session(pool=default_pool())
        return _default_session


def region(label: Optional[str] = None, *,
           backends: Sequence[BackendSpec] = (),
           flops: Optional[float] = None,
           tokens: Optional[int] = None) -> RegionHandle:
    """Open a region on the implicit default session::

        with pmt.region("roi", backends=["cpuutil"]) as r:
            work()
        print(r.measurement)

    ``backends`` attach to the default session (idempotent); omit them
    once attached.  For anything beyond quick scripts, construct an
    explicit :class:`Session`.
    """
    sess = default_session()
    for b in backends:
        sess.attach(b)
    return sess.region(label, flops=flops, tokens=tokens)


def set_default_session(session: Optional[Session]) -> Optional[Session]:
    """Swap the implicit default session; returns the previous one."""
    global _default_session
    with _default_lock:
        prev, _default_session = _default_session, session
        return prev


@atexit.register
def _shutdown() -> None:  # pragma: no cover - interpreter teardown
    with _default_lock:
        sess = _default_session
    if sess is not None:
        try:
            sess.close()
        except Exception:
            pass
