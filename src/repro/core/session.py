"""``pmt.Session`` — the unified measurement facade.

The paper exposes three modes (read-pairs, decorators, dump files); this
reproduction additionally grew a ``PowerMonitor`` for the training loop.
Each of those constructed and polled its own sensors, which means (a)
blocking ``_sample()`` calls on the caller's hot path and (b) N private
copies of the same backend when the serve engine, train loop, and a
decorator all measure at once.

A :class:`Session` inverts that: sensors live in a refcounted
:class:`SensorPool` (one shared, lazily-started background
:class:`~repro.core.sampler.RingSampler` per backend), and consumers open
*regions*::

    with pmt.Session(["cpuutil", "tpu"]) as sess:
        with sess.region("prefill"):
            ...
        with sess.region("decode", tokens=128) as r:
            ...
    print(r.measurements.total_joules())

The measurement hot path allocates nothing durable and reads no sensor:

  * region *entry* reads each backend's clock and pins the span start on
    the ring (so wraparound over it is detectable);
  * region *exit* is O(1) — it reads the clocks again, appends the span
    to a bounded queue, and wakes the background resolver.

Resolution happens off-path in :mod:`repro.core.resolver`: a background
thread batch-resolves many spans per backend with one vectorized pass
(``np.searchsorted`` over all endpoints, fused interpolation of the
cumulative-joules counter) once the ring's timeline covers them, then
fans the records out to exporters.  ``RegionHandle.measurements`` is
future-style — it blocks (resolving synchronously, at most one closing
sample per backend) only if the caller actually asks for the number, so
serve/train loops that just export never wait.  Results therefore become
available either ~one sampling period after region exit (async) or
immediately on ``measurements``/``flush()``/``close()`` (forced).

Regions nest (paths like ``"serve/wave0/prefill"``) and are thread-safe,
so concurrent serve requests can each open their own span against the
same sampler.  A span that outlives the ring capacity resolves with
``window_evicted=True`` (and a ``SamplerWindowEvicted`` warning) instead
of silently under-reporting energy.

Resolved regions flow to pluggable exporters (see repro.core.export).

Subscriber-exporter contract: a :class:`~repro.core.export.MemoryExporter`
subscriber callback (and a ``PowerMonitor.subscribe`` callback) is
invoked on whichever thread resolves the span — normally the session's
background resolver.  The callback **must not block**: while it runs, no
further spans resolve and no other exporter receives records, so a slow
callback back-pressures the whole measurement plane (the bounded span
queue eventually drops the oldest spans from auto-resolution).  Hand the
record to a queue and return — the telemetry server's SSE fan-out does
exactly this.  A callback that raises is dropped with a warning rather
than killing the resolver.

The classic surfaces — ``@pmt.measure``, ``pmt.Region``, ``@pmt.dump``,
``pmt.PowerMonitor`` — are thin shims drawing their sensors from the
process-wide :func:`default_pool`, so everything in one process shares
one sampler per backend.  :func:`default_session` is the implicit
session behind the module-level :func:`region` convenience (and
swappable via :func:`set_default_session`)::

    pmt.region("roi", backends=["cpuutil"])   # implicit-session region
"""
from __future__ import annotations

import atexit
import bisect
import collections
import itertools
import threading
import warnings
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple, Union)

from repro.core import registry
from repro.core import resolver as resolver_mod
from repro.core.export import Exporter, RegionRecord
from repro.core.sampler import make_ring_sampler
from repro.core.sensor import Sensor, SensorError
from repro.core.state import State

BackendSpec = Union[str, Sensor]


# ---------------------------------------------------------------------------
# SensorPool — refcounted shared sensors + ring samplers
# ---------------------------------------------------------------------------

class SensorLease:
    """A consumer's handle on a pooled sensor.

    Holding a lease pins the sensor (and, for sampling leases, its
    background ring sampler) alive; ``release()`` — or releasing the
    owning session — lets the pool stop the sampler once the last
    sampling consumer detaches.
    """

    def __init__(self, pool: "SensorPool", key: Any, sensor: Sensor,
                 sampling: bool):
        self._pool = pool
        self._key = key
        self.sensor = sensor
        self.sampling = sampling
        self._released = False

    @property
    def sampler(self):
        return self._pool._sampler_for(self._key)

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._pool._release(self._key, self.sampling)

    def __repr__(self):
        return (f"<SensorLease {self.sensor.name!r} "
                f"sampling={self.sampling}>")


class _PoolEntry:
    __slots__ = ("sensor", "sampler", "refs", "sampling_refs", "period_s")

    def __init__(self, sensor: Sensor, period_s: Optional[float]):
        self.sensor = sensor
        self.sampler = None
        self.refs = 0
        self.sampling_refs = 0
        self.period_s = period_s


class SensorPool:
    """Refcounted registry of live sensors and their ring samplers.

    Keyed by ``(backend name, construction kwargs)`` — two consumers
    asking for ``"cpuutil"`` get the *same* sensor and the same background
    sampler; passing an existing :class:`Sensor` instance pools by
    identity so framework-owned sensors can be shared too.  The sampler
    starts lazily with the first sampling consumer and stops (joined)
    when the last one releases.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: Dict[Any, _PoolEntry] = {}

    @staticmethod
    def _key_for(spec: BackendSpec, kwargs: Dict[str, Any]) -> Any:
        if isinstance(spec, Sensor):
            return ("instance", id(spec))
        try:
            return (spec, tuple(sorted(kwargs.items())))
        except TypeError:
            # unhashable kwarg (rare): fall back to a repr key so at
            # least identical reprs still share.
            return (spec, repr(sorted(kwargs.items(), key=lambda kv: kv[0])))

    def acquire(self, spec: BackendSpec, *, sampling: bool = True,
                period_s: Optional[float] = None,
                **backend_kwargs) -> SensorLease:
        """Check out a shared sensor (and its sampler when ``sampling``)."""
        key = self._key_for(spec, backend_kwargs)
        start_sampler = None
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                sensor = (spec if isinstance(spec, Sensor)
                          else registry.create(spec, **backend_kwargs))
                entry = _PoolEntry(sensor, period_s)
                self._entries[key] = entry
            entry.refs += 1
            if sampling:
                entry.sampling_refs += 1
                if entry.sampler is None:
                    entry.sampler = make_ring_sampler(
                        entry.sensor, period_s=period_s or entry.period_s)
                    start_sampler = entry.sampler
        if start_sampler is not None:
            # Start outside the pool lock; seed one synchronous sample so
            # every span opened after acquire has a left bracket.
            start_sampler.start()
            start_sampler.sample_now()
        return SensorLease(self, key, entry.sensor, sampling)

    def _sampler_for(self, key: Any):
        with self._lock:
            entry = self._entries.get(key)
            return entry.sampler if entry is not None else None

    def _release(self, key: Any, sampling: bool) -> None:
        stop_sampler = None
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                return
            entry.refs -= 1
            if sampling:
                entry.sampling_refs -= 1
                if entry.sampling_refs <= 0 and entry.sampler is not None:
                    stop_sampler = entry.sampler
                    entry.sampler = None
            if entry.refs <= 0:
                del self._entries[key]
        if stop_sampler is not None:
            stop_sampler.stop(join=True)

    def live_sampler_count(self) -> int:
        with self._lock:
            return sum(1 for e in self._entries.values()
                       if e.sampler is not None)

    def close(self) -> None:
        """Force-stop every sampler (process shutdown path)."""
        with self._lock:
            samplers = [e.sampler for e in self._entries.values()
                        if e.sampler is not None]
            self._entries.clear()
        for s in samplers:
            s.stop(join=True)


_default_pool = SensorPool()


def default_pool() -> SensorPool:
    """The process-wide pool the implicit default session draws from."""
    return _default_pool


# ---------------------------------------------------------------------------
# Span resolution — interpolate the cumulative-joules counter
# ---------------------------------------------------------------------------

def _joules_at(samples: Sequence[State], ts: Sequence[float], t: float
               ) -> float:
    """Cumulative joules at sensor-clock time ``t``, linearly interpolated.

    The scalar reference for :func:`repro.core.resolver.batch_joules_at`
    (and the resolution path for the ``PMT_LEGACY_RING=1`` list core).
    Clamps outside the sampled range (the resolver takes a closing sample
    first, so clamping only under-counts by less than one period at the
    open end).  Duplicate timestamps (virtual clocks) collapse to the
    later sample, which carries the up-to-date counter.
    """
    if not samples:
        raise SensorError("ring buffer empty; sampler not started?")
    i = bisect.bisect_right(ts, t)
    if i <= 0:
        return samples[0].joules
    if i >= len(samples):
        return samples[-1].joules
    lo, hi = samples[i - 1], samples[i]
    dt = hi.timestamp_s - lo.timestamp_s
    if dt <= 0.0:
        return hi.joules
    frac = (t - lo.timestamp_s) / dt
    return lo.joules + frac * (hi.joules - lo.joules)


class _Span:
    """An unresolved region interval: timestamps only, no sensor data."""

    __slots__ = ("path", "label", "depth", "flops", "tokens",
                 "t0", "t1", "snap", "pins", "resolved", "error",
                 "on_resolved", "seq", "nested")

    def __init__(self, path: str, label: str, depth: int,
                 flops: Optional[float], tokens: Optional[int],
                 t0: Dict[Any, float], snap, pins,
                 on_resolved, nested: bool = True):
        self.path = path
        self.label = label
        self.depth = depth
        self.flops = flops
        self.tokens = tokens
        self.t0 = t0                      # pool key -> entry timestamp
        self.t1: Dict[Any, float] = {}    # pool key -> exit timestamp
        self.snap = snap                  # (key, clock) snapshot at entry
        self.pins = pins                  # pool key -> (sampler, pin token)
        self.resolved = None              # Measurements once resolved
        self.error: Optional[BaseException] = None
        self.on_resolved = on_resolved    # callback(Measurements), once
        self.seq = 0                      # close order (set at close)
        self.nested = nested              # False: span skipped the stack


class RegionHandle:
    """Context manager for one region; resolves asynchronously after exit.

    Entry/exit are non-blocking (clock reads, a ring pin, a queue
    append).  :attr:`measurements` is future-style: if the background
    resolver already finished the span it returns the cached result;
    otherwise it resolves synchronously on the calling thread (taking at
    most one closing sample per sensor).  Either way the span's
    :class:`RegionRecord`\\ s are emitted to the session's exporters
    exactly once.
    """

    def __init__(self, session: "Session", label: Optional[str],
                 flops: Optional[float], tokens: Optional[int],
                 on_resolved=None, nested: bool = True):
        self._session = session
        self._label = label
        self._flops = flops
        self._tokens = tokens
        self._on_resolved = on_resolved
        self._nested = nested
        self._span: Optional[_Span] = None

    def __enter__(self) -> "RegionHandle":
        self._span = self._session._open_span(self._label, self._flops,
                                              self._tokens,
                                              self._on_resolved,
                                              nested=self._nested)
        return self

    def __exit__(self, *exc) -> bool:
        self._session._close_span(self._span)
        return False

    @property
    def resolved(self) -> bool:
        """Whether the background resolver already finished this span
        (non-blocking peek)."""
        return self._span is not None and self._span.resolved is not None

    @property
    def measurements(self) -> "Measurements":
        if self._span is None:
            raise SensorError("region never entered")
        if not self._span.t1:
            raise SensorError("region still open; exit it before resolving")
        return self._session._resolve_blocking(self._span)

    @property
    def measurement(self) -> "Measurement":
        """First sensor's measurement (single-backend convenience)."""
        return self.measurements[0]


class Session:
    """Shared-sampler measurement facade (see module docstring).

    Args:
      backends: backend names or Sensor instances this session measures
        by default.  More can be attached later via :meth:`attach`.
      pool: the SensorPool to draw sensors from; defaults to the
        process-wide pool so independent sessions share samplers.
      period_s: sampling period request, clamped per backend to its
        ``native_period_s`` floor.
      exporters: initial exporter sinks (see :mod:`repro.core.export`).
      max_pending: bound on spans queued for (or awaiting) background
        resolution; on overflow the oldest span is dropped from the
        *auto-resolve* path — its handle still resolves on access, the
        drop is counted in :meth:`stats`, never silent.
    """

    def __init__(self, backends: Sequence[BackendSpec] = (),
                 *, pool: Optional[SensorPool] = None,
                 period_s: Optional[float] = None,
                 exporters: Sequence[Exporter] = (),
                 max_pending: int = 65536):
        self._pool = pool if pool is not None else default_pool()
        self._period_s = period_s
        self._max_pending = max_pending
        self._lock = threading.Lock()
        self._leases: "collections.OrderedDict[Any, SensorLease]" = \
            collections.OrderedDict()
        self._exporters: List[Exporter] = list(exporters)
        # Serialises span resolution (background batches, blocking
        # accesses, flush): exporters see each span exactly once, in
        # close order for the batched path.
        self._resolve_lock = threading.Lock()
        # Closed spans ride _queue (lock-free append on the hot path)
        # until the resolver claims them into _waiting; _waiting holds
        # spans whose rings don't cover t1 yet; background-settled spans
        # park in _flushable so flush() can still return them.  All
        # three under _resolve_lock.
        self._queue: Deque[_Span] = collections.deque()
        self._waiting: List[_Span] = []
        self._flushable: Deque[_Span] = collections.deque(
            maxlen=max_pending)
        self._close_seq = itertools.count(1)
        # Exporter emissions and on_resolved callbacks never run under
        # _resolve_lock (a callback touching the session would
        # self-deadlock): resolution appends to _emit_queue and the
        # resolving thread drains it FIFO after releasing the lock.
        # RLock so a callback that itself forces resolution can drain
        # its own nested emissions.
        self._emit_queue: Deque[tuple] = collections.deque()
        self._emit_lock = threading.RLock()
        self._resolver: Optional[resolver_mod.SpanResolver] = None
        self._stats = {"resolved": 0, "evicted": 0, "degraded": 0,
                       "dropped": 0, "resolve_errors": 0}
        self._tls = threading.local()
        self._anon = itertools.count(1)
        self._closed = False
        # Hot-path snapshot: regions open/close without the session lock
        # (attribute replacement is atomic; a momentarily stale snapshot
        # just measures the backend set as of region entry).  One tuple
        # holds both views so open/close never see mismatched halves:
        #   open3:  (key, clock, sampler) — entry timestamps + ring pins
        #   pairs:  (key, clock)          — exit timestamps
        # pre-bound so a span timestamp is one call, no attribute
        # dispatch.
        self._lease_snapshot: Tuple[SensorLease, ...] = ()
        self._hot_snapshot: Tuple[Tuple, Tuple] = ((), ())
        try:
            for b in backends:
                self.attach(b)
        except BaseException:
            # A later backend failed (typo'd name, probe error): release
            # what was already acquired or its sampler outlives us.
            self._stop_resolver()
            self._release_leases()
            raise

    def _release_leases(self) -> None:
        with self._lock:
            leases = list(self._leases.values())
            self._leases.clear()
            self._lease_snapshot = ()
            self._hot_snapshot = ((), ())
        for lease in leases:
            lease.release()

    def _stop_resolver(self) -> None:
        res = self._resolver
        if res is not None:
            res.stop(join=True)
            if res.is_alive():  # pragma: no cover - stuck sensor I/O
                warnings.warn("pmt resolver thread did not stop within "
                              "timeout; leaking daemon thread")
            self._resolver = None

    # -- sensor management ---------------------------------------------------
    def attach(self, backend: BackendSpec, **backend_kwargs) -> Sensor:
        """Attach a backend to this session (idempotent), return its sensor."""
        if self._closed:
            raise SensorError("session is closed")
        key = SensorPool._key_for(backend, backend_kwargs)
        with self._lock:
            lease = self._leases.get(key)
            if lease is None:
                lease = self._pool.acquire(
                    backend, sampling=True, period_s=self._period_s,
                    **backend_kwargs)
                self._leases[key] = lease
                self._lease_snapshot = tuple(self._leases.values())
                open3 = tuple((l._key, l.sensor._clock, l.sampler)
                              for l in self._lease_snapshot)
                self._hot_snapshot = (
                    open3, tuple((k, clk) for k, clk, _ in open3))
            if self._resolver is None:
                self._resolver = resolver_mod.SpanResolver(self)
                self._resolver.start()
            return lease.sensor

    def _lease_by_key(self, key: Any) -> Optional[SensorLease]:
        with self._lock:
            return self._leases.get(key)

    @property
    def sensors(self) -> List[Sensor]:
        with self._lock:
            return [lease.sensor for lease in self._leases.values()]

    def samplers(self) -> List[Tuple[str, Any]]:
        """``(backend name, ring sampler)`` per attached backend.

        The read-only seam the telemetry plane taps for live power
        timelines: a :class:`~repro.core.sampler.RingSampler`'s
        ``timeline()``/``window_arrays()`` readers are seqlock-based and
        never block the sampling thread, so a poller can copy watts
        series as often as it likes without perturbing measurement.
        Samplers are pool-owned; entries go stale once the session (or
        the last sampling consumer) releases the backend.
        """
        with self._lock:
            return [(lease.sensor.name, lease.sampler)
                    for lease in self._leases.values()
                    if lease.sampler is not None]

    def add_exporter(self, exporter: Exporter) -> Exporter:
        with self._lock:
            self._exporters.append(exporter)
        return exporter

    # -- regions -------------------------------------------------------------
    def region(self, label: Optional[str] = None, *,
               flops: Optional[float] = None,
               tokens: Optional[int] = None,
               on_resolved: Optional[Callable] = None,
               nested: bool = True) -> RegionHandle:
        """Open a (nestable, thread-safe, non-blocking) measured region.

        ``on_resolved`` is called exactly once with the span's
        ``Measurements`` when it resolves — on the background resolver
        thread, or on whichever thread forces resolution first.

        ``nested=False`` opens a *flat* span: it neither reads nor joins
        the thread-local label stack (path == label, depth 0), so many
        spans can be open concurrently on one thread and close in any
        order — the serve engine's per-request spans, whose lifetimes
        interleave as slots retire and refill, need exactly this.
        """
        return RegionHandle(self, label, flops, tokens,
                            on_resolved=on_resolved, nested=nested)

    def _label_stack(self) -> List[str]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _open_span(self, label: Optional[str], flops: Optional[float],
                   tokens: Optional[int], on_resolved,
                   nested: bool = True) -> _Span:
        if self._closed:
            raise SensorError("session is closed")
        open3, pairs = self._hot_snapshot
        if not open3:
            raise SensorError(
                "session has no backends; pass them to Session(...) or "
                "call session.attach(...)")
        if label is None:
            label = f"region{next(self._anon)}"
        if nested:
            stack = self._label_stack()
            path = "/".join(stack + [label]) if stack else label
            depth = len(stack)
        else:
            path, depth = label, 0
        # Spans key their timestamps by pool key, not sensor name — two
        # pooled sensors may share a name (same backend, different kwargs).
        t0: Dict[Any, float] = {}
        pins: Dict[Any, Tuple[Any, int]] = {}
        for k, clk, sampler in open3:
            t = clk()
            t0[k] = t
            pins[k] = (sampler, sampler.pin(t))
        span = _Span(path, label, depth, flops, tokens, t0, pairs,
                     pins, on_resolved, nested=nested)
        if nested:
            stack.append(label)
        return span

    def _close_span(self, span: Optional[_Span]) -> None:
        if span is None:
            return
        pairs = self._hot_snapshot[1]
        if pairs is span.snap:       # common case: backend set unchanged
            span.t1 = {k: clk() for k, clk in pairs}
        else:                        # a backend attached mid-span
            t0 = span.t0
            span.t1 = {k: clk() for k, clk in pairs if k in t0}
        if span.nested:
            stack = self._label_stack()
            if stack and stack[-1] == span.label:
                stack.pop()
        span.seq = next(self._close_seq)
        # O(1) hand-off to the background resolver; no locks, no sensor
        # I/O, no resolution work on the caller's thread.  The wake event
        # stays set while the resolver is busy (it clears only right
        # before a drain), so a burst of closes costs one event set plus
        # an is_set() check per region — and because every clear is
        # followed by a drain, a span appended before the check can
        # never be stranded (no lost wakeup).
        q = self._queue
        if len(q) >= self._max_pending:
            try:
                old = q.popleft()
            except IndexError:      # racing drain emptied it — fine
                pass
            else:
                self._drop_span(old)
        q.append(span)
        res = self._resolver
        if res is not None and not res.wake.is_set():
            res.wake.set()

    def _unpin_span(self, span: _Span) -> None:
        for sampler, tok in span.pins.values():
            sampler.unpin(tok)
        span.pins = {}

    def _drop_span(self, span: _Span) -> None:
        """A span fell off the bounded auto-resolve queue: count it and
        release its ring pins.  Its handle can still resolve on access."""
        if span.resolved is None and span.error is None:
            self._stats["dropped"] += 1
        self._unpin_span(span)

    # -- resolution plumbing (called by repro.core.resolver) -----------------
    def _note_span_resolved(self, span: _Span, evicted: bool,
                            degraded: bool = False) -> None:
        self._stats["resolved"] += 1
        if evicted:
            self._stats["evicted"] += 1
        if degraded:
            self._stats["degraded"] += 1
        self._unpin_span(span)

    def _note_span_error(self, span: _Span) -> None:
        self._stats["resolve_errors"] += 1
        self._unpin_span(span)

    def _enqueue_emission(self, records, on_resolved, measurements) -> None:
        """Queue a resolved span's exporter records + callback (caller
        holds ``_resolve_lock``; actual emission happens in
        :meth:`_drain_emissions` after the lock is released)."""
        self._emit_queue.append((records, on_resolved, measurements))

    def _drain_emissions(self) -> None:
        """Emit queued records/callbacks FIFO, outside ``_resolve_lock``.

        Every resolution path calls this right after releasing the
        resolve lock, so (a) exporters see records exactly once and in
        close order (the queue is FIFO and one drainer runs at a time),
        (b) a blocking ``measurements`` access returns only after its
        span's records reached the exporters *and* callbacks ran — the
        unconditional emit-lock acquisition doubles as a barrier against
        an emission another thread has in flight — and (c) an
        ``on_resolved`` callback may safely call back into the session:
        it runs under no session lock except the re-entrant emit lock.
        """
        while True:
            with self._emit_lock:
                while True:
                    try:
                        records, cb, ms = self._emit_queue.popleft()
                    except IndexError:
                        break
                    with self._lock:
                        exporters = list(self._exporters)
                    for exp in exporters:
                        for rec in records:
                            exp.emit(rec)
                    if cb is not None:
                        cb(ms)
            if not self._emit_queue:
                return

    def _drain_ready(self, force: bool) -> Tuple[int, int]:
        """Claim queued spans and resolve the ones their rings cover.

        The background resolver calls this with ``force=False`` so async
        resolution never issues an extra sensor read: spans ahead of the
        sampler timeline wait in ``_waiting`` for the next tick; settled
        spans park in ``_flushable`` for the next ``flush()``.  Returns
        ``(resolved_now, deferred)`` counts.
        """
        with self._resolve_lock:
            waiting = self._waiting
            while True:
                try:
                    waiting.append(self._queue.popleft())
                except IndexError:
                    break
            if not waiting:
                return 0, 0
            ready: List[_Span] = []
            deferred: List[_Span] = []
            for span in waiting:
                if span.resolved is not None:
                    self._flushable.append(span)   # settled via an access
                    continue
                if span.error is not None:
                    continue
                if force or resolver_mod._covered(self, span):
                    ready.append(span)
                else:
                    deferred.append(span)
            if ready:
                resolver_mod.resolve_spans(self, ready, force=force)
                for span in ready:
                    if span.resolved is not None:
                        self._flushable.append(span)
            if len(deferred) > self._max_pending:
                for span in deferred[:-self._max_pending]:
                    self._drop_span(span)
                deferred = deferred[-self._max_pending:]
            self._waiting = deferred
        self._drain_emissions()
        return len(ready), len(deferred)

    def _resolve_blocking(self, span: _Span) -> "Measurements":
        if span.resolved is None:
            with self._resolve_lock:
                if span.resolved is None and span.error is None:
                    resolver_mod.resolve_spans(self, [span], force=True)
        # Always drain — even when the background resolver resolved the
        # span first, its exporter records / on_resolved callback may
        # still be queued or mid-emission; the drain's lock acquisition
        # barriers on them so a returning ``measurements`` caller can
        # rely on completion side effects (e.g. monitor accounting).
        self._drain_emissions()
        if span.error is not None:
            raise span.error
        return span.resolved

    def flush(self) -> List["Measurements"]:
        """Resolve every pending span now (emitting to exporters); drain.

        Spans join the queue only when their region exits, so everything
        here is closed and resolvable — at most one closing sample per
        backend is taken for spans the ring does not cover yet.  Returns
        the resolved :class:`Measurements` in close order for every span
        closed since the last flush — including spans the background
        resolver or a handle access already settled.  Spans that could
        *not* resolve (their sampler stopped underneath them) are
        surfaced in :meth:`stats` under ``resolve_errors`` rather than
        dropped silently.
        """
        with self._resolve_lock:
            spans = list(self._flushable) + self._waiting
            self._flushable.clear()
            self._waiting = []
            while True:
                try:
                    spans.append(self._queue.popleft())
                except IndexError:
                    break
            resolver_mod.resolve_spans(
                self, [s for s in spans if s.resolved is None], force=True)
            spans.sort(key=lambda s: s.seq)
            out = [s.resolved for s in spans if s.resolved is not None]
        self._drain_emissions()
        return out

    def stats(self) -> Dict[str, int]:
        """Resolution counters: ``resolved``, ``evicted`` (spans flagged
        ``window_evicted``), ``degraded`` (spans that straddled a sensor
        coverage gap), ``dropped`` (fell off the bounded queue — handles
        still resolve on access), ``resolve_errors``, and ``pending``
        (closed spans not yet resolved)."""
        with self._resolve_lock:
            pending = len(self._queue) + sum(
                1 for s in self._waiting
                if s.resolved is None and s.error is None)
            out = dict(self._stats)
        out["pending"] = pending
        return out

    def health(self) -> Dict[str, Any]:
        """Per-backend measurement-plane health, keyed by backend name.

        Each entry is the backend sampler's :meth:`RingSampler.health`
        snapshot (state ok/degraded/failed, read errors, coverage gaps,
        staleness, plus the wrapped supervisor's chain health when the
        backend is a :class:`~repro.core.supervisor.SensorSupervisor`).
        """
        return {name: sampler.health()
                for name, sampler in self.samplers()}

    # -- lifecycle -----------------------------------------------------------
    def close(self, timeout: float = 5.0) -> None:
        """Flush, stop the resolver (bounded join), close exporters,
        release every lease (idempotent).  Never hangs on a wedged
        resolver thread and never drops spans silently: anything still
        unresolved after the drain is reported via a warning +
        :meth:`stats`."""
        if self._closed:
            return
        self.flush()
        self._closed = True
        res = self._resolver
        if res is not None:
            res.stop(join=True, timeout=timeout)
            self._resolver = None
        st = self.stats()
        if st["resolve_errors"] or st["pending"]:
            warnings.warn(
                f"pmt.Session closed with {st['resolve_errors']} "
                f"unresolvable and {st['pending']} unresolved spans "
                f"(see Session.stats())")
        with self._lock:
            exporters = list(self._exporters)
            self._exporters.clear()
        self._release_leases()
        for exp in exporters:
            exp.close()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def __repr__(self):
        names = [s.name for s in self.sensors]
        return f"<Session backends={names} closed={self._closed}>"


# ---------------------------------------------------------------------------
# Implicit default session — what the legacy shims ride on
# ---------------------------------------------------------------------------

_default_session: Optional[Session] = None
_default_lock = threading.Lock()


def default_session() -> Session:
    """The process-wide implicit session behind module-level ``region``.

    Created lazily with no backends (``region(..., backends=...)``
    attaches what it needs) and torn down at interpreter exit.  It
    draws from the same :func:`default_pool` as the classic shims, so
    everything shares one sampler per backend either way.
    """
    global _default_session
    with _default_lock:
        if _default_session is None or _default_session._closed:
            _default_session = Session(pool=default_pool())
        return _default_session


def region(label: Optional[str] = None, *,
           backends: Sequence[BackendSpec] = (),
           flops: Optional[float] = None,
           tokens: Optional[int] = None) -> RegionHandle:
    """Open a region on the implicit default session::

        with pmt.region("roi", backends=["cpuutil"]) as r:
            work()
        print(r.measurement)

    ``backends`` attach to the default session (idempotent); omit them
    once attached.  For anything beyond quick scripts, construct an
    explicit :class:`Session`.
    """
    sess = default_session()
    for b in backends:
        sess.attach(b)
    return sess.region(label, flops=flops, tokens=tokens)


def set_default_session(session: Optional[Session]) -> Optional[Session]:
    """Swap the implicit default session; returns the previous one."""
    global _default_session
    with _default_lock:
        prev, _default_session = _default_session, session
        return prev


@atexit.register
def _shutdown() -> None:  # pragma: no cover - interpreter teardown
    with _default_lock:
        sess = _default_session
    if sess is not None:
        try:
            sess.close()
        except Exception:
            pass
