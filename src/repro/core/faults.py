"""Deterministic fault injection for sensor backends.

The measurement plane's robustness claims (supervisor retry/failover,
sampler blackout gaps, governor fail-safe degradation) are only testable
if faults are *scriptable*: the Cray PMDB experience paper shows real
power counters drop samples, reset mid-run, and report garbage, but none
of that reproduces on demand in CI.  :class:`FaultInjectingSensor` wraps
any backend and replays a fault plan — a list of :class:`Fault` windows —
deterministically against either the read index or an injectable clock,
so a chaos test (or benchmarks/bench_faults.py) can stage an exact
blackout/flap/recovery timeline without sleeping.

Fault kinds (the fault matrix):

========  ============================================================
kind      effect on the wrapped read
========  ============================================================
error     raise :class:`~repro.core.sensor.SensorError`
hang      sleep ``hang_s`` (injected sleep fn) then read normally —
          with a fake clock this models a slow read, not a real stall
nan       watts replaced with NaN (power-meter poisoning)
negative  watts negated (bogus counter math upstream)
spike     watts multiplied by ``factor`` (transient garbage value)
stuck     joules/watts frozen at their last pre-fault values
reset     joules counter restarts from ``reset_to`` (RAPL wraparound /
          node reboot: the raw counter goes *backwards*)
flap      ``error``, but only on reads where
          ``(i // period) % duty_cycle == 0`` — intermittent failure
========  ============================================================

Windows select by read index (``start``/``count``) or by time
(``t0_s``/``t1_s`` relative to :meth:`FaultInjectingSensor.arm`, or to
the first read if never armed).  Index windows make unit tests
bit-exact; time windows let a live bench stage "blackout from t=1.0s to
t=2.5s" regardless of sampling rate.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

from repro.core.sensor import Sample, Sensor, SensorError

FAULT_KINDS = ("error", "hang", "nan", "negative", "spike", "stuck",
               "reset", "flap")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scripted fault window (see module docstring for kinds).

    Exactly one selector must be active: an index window (``start`` +
    ``count``, count=None meaning "forever") or a time window (``t0_s`` +
    ``t1_s`` seconds relative to arm time).
    """

    kind: str
    start: Optional[int] = None       # first read index affected
    count: Optional[int] = None       # reads affected (None = until stopped)
    t0_s: Optional[float] = None      # time window start (relative to arm)
    t1_s: Optional[float] = None      # time window end (None = forever)
    hang_s: float = 0.0               # kind="hang": injected read latency
    factor: float = 10.0              # kind="spike": watts multiplier
    reset_to: float = 0.0             # kind="reset": counter restart value
    period: int = 2                   # kind="flap": cycle length in reads
    duty: int = 1                     # kind="flap": failing reads per cycle

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        by_index = self.start is not None
        by_time = self.t0_s is not None
        if by_index == by_time:
            raise ValueError("fault needs exactly one selector: "
                             "start/count (index) or t0_s/t1_s (time)")
        if self.kind == "flap" and not (0 < self.duty <= self.period):
            raise ValueError(f"flap needs 0 < duty <= period, got "
                             f"duty={self.duty} period={self.period}")

    def _active(self, index: int, rel_t: Optional[float]) -> bool:
        if self.start is not None:
            if index < self.start:
                return False
            return self.count is None or index < self.start + self.count
        if rel_t is None:
            return False
        if rel_t < self.t0_s:
            return False
        return self.t1_s is None or rel_t < self.t1_s

    def _fires(self, index: int, rel_t: Optional[float]) -> bool:
        if not self._active(index, rel_t):
            return False
        if self.kind != "flap":
            return True
        return (index % self.period) < self.duty


class FaultInjectingSensor(Sensor):
    """Wrap ``inner`` and replay ``plan`` faults over its samples.

    The wrapper is itself a :class:`Sensor`: it overrides ``_sample()``
    so faults flow through the exact read path the sampler/supervisor
    exercise in production (base-class locking, watts integration, raw
    tuples).  ``clock``/``sleep_fn`` are injectable so a hang fault in a
    test advances a fake clock instead of stalling the suite.
    """

    def __init__(self, inner: Sensor, plan: Sequence[Fault] = (),
                 clock: Optional[Callable[[], float]] = None,
                 sleep_fn: Optional[Callable[[float], None]] = None):
        super().__init__(clock=clock or inner._clock)
        self.name = inner.name
        self.kind = inner.kind
        self.native_period_s = inner.native_period_s
        self._inner = inner
        self._plan: List[Fault] = list(plan)
        self._sleep = sleep_fn or time.sleep
        self._index = 0               # reads attempted so far
        self._t_armed: Optional[float] = None
        self._stuck_sample: Optional[Sample] = None
        self._reset_base: Optional[float] = None   # inner joules at reset
        self._injected = {k: 0 for k in FAULT_KINDS}

    # -- plan control ------------------------------------------------------
    def arm(self, t: Optional[float] = None) -> None:
        """(Re)base time-window faults at ``t`` (default: clock now).

        Call after warmup/compile so "blackout at t0_s=1.0" means one
        second into the *measured* run, not one second into jit tracing.
        """
        self._t_armed = self._clock() if t is None else t

    def extend(self, *faults: Fault) -> None:
        self._plan.extend(faults)

    @property
    def injected(self) -> dict:
        """Per-kind count of faults actually injected (not just planned)."""
        return dict(self._injected)

    # -- the faulted read path --------------------------------------------
    def _sample(self) -> Sample:
        idx = self._index
        self._index = idx + 1
        now = self._clock()
        if self._t_armed is None:
            self._t_armed = now
        rel_t = now - self._t_armed
        fired = [f for f in self._plan if f._fires(idx, rel_t)]
        for f in fired:
            if f.kind == "hang":
                self._injected["hang"] += 1
                self._sleep(f.hang_s)
        if any(f.kind in ("error", "flap") for f in fired):
            for f in fired:
                if f.kind in ("error", "flap"):
                    self._injected[f.kind] += 1
            raise SensorError(
                f"injected fault on {self.name!r} read #{idx}")
        if any(f.kind == "stuck" for f in fired) \
                and self._stuck_sample is not None:
            self._injected["stuck"] += 1
            return self._stuck_sample

        s = self._inner._sample()
        joules, watts = s.joules, s.watts
        for f in fired:
            if f.kind == "nan" and watts is not None:
                self._injected["nan"] += 1
                watts = float("nan")
            elif f.kind == "negative" and watts is not None:
                self._injected["negative"] += 1
                watts = -abs(watts)
            elif f.kind == "spike" and watts is not None:
                self._injected["spike"] += 1
                watts = watts * f.factor
            elif f.kind == "reset" and joules is not None:
                self._injected["reset"] += 1
                if self._reset_base is None:
                    self._reset_base = joules
                joules = f.reset_to + (joules - self._reset_base)
        if not any(f.kind == "reset" for f in fired):
            self._reset_base = None
        out = Sample(joules=joules, watts=watts, rails=s.rails)
        if not fired:
            self._stuck_sample = out
        return out

    def __repr__(self):
        return (f"<FaultInjectingSensor inner={self._inner!r} "
                f"plan={len(self._plan)} faults>")
