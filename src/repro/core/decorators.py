"""Classic PMT surfaces — now thin shims over the implicit default session.

    import repro.core as pmt

    @pmt.measure("rapl")
    def my_application():
        ...

    measures = my_application()
    for m in measures:
        print(m)

Semantics preserved from the paper:

  * the decorated call returns the measurements (a :class:`Measurements`
    list of one :class:`Measurement` per backend); the wrapped function's
    own return value is available as ``measures.result``;
  * decorators stack — ``@pmt.measure("tpu")`` above ``@pmt.measure("cpuutil")``
    yields both measurements in one list (paper Fig. 2 stacks GPU on CPU);
  * ``@pmt.dump(backend, filename=...)`` is measurement's dump-mode twin.

What changed (the ``pmt.Session`` redesign): sensors are no longer
constructed privately per decorated function.  Every shim draws its
sensor from the process-wide default :class:`~repro.core.session.SensorPool`
(the same pool behind ``pmt.Session`` / ``pmt.region``), so a decorator,
the serve engine, and the train loop measuring the same backend all
share one sensor (and, for Region consumers, one background sampler).
``pmt.Region`` resolves against the shared ring buffer instead of
issuing its own reads; its per-backend shim sessions are closed at
interpreter exit.

Deprecation note: these shims stay supported, but new code should use
:class:`pmt.Session` directly — ``with session.region("roi"):`` is
non-blocking on the hot path and nests; ``@pmt.measure`` still performs
two synchronous reads around the call (the paper's Listing 2 contract
requires materialised results at return time).
"""
from __future__ import annotations

import atexit
import dataclasses
import functools
import threading
import weakref
from typing import Any, Dict, List, Optional, Union

from repro.core.sensor import Sensor, SensorError
from repro.core.state import State


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One backend's measurement of one region of interest.

    ``window_evicted`` flags a session region that outlived the sampling
    ring: its bracketing start sample was overwritten before resolution,
    so ``joules`` covers a truncated window (see
    ``repro.core.sampler.SamplerWindowEvicted``).

    ``degraded`` flags a region that straddled a sampler coverage gap
    (failed reads / sensor blackout): ``joules`` interpolates across the
    blackout, so treat the number as a lower-confidence estimate (see
    ``repro.core.sampler.SamplerCoverageGap``).
    """

    sensor: str
    kind: str
    joules: float
    watts: float
    seconds: float
    start: State
    end: State
    label: Optional[str] = None
    window_evicted: bool = False
    degraded: bool = False

    def __str__(self) -> str:
        tag = f"{self.sensor}" + (f"[{self.label}]" if self.label else "")
        return (f"{tag}: {self.joules:.6f} J, {self.watts:.6f} W, "
                f"{self.seconds:.6f} s ({self.kind})")

    @property
    def edp(self) -> float:
        """Energy-delay product, J*s (paper §III)."""
        return self.joules * self.seconds


class Measurements(List[Measurement]):
    """List of measurements; carries the wrapped function's return value."""

    result: Any = None

    def by_sensor(self, name: str) -> Measurement:
        for m in self:
            if m.sensor == name:
                return m
        raise KeyError(name)

    def total_joules(self) -> float:
        return sum(m.joules for m in self)


def _pooled(backend: Union[str, Sensor], sampling: bool = False, **kwargs):
    """A lease on a shared sensor from the default pool."""
    from repro.core.session import default_pool

    return default_pool().acquire(backend, sampling=sampling, **kwargs)


def _adopt_leases(wrapper, leases) -> None:
    """Tie pool leases to a decorated function's lifetime.

    The wrapper holds the leases (so the sensors stay pooled while it
    is callable) and releases them when it is garbage collected —
    without this, dynamically-created decorators would grow the pool
    unboundedly with entries nothing can ever release.
    """
    wrapper.__pmt_leases__ = leases
    wrapper.__pmt_sensors__ = [l.sensor for l in leases]
    for lease in leases:
        weakref.finalize(wrapper, lease.release)


# Single-backend sessions backing the Region shim, one per pool key, so
# Region("dummy") resolves only dummy even when the default session has
# other backends attached.
_shim_sessions: Dict[Any, "object"] = {}
_shim_lock = threading.Lock()


def _shim_session(backend: Union[str, Sensor], **kwargs):
    from repro.core.session import Session, SensorPool, default_pool

    key = SensorPool._key_for(backend, kwargs)
    with _shim_lock:
        sess = _shim_sessions.get(key)
        if sess is None or sess._closed:
            sess = Session(pool=default_pool())
            sess.attach(backend, **kwargs)
            _shim_sessions[key] = sess
        return sess


@atexit.register
def _close_shim_sessions() -> None:  # pragma: no cover - teardown
    with _shim_lock:
        sessions = list(_shim_sessions.values())
        _shim_sessions.clear()
    for sess in sessions:
        try:
            sess.close()
        except Exception:
            pass


def measure(*backends: Union[str, Sensor], label: Optional[str] = None,
            **backend_kwargs):
    """Measurement-mode decorator (paper mode 2) — blocking by contract.

    One pooled sensor per listed backend is read before and after the
    wrapped call.  Multiple backends in one decorator and stacked
    decorators both work and produce a flat :class:`Measurements` list.

    Prefer ``session.region(...)`` for hot paths: this decorator must
    return resolved measurements, so it reads synchronously on the
    caller's thread (see benchmarks/bench_overhead.py for the gap).
    """
    if not backends:
        raise ValueError("pmt.measure requires at least one backend")

    def decorate(fn):
        leases = [_pooled(b, **backend_kwargs) for b in backends]
        sensors = [l.sensor for l in leases]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            starts = [s.read() for s in sensors]
            inner = fn(*args, **kwargs)
            ends = [s.read() for s in sensors]
            out = Measurements()
            for sensor, st, en in zip(sensors, starts, ends):
                out.append(Measurement(
                    sensor=sensor.name, kind=sensor.kind,
                    joules=Sensor.joules(st, en),
                    watts=Sensor.watts(st, en),
                    seconds=Sensor.seconds(st, en),
                    start=st, end=en, label=label))
            if isinstance(inner, Measurements):
                # Stacked decorator underneath: merge, keep its result.
                out.extend(inner)
                out.result = inner.result
            else:
                out.result = inner
            return out

        _adopt_leases(wrapper, leases)  # __pmt_sensors__ for tests/benchmarks
        return wrapper

    return decorate


def dump(backend: Union[str, Sensor], filename: str,
         period_s: Optional[float] = None, **backend_kwargs):
    """Dump-mode decorator (paper mode 1).

    Runs a background dump thread for the duration of the wrapped call,
    writing the power timeline to ``filename``; the wrapped function's own
    return value passes through unchanged (measurements live in the file).

    The sensor is pooled; the dump thread is private to this decorator,
    so two dump decorators over the same backend coexist (each owns its
    file).
    """

    def decorate(fn):
        from repro.core.sampler import DumpThread

        lease = _pooled(backend, **backend_kwargs)
        sensor = lease.sensor
        running = threading.Lock()

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # One dump at a time per decorated function: a concurrent
            # second call would truncate and interleave the same file.
            if not running.acquire(blocking=False):
                raise SensorError(
                    f"dump thread already running for {filename!r}")
            thread = DumpThread(sensor, filename, period_s=period_s)
            thread.start()
            try:
                return fn(*args, **kwargs)
            finally:
                thread.stop()
                running.release()

        _adopt_leases(wrapper, [lease])
        return wrapper

    return decorate


class Region:
    """Imperative measurement helper (the C++ Listing 1 shape)::

        with pmt.Region(sensor) as r:
            work()
        print(r.measurement)

    Now a shim over a pooled single-backend session region: entry/exit
    are non-blocking; the measurement resolves against the shared ring
    buffer when the block exits (at most one closing sample).
    """

    def __init__(self, sensor: Union[str, Sensor], label: Optional[str] = None,
                 **backend_kwargs):
        self._session = _shim_session(sensor, **backend_kwargs)
        self._label = label
        self.measurement: Optional[Measurement] = None

    def __enter__(self) -> "Region":
        self._handle = self._session.region(self._label)
        self._handle.__enter__()
        return self

    def __exit__(self, *exc) -> bool:
        self._handle.__exit__(*exc)
        m = self._handle.measurements[0]
        # Old Region reported the caller's label verbatim (not a path).
        self.measurement = dataclasses.replace(m, label=self._label)
        return False
