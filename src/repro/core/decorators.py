"""Python decorators — the paper's Listing 2 interface.

    import repro.core as pmt

    @pmt.measure("rapl")
    def my_application():
        ...

    measures = my_application()
    for m in measures:
        print(m)

Semantics preserved from the paper:

  * the decorated call returns the measurements (a :class:`Measurements`
    list of one :class:`Measurement` per backend); the wrapped function's
    own return value is available as ``measures.result``;
  * decorators stack — ``@pmt.measure("tpu")`` above ``@pmt.measure("cpuutil")``
    yields both measurements in one list (paper Fig. 2 stacks GPU on CPU);
  * overhead is cumulative per decorator (benchmarked in
    benchmarks/bench_overhead.py against the paper's ~10 ms Python claim);
  * ``@pmt.dump(backend, filename=...)`` is measurement's dump-mode twin.

Backends may be passed by name (constructed via the registry, one fresh
sensor per decorated function) or as an existing Sensor instance (so a
framework-owned TpuCostModelSensor can be shared).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, List, Optional, Union

from repro.core import registry
from repro.core.sensor import Sensor
from repro.core.state import State


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One backend's measurement of one region of interest."""

    sensor: str
    kind: str
    joules: float
    watts: float
    seconds: float
    start: State
    end: State
    label: Optional[str] = None

    def __str__(self) -> str:
        tag = f"{self.sensor}" + (f"[{self.label}]" if self.label else "")
        return (f"{tag}: {self.joules:.6f} J, {self.watts:.6f} W, "
                f"{self.seconds:.6f} s ({self.kind})")

    @property
    def edp(self) -> float:
        """Energy-delay product, J*s (paper §III)."""
        return self.joules * self.seconds


class Measurements(List[Measurement]):
    """List of measurements; carries the wrapped function's return value."""

    result: Any = None

    def by_sensor(self, name: str) -> Measurement:
        for m in self:
            if m.sensor == name:
                return m
        raise KeyError(name)

    def total_joules(self) -> float:
        return sum(m.joules for m in self)


def _resolve(backend: Union[str, Sensor], **kwargs) -> Sensor:
    if isinstance(backend, Sensor):
        return backend
    return registry.create(backend, **kwargs)


def measure(*backends: Union[str, Sensor], label: Optional[str] = None,
            **backend_kwargs):
    """Measurement-mode decorator (paper mode 2).

    One sensor per listed backend is read before and after the wrapped
    call.  Multiple backends in one decorator and stacked decorators both
    work and produce a flat :class:`Measurements` list.
    """
    if not backends:
        raise ValueError("pmt.measure requires at least one backend")

    def decorate(fn):
        sensors = [_resolve(b, **backend_kwargs) for b in backends]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            starts = [s.read() for s in sensors]
            inner = fn(*args, **kwargs)
            ends = [s.read() for s in sensors]
            out = Measurements()
            for sensor, st, en in zip(sensors, starts, ends):
                out.append(Measurement(
                    sensor=sensor.name, kind=sensor.kind,
                    joules=Sensor.joules(st, en),
                    watts=Sensor.watts(st, en),
                    seconds=Sensor.seconds(st, en),
                    start=st, end=en, label=label))
            if isinstance(inner, Measurements):
                # Stacked decorator underneath: merge, keep its result.
                out.extend(inner)
                out.result = inner.result
            else:
                out.result = inner
            return out

        wrapper.__pmt_sensors__ = sensors  # exposed for tests/benchmarks
        return wrapper

    return decorate


def dump(backend: Union[str, Sensor], filename: str,
         period_s: Optional[float] = None, **backend_kwargs):
    """Dump-mode decorator (paper mode 1).

    Runs a background dump thread for the duration of the wrapped call,
    writing the power timeline to ``filename``; the wrapped function's own
    return value passes through unchanged (measurements live in the file).
    """

    def decorate(fn):
        sensor = _resolve(backend, **backend_kwargs)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            sensor.start_dump_thread(filename, period_s=period_s)
            try:
                return fn(*args, **kwargs)
            finally:
                sensor.stop_dump_thread()

        wrapper.__pmt_sensors__ = [sensor]
        return wrapper

    return decorate


class Region:
    """Imperative measurement-mode helper (the C++ Listing 1 shape)::

        with pmt.Region(sensor) as r:
            work()
        print(r.measurement)
    """

    def __init__(self, sensor: Union[str, Sensor], label: Optional[str] = None,
                 **backend_kwargs):
        self._sensor = _resolve(sensor, **backend_kwargs)
        self._label = label
        self.measurement: Optional[Measurement] = None

    def __enter__(self) -> "Region":
        self._start = self._sensor.read()
        return self

    def __exit__(self, *exc) -> bool:
        end = self._sensor.read()
        self.measurement = Measurement(
            sensor=self._sensor.name, kind=self._sensor.kind,
            joules=Sensor.joules(self._start, end),
            watts=Sensor.watts(self._start, end),
            seconds=Sensor.seconds(self._start, end),
            start=self._start, end=end, label=self._label)
        return False
