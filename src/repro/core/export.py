"""Structured export of resolved session regions.

A :class:`repro.core.session.Session` resolves every finished region into
one :class:`RegionRecord` per attached sensor and hands it to each
registered exporter.  Exporters are deliberately dumb sinks — resolution
(ring-buffer interpolation, nesting paths) happens in the session; an
exporter only serialises.

Built-in exporters:

  * :class:`CsvExporter`   — one flushed CSV row per record (the
    PowerMonitor energy-log format, generalised to arbitrary regions).
  * :class:`JsonlExporter` — one JSON object per line; round-trips via
    :func:`read_jsonl`.
  * :class:`MemoryExporter` — in-memory record stream with subscriber
    callbacks, for dashboards/tests that want records as they resolve.

Exporters must tolerate concurrent ``emit`` calls: sessions resolve
regions from whichever thread first asks for a measurement.
"""
from __future__ import annotations

import csv
import dataclasses
import json
import threading
from typing import Callable, List, Optional, TextIO


@dataclasses.dataclass(frozen=True)
class RegionRecord:
    """One sensor's resolved measurement of one session region."""

    path: str            # nesting path, e.g. "serve/wave0/prefill"
    label: str           # leaf label, e.g. "prefill"
    depth: int           # nesting depth (0 = top-level region)
    sensor: str
    kind: str            # measured | modeled | hybrid
    start_s: float       # sensor-clock timestamp at region entry
    end_s: float         # sensor-clock timestamp at region exit
    seconds: float
    joules: float
    watts: float
    flops: Optional[float] = None
    tokens: Optional[int] = None
    # True when the region outlived the sampling ring and resolved from
    # a truncated window (energy under-reported; see SamplerWindowEvicted).
    window_evicted: bool = False

    def as_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, sort_keys=True)

    @classmethod
    def from_json(cls, line: str) -> "RegionRecord":
        d = json.loads(line)
        return cls(**d)


class Exporter:
    """Base class: override ``emit``; ``close`` is optional."""

    def emit(self, record: RegionRecord) -> None:  # pragma: no cover
        raise NotImplementedError

    def close(self) -> None:
        pass


class CsvExporter(Exporter):
    """Append-mode CSV sink, one flushed line per record."""

    HEADER = ("path,label,depth,sensor,kind,start_s,end_s,seconds,"
              "joules,watts,flops,tokens,window_evicted\n")

    def __init__(self, path: str):
        self._lock = threading.Lock()
        self._f: Optional[TextIO] = open(path, "a", buffering=1,
                                         newline="")
        self._writer = csv.writer(self._f, lineterminator="\n")
        if self._f.tell() == 0:
            self._f.write(self.HEADER)

    def emit(self, r: RegionRecord) -> None:
        with self._lock:
            if self._f is None:
                return
            # csv.writer so user-supplied path/label survive commas.
            self._writer.writerow([
                r.path, r.label, r.depth, r.sensor, r.kind,
                f"{r.start_s:.6f}", f"{r.end_s:.6f}", f"{r.seconds:.6f}",
                f"{r.joules:.6f}", f"{r.watts:.3f}",
                "" if r.flops is None else f"{r.flops:.0f}",
                "" if r.tokens is None else r.tokens,
                int(r.window_evicted)])

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


class JsonlExporter(Exporter):
    """One JSON object per line; read back with :func:`read_jsonl`."""

    def __init__(self, path: str):
        self._lock = threading.Lock()
        self._f: Optional[TextIO] = open(path, "a", buffering=1)

    def emit(self, r: RegionRecord) -> None:
        with self._lock:
            if self._f is not None:
                self._f.write(r.as_json() + "\n")

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


def read_jsonl(path: str) -> List[RegionRecord]:
    """Parse a JSONL export back into records (skips blank lines)."""
    out: List[RegionRecord] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(RegionRecord.from_json(line))
    return out


class MemoryExporter(Exporter):
    """In-memory subscriber stream.

    Keeps every emitted record in ``records`` (bounded by ``maxlen``) and
    fans each one out to subscriber callbacks as it resolves — the seam a
    live dashboard or a per-request energy attributor hangs off.
    """

    def __init__(self, maxlen: Optional[int] = None):
        self._lock = threading.Lock()
        self._records: List[RegionRecord] = []
        self._maxlen = maxlen
        self._subs: List[Callable[[RegionRecord], None]] = []

    def subscribe(self, fn: Callable[[RegionRecord], None]) -> Callable[[], None]:
        """Register ``fn`` for future records; returns an unsubscribe."""
        with self._lock:
            self._subs.append(fn)

        def unsubscribe() -> None:
            with self._lock:
                if fn in self._subs:
                    self._subs.remove(fn)

        return unsubscribe

    def emit(self, r: RegionRecord) -> None:
        with self._lock:
            self._records.append(r)
            if self._maxlen is not None and len(self._records) > self._maxlen:
                del self._records[:len(self._records) - self._maxlen]
            subs = list(self._subs)
        for fn in subs:
            fn(r)

    @property
    def records(self) -> List[RegionRecord]:
        with self._lock:
            return list(self._records)

    def total_joules(self, sensor: Optional[str] = None) -> float:
        return sum(r.joules for r in self.records
                   if sensor is None or r.sensor == sensor)
