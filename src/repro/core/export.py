"""Structured export of resolved session regions.

A :class:`repro.core.session.Session` resolves every finished region into
one :class:`RegionRecord` per attached sensor and hands it to each
registered exporter.  Exporters are deliberately dumb sinks — resolution
(ring-buffer interpolation, nesting paths) happens in the session; an
exporter only serialises.

Built-in exporters:

  * :class:`CsvExporter`   — one flushed CSV row per record (the
    PowerMonitor energy-log format, generalised to arbitrary regions).
  * :class:`JsonlExporter` — one JSON object per line; round-trips via
    :func:`read_jsonl`.
  * :class:`MemoryExporter` — in-memory record stream with subscriber
    callbacks, for dashboards/tests that want records as they resolve.

Exporters must tolerate concurrent ``emit`` calls: sessions resolve
regions from whichever thread first asks for a measurement.
"""
from __future__ import annotations

import csv
import dataclasses
import json
import threading
import warnings
from typing import Callable, List, Optional, TextIO


@dataclasses.dataclass(frozen=True)
class RegionRecord:
    """One sensor's resolved measurement of one session region."""

    path: str            # nesting path, e.g. "serve/wave0/prefill"
    label: str           # leaf label, e.g. "prefill"
    depth: int           # nesting depth (0 = top-level region)
    sensor: str
    kind: str            # measured | modeled | hybrid
    start_s: float       # sensor-clock timestamp at region entry
    end_s: float         # sensor-clock timestamp at region exit
    seconds: float
    joules: float
    watts: float
    flops: Optional[float] = None
    tokens: Optional[int] = None
    # True when the region outlived the sampling ring and resolved from
    # a truncated window (energy under-reported; see SamplerWindowEvicted).
    window_evicted: bool = False
    # True when the region straddled a sampler coverage gap (failed
    # reads): joules interpolates across the blackout, lower confidence
    # (see SamplerCoverageGap).
    degraded: bool = False

    def as_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, sort_keys=True)

    @classmethod
    def from_json(cls, line: str) -> "RegionRecord":
        d = json.loads(line)
        return cls(**d)


class Exporter:
    """Base class: override ``emit``; ``close`` is optional."""

    def emit(self, record: RegionRecord) -> None:  # pragma: no cover
        raise NotImplementedError

    def close(self) -> None:
        pass


class CsvExporter(Exporter):
    """Append-mode CSV sink, one flushed line per record."""

    HEADER = ("path,label,depth,sensor,kind,start_s,end_s,seconds,"
              "joules,watts,flops,tokens,window_evicted,degraded\n")

    def __init__(self, path: str):
        self._lock = threading.Lock()
        self._f: Optional[TextIO] = open(path, "a", buffering=1,
                                         newline="")
        self._writer = csv.writer(self._f, lineterminator="\n")
        if self._f.tell() == 0:
            self._f.write(self.HEADER)

    def emit(self, r: RegionRecord) -> None:
        with self._lock:
            if self._f is None:
                return
            # csv.writer so user-supplied path/label survive commas.
            self._writer.writerow([
                r.path, r.label, r.depth, r.sensor, r.kind,
                f"{r.start_s:.6f}", f"{r.end_s:.6f}", f"{r.seconds:.6f}",
                f"{r.joules:.6f}", f"{r.watts:.3f}",
                "" if r.flops is None else f"{r.flops:.0f}",
                "" if r.tokens is None else r.tokens,
                int(r.window_evicted), int(r.degraded)])

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


class JsonlExporter(Exporter):
    """One JSON object per line; read back with :func:`read_jsonl`."""

    def __init__(self, path: str):
        self._lock = threading.Lock()
        self._f: Optional[TextIO] = open(path, "a", buffering=1)

    def emit(self, r: RegionRecord) -> None:
        with self._lock:
            if self._f is not None:
                self._f.write(r.as_json() + "\n")

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


def read_jsonl(path: str, strict: bool = False) -> List[RegionRecord]:
    """Parse a JSONL export back into records (skips blank lines).

    A live export is appended to concurrently, so the file's last line
    may be mid-write (truncated JSON) when a tailing reader — the
    telemetry plane, a dashboard poller — gets to it.  Malformed lines
    are therefore *skipped with a warning* rather than raised on;
    ``strict=True`` restores the raising behaviour for post-hoc reads
    where corruption should be loud.
    """
    out: List[RegionRecord] = []
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(RegionRecord.from_json(line))
            except (json.JSONDecodeError, TypeError, KeyError) as e:
                if strict:
                    raise
                warnings.warn(
                    f"{path}:{lineno}: skipping unparseable JSONL line "
                    f"({type(e).__name__}: {e}); truncated live export?")
    return out


class MemoryExporter(Exporter):
    """In-memory subscriber stream.

    Keeps every emitted record in ``records`` (bounded by ``maxlen``) and
    fans each one out to subscriber callbacks as it resolves — the seam a
    live dashboard or a per-request energy attributor hangs off.

    Thread-safety contract: ``emit`` runs on whichever thread resolves a
    span (usually the session's background resolver), concurrently with
    ``subscribe``/``unsubscribe``/``records`` from e.g. a telemetry
    server thread.  Callbacks are invoked *outside* the exporter lock
    (a blocking callback can therefore stall record delivery but never
    deadlock the exporter), against a snapshot of the subscriber list —
    a subscriber removed mid-emit may see one final record.  A callback
    that raises is warned about and dropped instead of killing the
    resolver thread.
    """

    def __init__(self, maxlen: Optional[int] = None):
        self._lock = threading.Lock()
        self._records: List[RegionRecord] = []
        self._maxlen = maxlen
        self._subs: List[Callable[[RegionRecord], None]] = []

    def subscribe(self, fn: Callable[[RegionRecord], None]) -> Callable[[], None]:
        """Register ``fn`` for future records; returns an unsubscribe.

        ``fn`` runs on the resolving thread and must not block (see
        class docstring); if it raises it is dropped with a warning.
        """
        with self._lock:
            self._subs.append(fn)

        def unsubscribe() -> None:
            self._drop(fn)

        return unsubscribe

    def _drop(self, fn: Callable[[RegionRecord], None]) -> None:
        with self._lock:
            # identity, not equality: bound methods compare equal across
            # instances, and a subscriber may be registered twice.
            for i, sub in enumerate(self._subs):
                if sub is fn:
                    del self._subs[i]
                    break

    def emit(self, r: RegionRecord) -> None:
        with self._lock:
            self._records.append(r)
            if self._maxlen is not None and len(self._records) > self._maxlen:
                del self._records[:len(self._records) - self._maxlen]
            subs = list(self._subs)
        for fn in subs:
            try:
                fn(r)
            except Exception as e:
                # The emitting thread is usually the session's span
                # resolver — one broken dashboard callback must not take
                # the measurement plane down with it.
                self._drop(fn)
                warnings.warn(
                    f"MemoryExporter subscriber {fn!r} raised "
                    f"{type(e).__name__}: {e}; subscriber dropped")

    @property
    def records(self) -> List[RegionRecord]:
        with self._lock:
            return list(self._records)

    def total_joules(self, sensor: Optional[str] = None) -> float:
        return sum(r.joules for r in self.records
                   if sensor is None or r.sensor == sensor)
