"""Backend registry and the top-level ``pmt.create`` factory.

Mirrors PMT's extensibility claim: "it can be easily extended to support
new vendors' hardware" — a new backend is one subclass plus one
``register_backend`` call.
"""
from __future__ import annotations

from typing import Dict, List, Type

_REGISTRY: Dict[str, Type] = {}


def register_backend(name: str, cls) -> None:
    """Register a Sensor subclass under ``name`` (last write wins)."""
    _REGISTRY[name] = cls


def backend_names() -> List[str]:
    """All registered backend names (available on this host or not)."""
    _ensure_builtin()
    return sorted(_REGISTRY)


def available_backend_names() -> List[str]:
    """Backends that can actually produce readings on this host.

    A backend whose ``is_available()`` itself raises (broken sysfs tree,
    driver missing mid-probe) is treated as unavailable rather than
    letting one bad backend take down enumeration for all of them.
    """
    _ensure_builtin()
    out = []
    for n, c in _REGISTRY.items():
        try:
            if c.is_available():
                out.append(n)
        except Exception:
            continue
    return sorted(out)


def get_backend(name: str):
    _ensure_builtin()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown PMT backend {name!r}; known: {backend_names()}") from None


def create(name: str, **kwargs):
    """``pmt.create("rapl")`` — construct a sensor by backend name.

    The Python-level analogue of ``pmt::rapl::Rapl::create()``.
    """
    return get_backend(name).create(**kwargs)


def _ensure_builtin() -> None:
    # Import built-in backends lazily so registry import never touches
    # procfs/sysfs; each backend module self-registers on import.
    if "dummy" not in _REGISTRY:
        import repro.core.backends  # noqa: F401
