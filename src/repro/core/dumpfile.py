"""Dump-mode file format — writer and reader.

The paper's dump-mode "writes into a file timestamps and power
measurements to be able to examine the power consumption over time".

Format (text, one record per line, whitespace-separated):

    # pmt-dump v1 sensor=<name> kind=<kind> t0=<unix epoch seconds>
    <t_rel_seconds> <watts> <joules_cumulative>
    ...

``watts`` is the backend's instantaneous power when it has one, else the
average power since the previous record; ``joules_cumulative`` is the
sensor's unwrapped energy counter.  The reader returns the records and the
header so analyses (benchmarks/, examples/power_timeline.py) can rebuild
absolute timelines and stack multiple sensors (paper Fig. 2).
"""
from __future__ import annotations

import dataclasses
import io
import time
from typing import List, Optional, TextIO, Tuple


@dataclasses.dataclass(frozen=True)
class DumpRecord:
    t_rel_s: float
    watts: float
    joules: float


@dataclasses.dataclass(frozen=True)
class DumpHeader:
    version: int
    sensor: str
    kind: str
    t0: float


class DumpWriter:
    """Line-buffered dump writer. Thread-compatible with one writer."""

    def __init__(self, filename: str, sensor_name: str, sensor_kind: str,
                 t0: Optional[float] = None):
        self._f: TextIO = open(filename, "w", buffering=1)
        self._t0 = time.time() if t0 is None else t0
        self._f.write(f"# pmt-dump v1 sensor={sensor_name} "
                      f"kind={sensor_kind} t0={self._t0:.6f}\n")

    def write(self, t_rel_s: float, watts: float, joules: float) -> None:
        self._f.write(f"{t_rel_s:.6f} {watts:.6f} {joules:.6f}\n")

    def close(self) -> None:
        self._f.close()


def _parse_header(line: str) -> DumpHeader:
    if not line.startswith("# pmt-dump"):
        raise ValueError(f"not a pmt dump file (header: {line[:40]!r})")
    parts = line.split()  # ['#', 'pmt-dump', 'v1', 'sensor=..', ...]
    fields = dict(kv.split("=", 1) for kv in parts[3:])
    version = int(parts[2].lstrip("v"))
    return DumpHeader(version=version, sensor=fields.get("sensor", "?"),
                      kind=fields.get("kind", "?"),
                      t0=float(fields.get("t0", "0")))


def read_dump(filename: str) -> Tuple[DumpHeader, List[DumpRecord]]:
    with open(filename, "r") as f:
        return read_dump_io(f)


def read_dump_io(f: io.TextIOBase) -> Tuple[DumpHeader, List[DumpRecord]]:
    header = _parse_header(f.readline().rstrip("\n"))
    records: List[DumpRecord] = []
    for line in f:
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        t, w, j = line.split()
        records.append(DumpRecord(float(t), float(w), float(j)))
    return header, records


def total_joules(records: List[DumpRecord]) -> float:
    if len(records) < 2:
        return 0.0
    return records[-1].joules - records[0].joules


def average_watts(records: List[DumpRecord]) -> float:
    if len(records) < 2:
        return records[0].watts if records else 0.0
    dt = records[-1].t_rel_s - records[0].t_rel_s
    if dt <= 0:
        return records[0].watts
    return total_joules(records) / dt
