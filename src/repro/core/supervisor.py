"""Supervised sensor reads: retry, sanitize, circuit-break, fail over.

``RingSampler`` trusts its backend completely — before this module a
single raising read killed the sampling thread, a NaN watt poisoned the
integrated joules counter, and a RAPL-style counter reset showed up as a
huge negative energy.  :class:`SensorSupervisor` wraps a *chain* of
backends (primary first, fallbacks in preference order) and puts a
supervised read path in front of them:

* **deadline** — a read that takes longer than ``deadline_s`` (measured
  on the supervisor clock, so injected hang faults count under a fake
  clock) is treated as a failure;
* **retry** — each backend gets ``retries`` extra attempts with
  exponential backoff + deterministic jitter (injectable ``sleep_fn``,
  so tests assert the exact schedule without sleeping);
* **sanitize** — NaN/inf/negative watts are rejected; a monotonic
  joules counter that goes *backwards* is treated as a reset/wraparound
  (the regression is absorbed into a per-backend offset instead of
  emitting negative energy); a watts sample more than ``spike_sigma``
  robust deviations (MAD) from the recent median is rejected as a
  transient spike;
* **circuit breaker** — ``breaker_threshold`` consecutive failures open
  the breaker for ``breaker_cooldown_s``; while open the backend is
  skipped entirely (no slow timeouts on every tick), then a half-open
  probe either closes it or re-opens it;
* **failover** — when a backend's read fails (or its breaker is open)
  the next backend in the chain is tried; the supervisor reports
  ``DEGRADED`` while off-primary and ``FAILED`` when the whole chain is
  exhausted (the read raises ``SensorError`` — the hardened sampler
  records a coverage gap and keeps ticking).

Joules continuity: each backend's raw counter is rebased through a
per-backend offset so the *supervised* joules counter is one continuous
non-decreasing series across failovers, failbacks, and counter resets —
exactly what span resolution's interpolation assumes.

The supervisor is itself a :class:`Sensor` (it implements ``_sample()``
and inherits the locked read/integration machinery), so it drops into
``SensorPool``/``Session``/``RingSampler`` anywhere a bare backend does.
"""
from __future__ import annotations

import math
import time
from typing import Callable, List, Optional, Sequence

from repro.core.sensor import Sample, Sensor, SensorError

# Health states, in increasing severity.
OK = "ok"
DEGRADED = "degraded"
FAILED = "failed"

_BREAKER_CLOSED = "closed"
_BREAKER_OPEN = "open"
_BREAKER_HALF_OPEN = "half_open"


class _RejectedSample(SensorError):
    """A read that *returned* but failed sanitization (NaN/negative
    watts, spike, non-finite joules).  Distinguished from transport
    failures so the retry loop re-reads immediately — backoff exists to
    let a struggling device recover, not to penalize bad data."""


class _Backend:
    """Per-backend supervision state (breaker + joules rebase)."""

    __slots__ = ("sensor", "breaker", "consecutive_failures", "opened_at",
                 "joules_offset", "last_raw_joules", "failures", "reads",
                 "counter_resets")

    def __init__(self, sensor: Sensor):
        self.sensor = sensor
        self.breaker = _BREAKER_CLOSED
        self.consecutive_failures = 0
        self.opened_at = 0.0
        # supervised_joules = raw_joules + joules_offset; rebased on
        # first use, on failback, and on counter regression.
        self.joules_offset: Optional[float] = None
        self.last_raw_joules: Optional[float] = None
        self.failures = 0
        self.reads = 0
        self.counter_resets = 0


class SensorSupervisor(Sensor):
    """Supervised, fail-over read path over a chain of backends.

    Args:
      backends: primary first, then fallbacks in preference order.
      deadline_s: per-read wall deadline on the supervisor clock
        (None = no deadline).
      retries: extra attempts per backend per supervised read.
      backoff_s: initial retry backoff; doubles per retry.
      backoff_jitter: deterministic jitter fraction folded into each
        backoff interval (keyed off the retry counter, not RNG state).
      breaker_threshold: consecutive failures that open the breaker.
      breaker_cooldown_s: open duration before a half-open probe.
      spike_sigma: reject watts further than this many robust sigmas
        (1.4826 * MAD) from the recent median (None disables the gate).
      clock/sleep_fn: injectable for deterministic tests.
    """

    def __init__(self, backends: Sequence[Sensor],
                 deadline_s: Optional[float] = None,
                 retries: int = 1,
                 backoff_s: float = 0.01,
                 backoff_jitter: float = 0.1,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 1.0,
                 spike_sigma: Optional[float] = 8.0,
                 spike_window: int = 32,
                 clock: Optional[Callable[[], float]] = None,
                 sleep_fn: Optional[Callable[[float], None]] = None,
                 on_transition: Optional[Callable[[str, str, str],
                                                  None]] = None):
        backends = list(backends)
        if not backends:
            raise ValueError("SensorSupervisor needs at least one backend")
        if retries < 0:
            raise ValueError(f"retries must be >= 0, got {retries}")
        if breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1")
        primary = backends[0]
        super().__init__(clock=clock or primary._clock)
        # Present as the primary to the registry/session layer.
        self.name = primary.name
        self.kind = primary.kind
        self.native_period_s = primary.native_period_s
        self._chain = [_Backend(b) for b in backends]
        self._deadline_s = deadline_s
        self._retries = int(retries)
        self._backoff_s = float(backoff_s)
        self._backoff_jitter = float(backoff_jitter)
        self._breaker_threshold = int(breaker_threshold)
        self._breaker_cooldown_s = float(breaker_cooldown_s)
        self._spike_sigma = spike_sigma
        self._spike_window = int(spike_window)
        self._sleep = sleep_fn or time.sleep
        self._on_transition = on_transition
        self._state = OK
        self._active_index = 0          # backend that served the last read
        self._sup_joules: Optional[float] = None   # last supervised joules
        self._recent_watts: List[float] = []
        self._spike_lo = float("-inf")  # cached accept band
        self._spike_hi = float("inf")
        self._spike_dirty = True
        self._watts_seen = 0            # accepted watts (recompute cadence)
        self._spike_consec = 0          # consecutive out-of-band samples
        self._retry_seq = 0             # deterministic jitter source
        self._counters = {"reads": 0, "failures": 0, "retries": 0,
                          "timeouts": 0, "failovers": 0, "failbacks": 0,
                          "counter_resets": 0, "spikes_rejected": 0,
                          "samples_rejected": 0, "breaker_opens": 0}

    # -- health ------------------------------------------------------------
    @property
    def state(self) -> str:
        return self._state

    def health(self) -> dict:
        """Snapshot of supervisor + per-backend health for telemetry."""
        return {
            "state": self._state,
            "active_backend": self._chain[self._active_index].sensor.name,
            "active_index": self._active_index,
            "counters": dict(self._counters),
            "backends": [
                {"name": be.sensor.name,
                 "breaker": be.breaker,
                 "consecutive_failures": be.consecutive_failures,
                 "reads": be.reads,
                 "failures": be.failures,
                 "counter_resets": be.counter_resets}
                for be in self._chain],
        }

    def _set_state(self, new_state: str, detail: str = "") -> None:
        if new_state == self._state:
            return
        old, self._state = self._state, new_state
        if self._on_transition is not None:
            try:
                self._on_transition(old, new_state, detail)
            except Exception:
                pass   # health reporting must never break the read path

    # -- sanitization ------------------------------------------------------
    def _note_watts(self, w: float) -> None:
        self._recent_watts.append(w)
        if len(self._recent_watts) > self._spike_window:
            del self._recent_watts[:len(self._recent_watts)
                                   - self._spike_window]
        # Recompute the accept band lazily every few accepts (counted,
        # not len-based — the window length pins at capacity): the gate
        # reads two cached floats on the hot path instead of a median.
        self._watts_seen += 1
        if self._spike_dirty or (self._watts_seen & 15) == 0:
            self._recompute_spike_band()

    def _recompute_spike_band(self) -> None:
        self._spike_dirty = False
        if self._spike_sigma is None or len(self._recent_watts) < 8:
            self._spike_lo, self._spike_hi = float("-inf"), float("inf")
            return
        xs = sorted(self._recent_watts)
        n = len(xs)
        med = xs[n // 2] if n & 1 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])
        devs = sorted(abs(x - med) for x in xs)
        mad = devs[n // 2] if n & 1 else 0.5 * (devs[n // 2 - 1]
                                                + devs[n // 2])
        # Floor the robust sigma so a perfectly flat idle trace doesn't
        # reject the first genuine load step as a "spike".
        sigma = max(1.4826 * mad, 0.05 * abs(med), 1e-3)
        half = self._spike_sigma * sigma
        self._spike_lo, self._spike_hi = med - half, med + half

    def _sanitize(self, be: _Backend, s: Sample) -> Sample:
        """Validate one raw sample; raises SensorError on rejection.
        Returns the sample with joules rebased into the supervised
        continuous counter."""
        w = s.watts
        if w is not None:
            if not math.isfinite(w) or w < 0.0:
                self._counters["samples_rejected"] += 1
                raise _RejectedSample(
                    f"backend {be.sensor.name!r} reported invalid watts "
                    f"{w!r}")
            if not (self._spike_lo <= w <= self._spike_hi):
                # A transient outlier is a spike; a *sustained*
                # out-of-band level is a genuine step change (load
                # ramp, frequency shift) — after two consecutive
                # rejections accept it and rebuild the band around the
                # new level instead of rejecting the signal forever.
                self._spike_consec += 1
                if self._spike_consec <= 2:
                    self._counters["spikes_rejected"] += 1
                    self._counters["samples_rejected"] += 1
                    raise _RejectedSample(
                        f"backend {be.sensor.name!r} watts {w:.3f} "
                        f"outside robust band [{self._spike_lo:.3f}, "
                        f"{self._spike_hi:.3f}] (spike)")
                self._spike_consec = 0
                self._recent_watts.clear()
                self._spike_dirty = True
            else:
                self._spike_consec = 0
            self._note_watts(w)

        raw_j = s.joules
        if raw_j is None:
            return s
        if not math.isfinite(raw_j):
            self._counters["samples_rejected"] += 1
            raise _RejectedSample(
                f"backend {be.sensor.name!r} reported invalid joules "
                f"{raw_j!r}")
        # Rebase the raw counter into the continuous supervised series.
        if be.joules_offset is None:
            # First read from this backend (or after failover away and
            # back): continue from wherever the supervised counter is.
            base = self._sup_joules if self._sup_joules is not None \
                else raw_j
            be.joules_offset = base - raw_j
        elif be.last_raw_joules is not None and raw_j < be.last_raw_joules:
            # Counter went backwards: reset/wraparound.  Treat the new
            # raw value as energy accumulated *since* the reset.
            be.counter_resets += 1
            self._counters["counter_resets"] += 1
            base = self._sup_joules if self._sup_joules is not None \
                else 0.0
            be.joules_offset = base - min(raw_j, 0.0)
            # max(raw, 0): a reset to a negative counter still must not
            # roll the supervised series backwards.
        be.last_raw_joules = raw_j
        sup_j = raw_j + be.joules_offset
        if self._sup_joules is not None and sup_j < self._sup_joules:
            # Belt and braces: never publish a regression.
            be.joules_offset += self._sup_joules - sup_j
            sup_j = self._sup_joules
        self._sup_joules = sup_j
        return Sample(joules=sup_j, watts=w, rails=s.rails)

    # -- breaker -----------------------------------------------------------
    def _breaker_allows(self, be: _Backend, now: float) -> bool:
        if be.breaker == _BREAKER_CLOSED:
            return True
        if be.breaker == _BREAKER_OPEN:
            if now - be.opened_at >= self._breaker_cooldown_s:
                be.breaker = _BREAKER_HALF_OPEN
                return True          # one probe allowed
            return False
        return True                  # half-open: probe in flight

    def _record_failure(self, be: _Backend, now: float) -> None:
        be.failures += 1
        be.consecutive_failures += 1
        self._counters["failures"] += 1
        if be.breaker == _BREAKER_HALF_OPEN or \
                be.consecutive_failures >= self._breaker_threshold:
            if be.breaker != _BREAKER_OPEN:
                self._counters["breaker_opens"] += 1
            be.breaker = _BREAKER_OPEN
            be.opened_at = now

    def _record_success(self, be: _Backend) -> None:
        be.reads += 1
        be.consecutive_failures = 0
        be.breaker = _BREAKER_CLOSED

    def _backoff(self, attempt: int) -> float:
        """Deterministic backoff for retry ``attempt`` (0-based)."""
        base = self._backoff_s * (2.0 ** attempt)
        self._retry_seq += 1
        # Deterministic "jitter": a fixed multiplicative pattern keyed
        # off the global retry counter — reproducible in tests, still
        # decorrelates synchronized retry storms across supervisors.
        frac = ((self._retry_seq * 2654435761) & 0xFF) / 255.0
        return base * (1.0 + self._backoff_jitter * frac)

    # -- the supervised read ----------------------------------------------
    def _read_backend(self, be: _Backend) -> Sample:
        """One attempt against one backend, with deadline enforcement."""
        t0 = self._clock()
        s = be.sensor._sample()
        if self._deadline_s is not None \
                and self._clock() - t0 > self._deadline_s:
            self._counters["timeouts"] += 1
            raise SensorError(
                f"backend {be.sensor.name!r} read exceeded deadline "
                f"{self._deadline_s}s")
        return s

    def _sample(self) -> Sample:
        self._counters["reads"] += 1
        be = self._chain[0]
        # Fast path — healthy primary, breaker closed, no deadline: the
        # steady-state supervised read is one backend call plus
        # sanitize, with no clock reads and no retry scaffolding (the
        # <= 1.1x read-overhead budget lives or dies here).
        if self._active_index == 0 and be.breaker == _BREAKER_CLOSED \
                and self._deadline_s is None:
            try:
                s = be.sensor._sample()
                w = s.watts
                # Inlined accept for the dominant shape — a finite,
                # non-negative, in-band watts-only sample.  (NaN fails
                # every comparison; inf fails the w - w == 0.0 check;
                # anything else falls through to the full sanitizer.)
                if w is not None and s.joules is None and w >= 0.0 \
                        and w - w == 0.0 \
                        and self._spike_lo <= w <= self._spike_hi:
                    self._spike_consec = 0
                    rw = self._recent_watts
                    rw.append(w)
                    if len(rw) > self._spike_window:
                        del rw[0]
                    self._watts_seen += 1
                    if (self._watts_seen & 15) == 0 or self._spike_dirty:
                        self._recompute_spike_band()
                else:
                    s = self._sanitize(be, s)
            except Exception as e:     # noqa: BLE001 — any read fault
                self._record_failure(be, self._clock())
                return self._sample_slow(skip=1, last_err=e)
            be.reads += 1
            be.consecutive_failures = 0
            if self._state != OK:
                self._set_state(OK,
                                detail=f"serving from {be.sensor.name!r}")
            return s
        return self._sample_slow()

    def _sample_slow(self, skip: int = 0,
                     last_err: Optional[Exception] = None) -> Sample:
        """Full supervised read: retry with backoff, fail over down the
        chain.  ``skip`` attempts against the primary were already
        consumed (and recorded as failures) by the fast path."""
        for i, be in enumerate(self._chain):
            if not self._breaker_allows(be, self._clock()):
                continue
            for attempt in range(skip if i == 0 else 0,
                                 self._retries + 1):
                if attempt:
                    self._counters["retries"] += 1
                    if not isinstance(last_err, _RejectedSample):
                        self._sleep(self._backoff(attempt - 1))
                try:
                    s = self._sanitize(be, self._read_backend(be))
                except Exception as e:     # noqa: BLE001 — any read fault
                    last_err = e
                    self._record_failure(be, self._clock())
                    if be.breaker == _BREAKER_OPEN:
                        break              # stop retrying an open breaker
                else:
                    self._record_success(be)
                    if i != self._active_index:
                        if i > self._active_index:
                            self._counters["failovers"] += 1
                        else:
                            self._counters["failbacks"] += 1
                        # The backend we're leaving must rebase when it
                        # next serves (its raw counter kept advancing).
                        self._chain[self._active_index].joules_offset = None
                        self._chain[self._active_index].last_raw_joules = \
                            None
                        self._active_index = i
                    self._set_state(
                        OK if i == 0 else DEGRADED,
                        detail=f"serving from {be.sensor.name!r}")
                    return s
        self._set_state(FAILED, detail=str(last_err))
        raise SensorError(
            f"all {len(self._chain)} backend(s) failed; last error: "
            f"{last_err}")

    def __repr__(self):
        names = ">".join(be.sensor.name for be in self._chain)
        return (f"<SensorSupervisor chain={names!r} state={self._state!r} "
                f"active={self._chain[self._active_index].sensor.name!r}>")
