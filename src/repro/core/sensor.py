"""PMT ``Sensor`` abstract base class.

Mirrors the C++ PMT API:

    std::unique_ptr<pmt::pmt> sensor(pmt::nvml::NVML::create());
    pmt::State start = sensor->read();
    ...
    sensor->joules(start, end); sensor->watts(start, end); sensor->seconds(...)

plus the dump-mode entry points ``start_dump_thread`` / ``stop_dump_thread``.

Backend authors implement ``_sample()`` returning a :class:`Sample`; the
base class turns samples into ``State``s, integrating instantaneous power
into a cumulative joules counter when the backend has no native energy
counter.  This mirrors how PMT's core background thread accumulates for
power-only backends like NVML.
"""
from __future__ import annotations

import abc
import dataclasses
import math
import threading
import time
from typing import Callable, Dict, Optional

from repro.core import state as state_mod
from repro.core.state import State


@dataclasses.dataclass(frozen=True)
class Sample:
    """Raw backend sample. At least one of ``joules``/``watts`` is set.

    Attributes:
      joules: cumulative energy counter (already unwrapped), if the
        backend is an energy counter (RAPL-like).
      watts: instantaneous power, if the backend is a power meter
        (NVML-like).
      rails: per-rail cumulative joules.
    """

    joules: Optional[float] = None
    watts: Optional[float] = None
    rails: Dict[str, float] = dataclasses.field(default_factory=dict)


class SensorError(RuntimeError):
    """Raised when a backend is unavailable or misbehaves."""


class Sensor(abc.ABC):
    """Abstract power sensor with PMT semantics.

    Class attributes (overridden per backend):
      name: registry name ("rapl", "nvml", "tpu", ...).
      kind: "measured" for physical counters, "modeled" for analytical
        models, "hybrid" for measured-activity x modeled-coefficients.
      native_period_s: fastest sampling period the backend sustains
        (paper: ~10 ms for NVML, ~500 ms for RAPL).
    """

    name: str = "abstract"
    kind: str = "measured"
    native_period_s: float = 0.010

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        # ``clock`` is injectable for deterministic tests; defaults to a
        # monotonic clock so intervals are immune to wall-clock jumps.
        self._clock: Callable[[], float] = clock or time.monotonic
        self._lock = threading.Lock()
        self._accum_joules = 0.0
        self._last_t: Optional[float] = None
        self._last_w: Optional[float] = None
        self._dump_thread = None  # type: Optional[object]

    # -- constructor mirroring pmt::<backend>::create() -----------------
    @classmethod
    def create(cls, **kwargs) -> "Sensor":
        return cls(**kwargs)

    @classmethod
    def is_available(cls) -> bool:
        """Whether this backend can produce readings on this host."""
        return True

    # -- backend hook ----------------------------------------------------
    @abc.abstractmethod
    def _sample(self) -> Sample:
        """Read the backend once. Must be cheap and thread-safe."""

    # -- public PMT API ---------------------------------------------------
    def now(self) -> float:
        """Current time on this sensor's clock (the ``State`` timebase).

        Session regions timestamp their spans with this so they resolve
        against ring-buffer samples taken by the same clock — including
        injected virtual clocks in tests.
        """
        return self._clock()

    def _read_locked(self):
        """One sample under ``self._lock``: ``(timestamp, joules, Sample)``.

        Shared between :meth:`read` (State-building public API) and
        :meth:`read_raw` (the array-ring sampler's allocation-light path).
        """
        t = self._clock()
        s = self._sample()
        if s.joules is not None:
            jl = s.joules
            self._last_t = t
            self._last_w = s.watts
        else:
            if s.watts is None:
                raise SensorError(
                    f"backend {self.name!r} returned neither joules nor watts")
            if not math.isfinite(s.watts) or s.watts < 0.0:
                # A NaN/inf/negative instantaneous watt would poison the
                # cumulative counter forever: drop the interval (no
                # accumulation across it) and carry the last good watts
                # forward so the *next* good interval integrates sanely.
                self._last_t = t
                return t, self._accum_joules, s
            if self._last_t is not None:
                dt = max(0.0, t - self._last_t)
                w_prev = self._last_w if self._last_w is not None else s.watts
                self._accum_joules += 0.5 * (w_prev + s.watts) * dt
            jl = self._accum_joules
            self._last_t = t
            self._last_w = s.watts
        return t, jl, s

    def read(self) -> State:
        """Take one reading, returning a :class:`State`.

        For power-only backends, integrates power trapezoidally between
        consecutive reads into the cumulative joules counter.
        """
        with self._lock:
            t, jl, s = self._read_locked()
            return State(timestamp_s=t, joules=jl, watts=s.watts,
                         rails=dict(s.rails))

    def read_raw(self):
        """Take one reading as bare floats: ``(timestamp_s, joules, watts)``.

        ``watts`` is NaN when the backend reports no instantaneous power.
        This is the sampling hot path used by the array ring sampler: no
        :class:`State` (or any other object meant to outlive the call) is
        constructed, so a steady-state sampler tick retains zero Python
        allocations.  Per-rail readings are not carried — rails stay a
        ``read()``/dump-mode concern.
        """
        with self._lock:
            t, jl, s = self._read_locked()
            return t, jl, (float("nan") if s.watts is None else s.watts)

    # Derivations — instance methods per the C++ API, also importable as
    # free functions from repro.core.state.
    @staticmethod
    def joules(start: State, end: State) -> float:
        return state_mod.joules(start, end)

    @staticmethod
    def watts(start: State, end: State) -> float:
        return state_mod.watts(start, end)

    @staticmethod
    def seconds(start: State, end: State) -> float:
        return state_mod.seconds(start, end)

    # -- dump-mode (paper mode 1) ------------------------------------------
    def start_dump_thread(self, filename: str,
                          period_s: Optional[float] = None) -> None:
        """Start the background dump thread writing to ``filename``.

        Mirrors PMT's ``startDumpThread``. The sampling period defaults to
        the backend's native period.
        """
        # Imported here to avoid a cycle (sampler imports Sensor for typing).
        from repro.core.sampler import DumpThread

        if self._dump_thread is not None:
            raise SensorError("dump thread already running")
        self._dump_thread = DumpThread(
            self, filename, period_s=period_s or self.native_period_s)
        self._dump_thread.start()

    def stop_dump_thread(self) -> None:
        """Stop the background dump thread (no-op if not running)."""
        if self._dump_thread is not None:
            self._dump_thread.stop()
            self._dump_thread = None

    # Pythonic context-manager sugar over dump mode.
    def dumping(self, filename: str, period_s: Optional[float] = None):
        sensor = self

        class _Ctx:
            def __enter__(self_inner):
                sensor.start_dump_thread(filename, period_s)
                return sensor

            def __exit__(self_inner, *exc):
                sensor.stop_dump_thread()
                return False

        return _Ctx()

    def __repr__(self):
        return f"<{type(self).__name__} name={self.name!r} kind={self.kind!r}>"
