"""Asynchronous, vectorized span resolution for ``pmt.Session``.

Region ``__exit__`` is O(1): it records ``(t0, t1, path, flops, ...)``
into a bounded queue and returns.  This module is everything that
happens afterwards, off the caller's hot path:

  * :func:`batch_joules_at` — the vectorized twin of the scalar
    ``_joules_at`` interpolation: one ``np.searchsorted`` over *all* span
    endpoints at once, then a fused linear interpolation of the
    cumulative-joules counter.  Bit-identical arithmetic to the scalar
    path (same clamping, same duplicate-timestamp collapse to the later
    sample), so the two agree to better than 1e-9 — see
    tests/test_array_core.py.
  * :func:`resolve_spans` — batch-resolves many closed spans per backend
    against one seqlock copy of the ring and builds
    ``Measurement``/``RegionRecord`` objects under the session's resolve
    lock; exporter fan-out and per-span completion callbacks are queued
    and run FIFO after the lock is released, so exporters see records
    exactly once and in close order while callbacks remain free to call
    back into the session.
  * :class:`SpanResolver` — the background thread draining the session's
    span queue.  It only resolves spans the ring already covers
    (``sampler.last_ts() >= t1``); spans ahead of the timeline wait for
    the background sampler to pass them instead of forcing an extra
    sensor read, so async resolution never perturbs the measured
    workload.  ``Session.flush()`` / a blocking ``measurements`` access
    force coverage with at most one ``sample_now`` per backend.

When does a result become available?  A span resolves when (a) the
background sampler's timeline covers its ``t1`` and the resolver thread
gets to it (typically within one sampling period), or (b) someone asks —
``handle.measurements``, ``session.flush()``, or ``session.close()`` —
which resolves it synchronously on the asking thread.  Serve/train loops
that only export therefore never wait.
"""
from __future__ import annotations

import threading
import warnings
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence

import numpy as np

from repro.core.export import RegionRecord
from repro.core.sampler import SamplerCoverageGap, SamplerWindowEvicted
from repro.core.sensor import SensorError
from repro.core.state import State

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.session import Session, _Span


def batch_joules_at(ts: np.ndarray, joules: np.ndarray,
                    t: np.ndarray) -> np.ndarray:
    """Cumulative joules at each time in ``t``, linearly interpolated.

    Vectorized mirror of the scalar ``session._joules_at``: clamps
    outside the sampled range, and collapses duplicate timestamps
    (virtual clocks) to the later sample via ``side="right"`` search.
    ``ts`` must be non-decreasing; ``t`` may be in any order.
    """
    n = ts.shape[0]
    if n == 0:
        raise SensorError("ring buffer empty; sampler not started?")
    t = np.asarray(t, dtype=np.float64)
    i = np.searchsorted(ts, t, side="right")
    ii = np.clip(i, 1, n - 1) if n > 1 else np.ones_like(i)
    lo_t = ts[ii - 1]
    lo_j = joules[ii - 1]
    hi_t = ts[np.minimum(ii, n - 1)]
    hi_j = joules[np.minimum(ii, n - 1)]
    dt = hi_t - lo_t
    safe_dt = np.where(dt > 0.0, dt, 1.0)
    # dt <= 0 (duplicate timestamps) -> frac 1.0 -> the later sample,
    # matching the scalar path's "hi.joules" branch.
    frac = np.where(dt > 0.0, (t - lo_t) / safe_dt, 1.0)
    out = lo_j + frac * (hi_j - lo_j)
    out = np.where(i <= 0, joules[0], out)
    out = np.where(i >= n, joules[-1], out)
    return out


def _interp_scalar(ts: np.ndarray, js: np.ndarray, t: float) -> float:
    """Scalar twin of :func:`batch_joules_at` on array storage (same
    clamping and duplicate-timestamp behaviour, same arithmetic —
    float64 -> Python float is exact, so the IEEE ops are identical).
    Extracting the four bracket values via ``.item()`` and doing the
    lerp in Python floats skips ~1 us of NumPy scalar dispatch per op.
    """
    n = ts.shape[0]
    i = int(ts.searchsorted(t, side="right"))
    if i <= 0:
        return js.item(0)
    if i >= n:
        return js.item(n - 1)
    lo_t = ts.item(i - 1)
    dt = ts.item(i) - lo_t
    if dt <= 0.0:
        return js.item(i)
    lo_j = js.item(i - 1)
    return lo_j + (t - lo_t) / dt * (js.item(i) - lo_j)


def _resolve_key_scalar(session: "Session", key, lease, sampler, todo,
                        idxs, per_span_parts, force: bool) -> None:
    """Scalar per-span resolution for the legacy list core (A/B only)."""
    from repro.core.session import _joules_at

    for i in idxs:
        span = todo[i]
        t0, t1 = span.t0[key], span.t1[key]
        samples, ts = sampler.window(t0, t1)
        close_failed = False
        if not samples or ts[-1] < t1:
            if not force:
                continue
            try:
                sampler.sample_now()
            except Exception:   # noqa: BLE001 — resolve from what we have
                close_failed = True
            samples, ts = sampler.window(t0, t1)
        if not samples:
            span.error = SensorError(
                "ring buffer empty; sampler not started?")
            continue
        j0 = _joules_at(samples, ts, t0)
        j1 = _joules_at(samples, ts, t1)
        degraded = sampler.gap_overlaps(t0, t1) \
            or (close_failed and ts[-1] < t1)
        per_span_parts[i][key] = (lease, t0, t1, j0, j1,
                                  bool(ts[0] > t0), degraded)


def _covered(session: "Session", span: "_Span") -> bool:
    """Whether every backend's ring already reaches the span's t1."""
    for key, t1 in span.t1.items():
        lease = session._lease_by_key(key)
        if lease is None:
            continue
        sampler = lease.sampler
        if sampler is None or sampler.last_ts() < t1:
            return False
    return True


def resolve_spans(session: "Session", spans: Sequence["_Span"],
                  force: bool = True) -> None:
    """Resolve ``spans`` in place (caller holds ``session._resolve_lock``).

    Groups spans per backend and resolves each group in one vectorized
    pass: a single seqlock copy of the bracketing window, one
    ``np.searchsorted`` over every endpoint, one fused interpolation.
    ``force=True`` takes at most one closing ``sample_now`` per backend
    when the ring does not cover the latest endpoint yet.  Exporter
    records and ``on_resolved`` callbacks are *queued* on the session —
    the caller must invoke ``session._drain_emissions()`` after
    releasing the resolve lock (exactly-once and close-order are
    guaranteed by the claim under the lock plus the FIFO emit queue).

    Skips spans that are already resolved (idempotent); spans whose
    sampler is gone get a pending :class:`~repro.core.sensor.SensorError`
    raised on access and counted in session stats.
    """
    from repro.core.decorators import Measurement, Measurements

    todo = [s for s in spans if s.resolved is None and s.error is None]
    if not todo:
        return

    # Group span indices by pool key so each backend is copied once.
    by_key: Dict[object, List[int]] = {}
    for idx, span in enumerate(todo):
        for key in span.t1:
            by_key.setdefault(key, []).append(idx)

    # Per-span accumulators, keyed in lease-attach order at build time.
    per_span_parts: List[Dict[object, tuple]] = [dict() for _ in todo]

    for key, idxs in by_key.items():
        lease = session._lease_by_key(key)
        sampler = lease.sampler if lease is not None else None
        if sampler is None:
            for i in idxs:
                todo[i].error = SensorError(
                    f"sampler for span {todo[i].path!r} already stopped")
            continue
        if not getattr(sampler, "VECTORIZED", False):
            # PMT_LEGACY_RING=1 A/B path: the previous revision's scalar
            # per-span resolution (bisect + lerp, one closing sample per
            # uncovered span) — kept bit-identical for benchmarking.
            _resolve_key_scalar(session, key, lease, sampler, todo, idxs,
                                per_span_parts, force)
            continue
        t0_list = [todo[i].t0[key] for i in idxs]
        t1_list = [todo[i].t1[key] for i in idxs]
        t_max = max(t1_list)
        close_failed = False
        if sampler.last_ts() < t_max:
            if not force:
                continue
            # The closing sample can fail mid-blackout; resolve from
            # whatever the ring holds (clamped at the last good sample)
            # and mark the affected spans degraded instead of raising
            # out of flush()/close().
            try:
                sampler.sample_now()
            except Exception:   # noqa: BLE001 — resolve from what we have
                close_failed = True
        ts, js, window_evicted = sampler.window_arrays(min(t0_list), t_max)
        if ts.size == 0:
            for i in idxs:
                todo[i].error = SensorError(
                    "ring buffer empty; sampler not started?")
            continue
        if len(idxs) == 1:
            # Single span: scalar searchsorted (same arithmetic as the
            # batch path) skips the fixed cost of ~10 array ops.
            j0 = (_interp_scalar(ts, js, t0_list[0]),)
            j1 = (_interp_scalar(ts, js, t1_list[0]),)
        else:
            j0 = batch_joules_at(ts, js, np.array(t0_list))
            j1 = batch_joules_at(ts, js, np.array(t1_list))
        oldest = float(ts[0])
        newest = float(ts[-1])
        for pos, i in enumerate(idxs):
            span = todo[i]
            evicted = window_evicted and t0_list[pos] < oldest
            pin = span.pins.get(key)
            if pin is not None and pin[0].pin_evicted(pin[1]):
                evicted = True
            degraded = sampler.gap_overlaps(t0_list[pos], t1_list[pos]) \
                or (close_failed and newest < t1_list[pos])
            per_span_parts[i][key] = (
                lease, t0_list[pos], t1_list[pos],
                float(j0[pos]), float(j1[pos]), bool(evicted),
                bool(degraded))

    for i, span in enumerate(todo):
        if span.error is not None:
            session._note_span_error(span)
            continue
        if len(per_span_parts[i]) < len(span.t1):
            continue             # deferred (force=False, ring not caught up)
        out = Measurements()
        records: List[RegionRecord] = []
        # Iterate in span-key order (== attach order at open time).
        for key in span.t1:
            part = per_span_parts[i].get(key)
            if part is None:
                continue
            lease, t0, t1, j0v, j1v, evicted, degraded = part
            joules = max(0.0, j1v - j0v)
            secs = t1 - t0
            watts = joules / secs if secs > 0 else 0.0
            name = lease.sensor.name
            # States synthesized at the span endpoints, so downstream
            # code written against read()-pair results keeps working.
            out.append(Measurement(
                sensor=name, kind=lease.sensor.kind, joules=joules,
                watts=watts, seconds=secs,
                start=State(timestamp_s=t0, joules=j0v),
                end=State(timestamp_s=t1, joules=j1v),
                label=span.path, window_evicted=evicted,
                degraded=degraded))
            records.append(RegionRecord(
                path=span.path, label=span.label, depth=span.depth,
                sensor=name, kind=lease.sensor.kind, start_s=t0, end_s=t1,
                seconds=secs, joules=joules, watts=watts,
                flops=span.flops, tokens=span.tokens,
                window_evicted=evicted, degraded=degraded))
            if evicted:
                warnings.warn(SamplerWindowEvicted(
                    f"span {span.path!r} outlived the {name!r} ring: "
                    "start bracket evicted; energy resolves from a "
                    "truncated window"))
            if degraded:
                warnings.warn(SamplerCoverageGap(
                    f"span {span.path!r} straddles a {name!r} coverage "
                    "gap (failed sensor reads); energy interpolates "
                    "across the blackout"))
        span.resolved = out
        session._note_span_resolved(
            span,
            evicted=any(r.window_evicted for r in records),
            degraded=any(r.degraded for r in records))
        # Exporter fan-out and the user callback run *after* the caller
        # releases the resolve lock (session._drain_emissions) — a
        # callback is then free to call back into the session.
        session._enqueue_emission(records, span.on_resolved, out)


class SpanResolver(threading.Thread):
    """Background thread draining a session's closed-span queue.

    Woken by the queue's empty->non-empty transition, it claims the
    queue under the session resolve lock, batch-resolves whatever the
    rings already cover, and parks the rest until the samplers catch up,
    polling every ``poll_s`` while work remains (so a burst of closes
    costs one wake + one vectorized resolve, not a wake per close).
    Spans whose clocks never advance (virtual-clock tests, stopped
    workloads) simply wait for a forcing call — ``flush()``,
    ``close()``, or a blocking ``measurements`` access.
    """

    def __init__(self, session: "Session", poll_s: float = 0.02):
        super().__init__(daemon=True,
                         name=f"pmt-resolver-{id(session):x}")
        self._session = session
        self._poll_s = poll_s
        self.wake = threading.Event()
        self._stop_evt = threading.Event()

    def stop(self, join: bool = True, timeout: float = 5.0) -> None:
        self._stop_evt.set()
        self.wake.set()
        if join and self.is_alive():
            self.join(timeout=timeout)

    def run(self) -> None:
        session = self._session
        while True:
            try:
                claimed, deferred = session._drain_ready(force=False)
            except Exception as exc:  # pragma: no cover - backend broke
                # Keep the thread alive: spans still resolve via the
                # forcing paths, and a transient sensor error must not
                # silently kill async resolution for the whole session.
                warnings.warn(f"pmt resolver: background resolve failed "
                              f"({exc!r}); retrying")
                claimed, deferred = 0, 1
            if self._stop_evt.is_set():
                return
            if claimed or deferred:
                # Busy: plain timed sleep.  Waking per close would tax
                # the measured workload with GIL/lock churn — sleeping a
                # poll interval instead batches the next burst of spans
                # into one vectorized resolve.
                self._stop_evt.wait(self._poll_s)
            else:
                # Idle: sleep until the first span of the next burst
                # (region close signals the queue's empty->non-empty
                # transition).
                self.wake.wait()
                self.wake.clear()
