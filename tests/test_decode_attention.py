"""Flash-decode kernel family: parity gates for the serve hot path.

Three layers of gates, tightest first:

  * kernel-level: the Pallas kernel (interpret mode) must match the
    blockwise ``ref.py`` oracle *bit-exactly* — the kernel only adds
    block skipping, which is a bit-neutral update (see ref.py), so any
    fp difference is a real bug, not tolerance noise.  The bucketed
    lax fallback computes each prefix in one fused pass instead of
    blockwise, so it matches within ~1 ulp of fp32 softmax
    reassociation, and must be invariant to scalar-vs-vector
    ``cur_len`` bit-exactly.
  * model-level: ``decode_attn_impl="flash"`` decode logits must match
    the dense path within fp-reassociation tolerance across the cache
    families (GQA, sliding-window ring, MLA latent), for scalar and
    per-row vector ``cur_len``.
  * engine-level: a continuous-batching ``ServeEngine`` with the knob
    flipped must produce byte-identical generated tokens.

Plus the satellite guard: ``attention(impl="pallas")`` refuses args the
flash kernel silently dropped before (kv_valid, cross-attention).
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro import configs
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_lax,
                                            decode_attention_pallas,
                                            decode_attention_ref)
from repro.models import model as M


def rng(i):
    return jax.random.PRNGKey(i)


def make_qkv(key, b, kvh, g, hdq, hdv, c, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, kvh, g, hdq)).astype(dtype)
    k = jax.random.normal(k2, (b, c, kvh, hdq)).astype(dtype)
    v = jax.random.normal(k3, (b, c, kvh, hdv)).astype(dtype)
    return q, k, v


# -- kernel-level: bit-exact vs the blockwise oracle ---------------------------

@pytest.mark.parametrize("kvh,g", [(4, 1), (2, 4), (1, 8)])  # G = 1, 4, H
@pytest.mark.parametrize("ring", [False, True])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_decode_kernel_bit_exact_vs_ref(kvh, g, ring, softcap):
    """One (B,) lens vector covers every fill class at once: empty-ish,
    mid, last-slot, and (ring wrap / clamped) beyond-capacity rows."""
    b, hdq, hdv, c, bk = 5, 32, 24, 64, 16
    q, k, v = make_qkv(rng(1), b, kvh, g, hdq, hdv, c)
    lens = jnp.array([0, 1, c // 2, c - 1, c + c // 2], jnp.int32)
    kw = dict(ring=ring, softcap=softcap, scale=1.0 / math.sqrt(hdq),
              block_k=bk)
    ref = decode_attention_ref(q, k, v, lens, **kw)
    pal = decode_attention_pallas(q, k, v, lens, interpret=True, **kw)
    lax = decode_attention_lax(q, k, v, lens, **kw)
    np.testing.assert_array_equal(np.asarray(pal), np.asarray(ref))
    assert_allclose(np.asarray(lax), np.asarray(ref), rtol=2e-6,
                    atol=2e-6)
    assert np.isfinite(np.asarray(ref)).all()


def test_decode_kernel_single_block_and_odd_sizes():
    # single-block cache (block_k >= C) and a cache size that forces
    # the gcd fallback block (40 with block_k=16 -> bk=8)
    for c, bk in [(32, 128), (40, 16)]:
        q, k, v = make_qkv(rng(2), 2, 2, 3, 16, 16, c)
        lens = jnp.array([c // 3, c - 1], jnp.int32)
        kw = dict(ring=True, softcap=None, scale=0.25, block_k=bk)
        ref = decode_attention_ref(q, k, v, lens, **kw)
        pal = decode_attention_pallas(q, k, v, lens, interpret=True, **kw)
        np.testing.assert_array_equal(np.asarray(pal), np.asarray(ref))


def test_decode_kernel_bf16():
    q, k, v = make_qkv(rng(3), 2, 2, 4, 32, 32, 64, dtype=jnp.bfloat16)
    lens = jnp.array([5, 63], jnp.int32)
    kw = dict(ring=False, softcap=None, scale=1.0 / math.sqrt(32))
    ref = decode_attention_ref(q, k, v, lens, **kw)
    pal = decode_attention_pallas(q, k, v, lens, interpret=True, **kw)
    assert pal.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(pal, np.float32),
                                  np.asarray(ref, np.float32))


def test_decode_ops_scalar_equals_vector():
    """The ops wrapper broadcasts a scalar cur_len to the (B,) vector
    path — results must be bit-identical (the continuous-batching
    invariant the engine relies on)."""
    b, h, kvh, hd, c = 3, 8, 2, 32, 64
    q = jax.random.normal(rng(4), (b, 1, h, hd), jnp.float32)
    k = jax.random.normal(rng(5), (b, c, kvh, hd), jnp.float32)
    v = jax.random.normal(rng(6), (b, c, kvh, hd), jnp.float32)
    for impl in ("lax", "pallas_interpret"):
        o_s = decode_attention(q, k, v, 17, impl=impl, scale=0.2)
        o_v = decode_attention(q, k, v, jnp.full((b,), 17, jnp.int32),
                               impl=impl, scale=0.2)
        np.testing.assert_array_equal(np.asarray(o_s), np.asarray(o_v))
        assert o_s.shape == (b, 1, h, hd)


def test_decode_ops_v_width_alias():
    """MLA passes the concatenated [latent | rope] cache as both K and
    V with v_width: must equal attending with an explicitly sliced V,
    on both dispatch paths, under jit."""
    b, h, c, r, rope = 2, 4, 64, 32, 16
    q = jax.random.normal(rng(7), (b, 1, h, r + rope), jnp.float32)
    kv = jax.random.normal(rng(8), (b, c, 1, r + rope), jnp.float32)
    lens = jnp.array([9, c - 1], jnp.int32)
    explicit = decode_attention(q, kv, kv[..., :r], lens, impl="lax",
                                scale=0.1)
    for impl in ("lax", "pallas_interpret"):
        alias = jax.jit(
            lambda q, kv, l, i=impl: decode_attention(
                q, kv, kv, l, impl=i, scale=0.1, v_width=r))(q, kv, lens)
        assert alias.shape == (b, 1, h, r)
        if impl == "lax":      # same impl -> identical ops -> bitwise
            np.testing.assert_array_equal(np.asarray(alias),
                                          np.asarray(explicit))
        else:                  # blockwise kernel vs fused pass: ~1 ulp
            assert_allclose(np.asarray(alias), np.asarray(explicit),
                            rtol=2e-6, atol=2e-6)


def test_decode_ops_validation():
    q = jnp.zeros((2, 2, 4, 8))       # Sq != 1
    k = jnp.zeros((2, 16, 2, 8))
    with pytest.raises(ValueError, match="one query token"):
        decode_attention(q, k, k, 0, impl="lax")
    with pytest.raises(ValueError, match="divisible"):
        decode_attention(jnp.zeros((2, 1, 3, 8)), k, k, 0, impl="lax")
    with pytest.raises(ValueError, match="unknown decode_attention"):
        decode_attention(jnp.zeros((2, 1, 4, 8)), k, k, 0, impl="nope")


# -- model-level: flash vs dense across cache families -------------------------

def _fp32(arch):
    cfg = dataclasses.replace(configs.get_config(arch, reduced=True),
                              dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    return cfg


# gemma2 = sliding-window ring + softcap; deepseek = MLA latent cache
@pytest.mark.parametrize("arch", ["smollm-135m", "gemma2-27b",
                                  "deepseek-v3-671b"])
def test_decode_impl_flash_matches_dense(arch):
    cfg = _fp32(arch)
    params, _ = M.init_params(rng(0), cfg)
    b, t = 2, 12
    tokens = jax.random.randint(rng(1), (b, t), 0, cfg.vocab_size)
    prefill = M.make_serve_fns(cfg).prefill
    _, caches = jax.jit(lambda p, bt: prefill(p, bt, t + 4))(
        params, {"tokens": tokens[:, :t - 1]})
    nxt = tokens[:, t - 1:t]
    logits = {}
    for impl in ("dense", "flash"):
        cfg_i = dataclasses.replace(cfg, decode_attn_impl=impl)
        decode = M.make_serve_fns(cfg_i).decode
        l_s, _ = jax.jit(decode)(params, caches, nxt,
                                 jnp.asarray(t - 1, jnp.int32))
        l_v, _ = jax.jit(decode)(params, caches, nxt,
                                 jnp.full((b,), t - 1, jnp.int32))
        # scalar and per-row vector positions stay bit-identical
        assert bool(jnp.array_equal(l_s, l_v)), impl
        logits[impl] = np.asarray(l_s)
    assert_allclose(logits["flash"], logits["dense"], rtol=2e-4, atol=2e-4)


def test_decode_impl_flash_ring_long_decode():
    """Flash decode far past the sliding window: the ring wraps, every
    step stays finite and tracks the dense path."""
    cfg = dataclasses.replace(_fp32("gemma2-27b"), decode_attn_impl="flash")
    cfg_d = dataclasses.replace(cfg, decode_attn_impl="dense")
    params, _ = M.init_params(rng(0), cfg)
    n = cfg.sliding_window * 2
    tokens = jax.random.randint(rng(2), (1, n), 0, cfg.vocab_size)
    prefill = M.make_serve_fns(cfg).prefill
    _, caches = jax.jit(lambda p, bt: prefill(p, bt, n + 8))(
        params, {"tokens": tokens[:, :8]})
    caches_d = jax.tree.map(lambda x: x, caches)
    dec_f = jax.jit(M.make_serve_fns(cfg)[1])
    dec_d = jax.jit(M.make_serve_fns(cfg_d)[1])
    for t in range(8, 8 + cfg.sliding_window + 6):
        cur = jnp.asarray(t, jnp.int32)
        lf, caches = dec_f(params, caches, tokens[:, t:t + 1], cur)
        ld, caches_d = dec_d(params, caches_d, tokens[:, t:t + 1], cur)
        assert np.isfinite(np.asarray(lf)).all()
        assert_allclose(np.asarray(lf), np.asarray(ld), rtol=2e-4,
                        atol=2e-4)


# -- engine-level: byte parity with the knob flipped ---------------------------

def test_serve_engine_byte_parity_across_decode_impls():
    from repro.serve.engine import Request, ServeEngine
    cfg = _fp32("smollm-135m")
    params, _ = M.init_params(rng(0), cfg)
    mixed = [([1, 2, 3], 8), ([4, 5], 3), ([6], 1), ([2], 12),
             ([7, 8, 9, 10, 11], 5)]
    outs = {}
    for impl in ("dense", "flash"):
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          decode_attn_impl=impl)
        assert eng.cfg.decode_attn_impl == impl
        done = eng.generate([Request(prompt=list(p), max_new_tokens=nt)
                             for p, nt in mixed])
        outs[impl] = [r.out for r in done]
        assert all(len(o) == nt for o, (_, nt) in zip(outs[impl], mixed))
    assert outs["flash"] == outs["dense"]


def test_decode_attn_impl_resolution(monkeypatch):
    from repro.models import blocks
    cfg = _fp32("smollm-135m")
    assert blocks.decode_attn_impl(
        dataclasses.replace(cfg, decode_attn_impl="flash")) == "flash"
    on_tpu = jax.default_backend() == "tpu"
    assert blocks.decode_attn_impl(cfg) == ("flash" if on_tpu else "dense")
    monkeypatch.setenv("PMT_DECODE_ATTN_IMPL", "flash")
    assert blocks.decode_attn_impl(cfg) == "flash"     # env flips "auto"
    # an explicit config value beats the env var
    assert blocks.decode_attn_impl(
        dataclasses.replace(cfg, decode_attn_impl="dense")) == "dense"
    with pytest.raises(ValueError, match="decode_attn_impl"):
        blocks.decode_attn_impl(
            dataclasses.replace(cfg, decode_attn_impl="nope"))


# -- satellite: attention(impl="pallas") refuses args it would drop ------------

def test_attention_pallas_rejects_unsupported_args():
    from repro.models import attention as A
    cfg = _fp32("smollm-135m")
    b, s, h, kvh, hd = 1, 16, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.zeros((b, s, h, hd), jnp.float32)
    k = jnp.zeros((b, s, kvh, hd), jnp.float32)
    v = jnp.zeros((b, s, kvh, hd), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    with pytest.raises(ValueError, match="kv_valid"):
        A.attention(cfg, q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                    kv_valid=jnp.ones((b, s), bool), impl="pallas")
    with pytest.raises(ValueError, match="causal"):
        A.attention(cfg, q, k, v, q_pos=pos, kv_pos=pos, causal=False,
                    impl="pallas")
    from repro.sharding.specs import split_params
    cross_p, _ = split_params(A.init_attention(rng(0), cfg, cross=True))
    with pytest.raises(ValueError, match="causal"):
        A.cross_attention(cfg, cross_p,
                          jnp.zeros((b, s, cfg.d_model), jnp.float32),
                          jnp.zeros((b, s, cfg.d_model), jnp.float32),
                          impl="pallas")
