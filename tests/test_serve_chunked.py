"""Chunked-prefill serve admission: correctness and accounting gates.

Model-level: ``ServeFns.prefill_chunk`` resumed chunk by chunk must
reproduce the whole-prompt (unpadded) prefill — same first token, same
valid cache rows up to one bf16 cache-quantization ulp — across the
cache families (GQA, sliding-window ring, MLA latent, mamba/xlstm scan
carries).

Engine-level: the chunked-interleaved engine must generate the same
tokens as the blocking-bucketed baseline whenever the two compute the
same function (prompts already bucket-sized, so blocking adds no
left-pad context), must be invariant to the chunk size, and must keep
the accounting invariants: phase spans tile request spans, prefill
compiles once at one chunk shape, spans never leak — even when a
prefill chunk raises mid-generate.

Satellites covered here: engine sampling (greedy/temperature/seed) and
``prompt_bucket`` min_bucket validation.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as pmt
from repro import configs
from repro.models import model as M
from repro.serve.engine import (Request, ServeEngine, prompt_bucket,
                                resolve_prefill_chunk)


def rng(i):
    return jax.random.PRNGKey(i)


def _fp32(arch):
    cfg = dataclasses.replace(configs.get_config(arch, reduced=True),
                              dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    return cfg


def mk(reqs):
    return [Request(prompt=list(p), max_new_tokens=n) for p, n in reqs]


def run_chunked_prefill(cfg, params, tokens, chunk, max_len):
    """Drive prefill_chunk over a (1, plen) prompt; returns
    (last logits (1, V), caches)."""
    fns = M.make_serve_fns(cfg)
    caches = M.init_caches(cfg, 1, max_len)
    plen = tokens.shape[1]
    padded = math.ceil(plen / chunk) * chunk
    toks = np.zeros((1, padded), np.int32)
    toks[0, :plen] = np.asarray(tokens)[0]
    pc = jax.jit(fns.prefill_chunk)
    logits = None
    for off in range(0, padded, chunk):
        last_idx = min(plen - 1 - off, chunk - 1)
        logits, caches = pc(params, caches,
                            jnp.asarray(toks[:, off:off + chunk]),
                            jnp.asarray(off, jnp.int32),
                            jnp.asarray(last_idx, jnp.int32))
    return logits, caches


def assert_caches_match(cfg, caches_whole, caches_chunked, plen,
                        atol=2e-2):
    """Compare cache trees on the slots whole-prompt prefill wrote.

    Chunked prefill reads the bf16-quantized prefix where whole-prompt
    prefill attends fp32 pre-cache K/V, so rows agree to one bf16 ulp
    (atol), not bitwise; slots past the prompt hold chunk padding on
    one side and init zeros on the other and are excluded (they are
    invalid under every decode path's cur_len masking)."""
    axes = M.cache_logical_axes(cfg)
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    ax_leaves = jax.tree.leaves(axes, is_leaf=is_axes)
    wl = jax.tree.leaves(caches_whole)
    cl = jax.tree.leaves(caches_chunked)
    assert len(ax_leaves) == len(wl) == len(cl)
    for ax, a, b in zip(ax_leaves, wl, cl):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        assert a.shape == b.shape
        if "kv_seq" in ax:
            s_ax = ax.index("kv_seq")
            n = min(plen, a.shape[s_ax])
            sl = [slice(None)] * a.ndim
            sl[s_ax] = slice(0, n)
            a, b = a[tuple(sl)], b[tuple(sl)]
        np.testing.assert_allclose(a, b, atol=atol, rtol=0)


# -- model-level: chunked == whole-prompt prefill ------------------------------

# gemma2 = sliding-window ring + softcap; deepseek = MLA latent cache;
# jamba = mamba scan carry (hybrid); xlstm = mLSTM/sLSTM carries
@pytest.mark.parametrize("arch", ["smollm-135m", "gemma2-27b",
                                  "deepseek-v3-671b", "jamba-v0.1-52b",
                                  "xlstm-1.3b"])
def test_chunked_prefill_matches_whole_prompt(arch):
    cfg = _fp32(arch)
    params, _ = M.init_params(rng(0), cfg)
    plen, chunk, max_len = 13, 4, 32
    tokens = jax.random.randint(rng(1), (1, plen), 0, cfg.vocab_size)
    fns = M.make_serve_fns(cfg)
    logits_w, caches_w = jax.jit(lambda p, b: fns.prefill(p, b, max_len))(
        params, {"tokens": tokens})
    logits_c, caches_c = run_chunked_prefill(cfg, params, tokens, chunk,
                                             max_len)
    # the acceptance gate: same first token, same valid cache rows
    assert int(np.argmax(logits_w)) == int(np.argmax(logits_c))
    np.testing.assert_allclose(np.asarray(logits_w), np.asarray(logits_c),
                               atol=2e-2, rtol=2e-2)
    assert_caches_match(cfg, caches_w, caches_c, plen)


def test_chunked_prefill_invariant_to_chunk_size():
    """The same prompt prefilled at chunk 3, 5, and 16 must land on the
    same first token and near-identical caches — the engine's knob is a
    scheduling choice, not a semantic one."""
    cfg = _fp32("smollm-135m")
    params, _ = M.init_params(rng(0), cfg)
    plen, max_len = 11, 32
    tokens = jax.random.randint(rng(2), (1, plen), 0, cfg.vocab_size)
    results = [run_chunked_prefill(cfg, params, tokens, ck, max_len)
               for ck in (3, 5, 16)]
    toks = {int(np.argmax(np.asarray(l))) for l, _ in results}
    assert len(toks) == 1
    for _, caches in results[1:]:
        assert_caches_match(cfg, results[0][1], caches, plen)


def test_chunked_prefill_ring_prompt_longer_than_window():
    """gemma2 local layers with a prompt well past the ring size: the
    chunked ring writes + trailing-query window masks must agree with
    the whole-prompt path."""
    cfg = _fp32("gemma2-27b")
    params, _ = M.init_params(rng(0), cfg)
    plen = cfg.sliding_window * 2 + 5
    max_len = plen + 11
    tokens = jax.random.randint(rng(3), (1, plen), 0, cfg.vocab_size)
    fns = M.make_serve_fns(cfg)
    logits_w, caches_w = jax.jit(lambda p, b: fns.prefill(p, b, max_len))(
        params, {"tokens": tokens})
    logits_c, caches_c = run_chunked_prefill(cfg, params, tokens, 8,
                                             max_len)
    assert int(np.argmax(logits_w)) == int(np.argmax(logits_c))
    assert_caches_match(cfg, caches_w, caches_c, plen)


def test_prefill_chunk_rejects_encoder_decoder():
    cfg = _fp32("whisper-tiny")
    fns = M.make_serve_fns(cfg)
    with pytest.raises(NotImplementedError, match="encoder-decoder"):
        fns.prefill_chunk(None, None, jnp.zeros((1, 4), jnp.int32), 0, 3)
    with pytest.raises(ValueError, match="encoder-decoder"):
        resolve_prefill_chunk(cfg, 8)
    assert resolve_prefill_chunk(cfg, None) == 0    # silent fallback


# -- engine-level --------------------------------------------------------------

MIXED = [([1, 2, 3], 8), ([4, 5], 3), ([6], 1),
         ([7, 8, 9, 10, 11, 12, 13, 14, 15], 5), ([2], 12),
         ([3, 1, 4, 1, 5], 2), ([9, 9], 7)]


@pytest.fixture(scope="module")
def smollm():
    cfg = _fp32("smollm-135m")
    params, _ = M.init_params(rng(0), cfg)
    return cfg, params


def test_engine_chunked_matches_blocking_on_bucket_sized_prompts(smollm):
    """For prompts already at their bucket size, blocking admission adds
    no left-pad context, so the chunked engine must generate identical
    tokens.  fp32 caches (``cache_dtype``): the reduced test model's
    top-2 logit gaps (~5e-5) sit *below* bf16 cache quantization noise,
    so bf16 would compare cache-rounding luck, not scheduler
    correctness."""
    cfg, params = smollm
    reqs = [(list(range(1, 9)), 6), (list(range(3, 19)), 4),
            ([5] * 8, 3), (list(range(2, 10)), 9)]
    outs = {}
    for chunk in (0, 4, 8):
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          prefill_chunk=chunk, cache_dtype=jnp.float32)
        outs[chunk] = [r.out for r in eng.generate(mk(reqs))]
        assert all(len(o) == n for o, (_, n) in zip(outs[chunk], reqs))
    assert outs[4] == outs[0]       # chunked == blocking baseline
    assert outs[8] == outs[4]       # and invariant to the chunk size


def test_engine_chunked_matches_single_request_runs(smollm):
    """Continuous chunked serving at B=3 == each request served alone
    (B=1), byte-identical — the PR3 slot-independence gate holds under
    interleaved chunked admission too."""
    cfg, params = smollm
    eng = ServeEngine(cfg, params, batch_size=3, max_len=64,
                      prefill_chunk=4)
    done = eng.generate(mk(MIXED))
    ref_eng = ServeEngine(cfg, params, batch_size=1, max_len=64,
                          prefill_chunk=4)
    for i, (prompt, n) in enumerate(MIXED):
        ref = ref_eng.generate(mk([(prompt, n)]))[0]
        assert done[i].out == ref.out
        assert len(done[i].out) == n


def test_engine_stall_events_recorded(smollm):
    """Chunked admission records one stall sample per fenced chunk run
    while another request is mid-decode, and each is bounded by chunk
    work (vs whole-prompt samples under blocking admission)."""
    cfg, params = smollm
    reqs = mk([(list(range(1, 17)), 6), (list(range(1, 17)), 6),
               (list(range(1, 17)), 6)])
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      prefill_chunk=4)
    eng.generate([dataclasses.replace(r) for r in reqs])
    assert len(eng.stall_events) >= 4    # 16/4 chunks for the refills
    assert all(s >= 0 for s in eng.stall_events)
    eng0 = ServeEngine(cfg, params, batch_size=2, max_len=64,
                       prefill_chunk=0)
    eng0.generate([dataclasses.replace(r) for r in reqs])
    # blocking: one (whole-prompt) stall per admission that finds the
    # batch already decoding
    assert 1 <= len(eng0.stall_events) <= 2


def test_engine_sampling_threads_keys(smollm):
    """greedy=False actually samples: same seed reproduces the exact
    token streams, different seeds diverge, and the distribution is not
    the greedy argmax stream."""
    cfg, params = smollm
    reqs = [(list(range(1, 7)), 12), ([3, 2], 10)]

    def run(**kw):
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64, **kw)
        return [r.out for r in eng.generate(mk(reqs))]

    greedy = run()
    s0a = run(greedy=False, temperature=1.5, seed=0)
    s0b = run(greedy=False, temperature=1.5, seed=0)
    s1 = run(greedy=False, temperature=1.5, seed=1)
    assert s0a == s0b               # deterministic under a fixed seed
    assert s0a != s1                # seeds decorrelate
    assert s0a != greedy            # and it is not argmax decoding
    assert all(len(o) == n for o, (_, n) in zip(s0a, reqs))
    with pytest.raises(ValueError, match="temperature"):
        ServeEngine(cfg, params, batch_size=1, max_len=32, greedy=False,
                    temperature=0.0)


def test_engine_prefill_failure_closes_all_spans(smollm):
    """A prefill chunk raising mid-generate must close every open
    serve/req span (request + phases) — Session.stats() ends with no
    pending spans and the flush sees exactly the opened set."""
    cfg, params = smollm
    with pmt.Session(["dummy"], pool=pmt.SensorPool()) as sess:
        mem = sess.add_exporter(pmt.MemoryExporter())
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          prefill_chunk=4, session=sess)
        calls = {"n": 0}
        real = eng._prefill_chunk_fn

        def boom(*args, **kw):
            calls["n"] += 1
            if calls["n"] == 4:     # mid-loop, second admission underway
                raise RuntimeError("injected prefill OOM")
            return real(*args, **kw)

        eng._prefill_chunk_fn = boom
        with pytest.raises(RuntimeError, match="injected"):
            eng.generate(mk([(list(range(1, 9)), 6),
                             (list(range(1, 13)), 6),
                             ([1, 2, 3], 4)]))
        sess.flush()
        st = sess.stats()
        assert st["pending"] == 0
        assert st["resolve_errors"] == 0
        req_paths = {r.path for r in mem.records
                     if r.path.startswith("serve/req")}
        # both admitted requests closed their request span and their
        # open phase spans (the second died mid-prefill: no decode span)
        assert "serve/req0" in req_paths and "serve/req1" in req_paths
        assert "serve/req0/prefill" in req_paths
        assert "serve/req1/prefill" in req_paths
        assert all(np.isfinite(r.joules) for r in mem.records)
    # a fresh generate on the same engine still works (no stuck state)
    eng2 = ServeEngine(cfg, params, batch_size=2, max_len=64,
                       prefill_chunk=4)
    assert [len(r.out) for r in eng2.generate(mk([([1, 2], 3)]))] == [3]


def test_engine_blocking_prefill_failure_closes_all_spans(smollm):
    """Same cleanup gate for the prefill_chunk=0 baseline: a whole-
    prompt prefill raising mid-admission must not leak the admitted
    request's open request/prefill spans."""
    cfg, params = smollm
    with pmt.Session(["dummy"], pool=pmt.SensorPool()) as sess:
        mem = sess.add_exporter(pmt.MemoryExporter())
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          prefill_chunk=0, session=sess)
        calls = {"n": 0}
        real = eng._prefill_request

        def boom(r):
            calls["n"] += 1
            if calls["n"] == 2:     # second admission, first mid-decode
                raise RuntimeError("injected prefill OOM")
            return real(r)

        eng._prefill_request = boom
        with pytest.raises(RuntimeError, match="injected"):
            eng.generate(mk([([1, 2, 3], 6), ([4, 5, 6], 4)]))
        sess.flush()
        assert sess.stats()["pending"] == 0
        req_paths = {r.path for r in mem.records
                     if r.path.startswith("serve/req")}
        assert {"serve/req0", "serve/req0/prefill", "serve/req0/decode",
                "serve/req1", "serve/req1/prefill"} <= req_paths


def test_engine_monitor_phase_split(smollm):
    """PowerMonitor path: per_request_energy carries the prefill/decode
    J split and the phases sum to the request total."""
    cfg, params = smollm
    mon = pmt.PowerMonitor(["dummy"])
    try:
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          prefill_chunk=4, monitor=mon)
        reqs = mk(MIXED[:4])
        eng.generate(reqs)
        per = mon.per_request_energy()
        assert sorted(per) == [0, 1, 2, 3]
        for i, d in per.items():
            assert d["tokens"] == MIXED[i][1]
            assert d["prefill_joules"] >= 0.0
            assert d["decode_joules"] >= 0.0
            split = d["prefill_joules"] + d["decode_joules"]
            assert split == pytest.approx(d["joules"], rel=0.05,
                                          abs=1e-3)
        # phase records carry the phase tag; whole-request spans don't
        phases = {r.phase for r in mon.request_records()}
        assert phases == {None, "prefill", "decode"}
    finally:
        mon.close()


# -- satellites ----------------------------------------------------------------

def test_prompt_bucket_min_bucket_must_be_power_of_two():
    assert prompt_bucket(3, min_bucket=2) == 4
    assert prompt_bucket(3, min_bucket=1) == 4
    for bad in (0, 3, 6, 12, -8):
        with pytest.raises(ValueError, match="power of two"):
            prompt_bucket(5, min_bucket=bad)


def test_resolve_prefill_chunk_precedence(smollm, monkeypatch):
    cfg, _ = smollm
    assert resolve_prefill_chunk(cfg, 16) == 16          # arg wins
    assert resolve_prefill_chunk(cfg, 0) == 0
    assert resolve_prefill_chunk(cfg, None) == cfg.prefill_chunk
    monkeypatch.setenv("PMT_PREFILL_CHUNK", "12")
    assert resolve_prefill_chunk(cfg, None) == 12        # env beats cfg
    assert resolve_prefill_chunk(cfg, 16) == 16          # arg beats env
    with pytest.raises(ValueError, match=">= 0"):
        resolve_prefill_chunk(cfg, -1)
