"""Quantized-KV kernel family: parity gates for int8 / fp8_e4m3 caches.

Same three-tier structure as the bf16 kernel gates, applied to the
quantized paths:

  * quantize/dequantize round-trip properties — bounded relative error,
    fp8 casts never produce NaN (the format has no inf, so out-of-range
    casts NaN unless clipped first — ``kernels/quant`` clips), zero rows
    survive the SCALE_EPS floor;
  * cache-update: the fused quantize+scatter Pallas kernels (interpret
    mode) must match the quantize-then-oracle-scatter refs bit-exactly,
    contiguous and paged;
  * attention: decode/prefill kernels dequantizing codes in-register
    inside the online-softmax loop must match their blockwise ``ref.py``
    twins bit-exactly (interpret mode) and the fused lax fallbacks to
    fp32-reassociation tolerance — across full, ring, paged, windowed,
    and MLA ``v_width``-alias (scales quantized ONCE, serving as both
    key and value scale) cache families.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import quant
from repro.kernels.cache_update.cache_update import (
    quant_cache_update_pallas, quant_paged_cache_update_pallas)
from repro.kernels.cache_update.ops import (quant_cache_update,
                                            quant_paged_cache_update)
from repro.kernels.cache_update.ref import (quant_cache_update_ref,
                                            quant_paged_cache_update_ref)
from repro.kernels.decode_attention.decode_attention import (
    decode_attention_paged_pallas, decode_attention_pallas)
from repro.kernels.decode_attention.ops import (decode_attention_lax,
                                                decode_attention_paged_lax)
from repro.kernels.decode_attention.ref import (decode_attention_paged_ref,
                                                decode_attention_ref)
from repro.kernels.prefill_attention.ops import (prefill_attention_lax,
                                                 prefill_attention_paged_lax)
from repro.kernels.prefill_attention.prefill_attention import (
    prefill_attention_paged_pallas, prefill_attention_pallas)
from repro.kernels.prefill_attention.ref import (prefill_attention_paged_ref,
                                                 prefill_attention_ref)

MODES = list(quant.QUANT_MODES)
B, C, T, KVH, G, HD = 3, 64, 8, 2, 4, 16
PS, NB, P = 8, 8, 32
RK = 24          # MLA latent+rope width (v_width=8 slice alias)


def rng(i):
    return np.random.default_rng(i)


def bitexact(a, b):
    assert np.array_equal(np.asarray(a), np.asarray(b))


def close(a, b, atol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)


# -- quantize/dequantize properties -------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_quant_roundtrip_bounded(mode):
    x = jnp.asarray(rng(0).normal(size=(B, C, KVH, HD)) * 5, jnp.float32)
    codes, scales = quant.quantize(x, mode)
    assert codes.dtype == quant.quant_dtype(mode)
    assert scales.dtype == jnp.float32 and scales.shape == x.shape[:-1]
    y = np.asarray(quant.dequantize(codes, scales))
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    # absmax scheme: error bounded by half a step of the row's range
    step = amax / (127.0 if mode == "int8" else 448.0)
    tol = 0.51 * step if mode == "int8" else 0.07 * amax + 1e-6
    assert np.all(np.abs(y - np.asarray(x)) <= tol + 1e-7)


@pytest.mark.parametrize("mode", MODES)
def test_quant_no_nan_extremes(mode):
    # fp8_e4m3 casts NaN out-of-range values (no inf encoding); the
    # quantizer must clip first.  Also: all-zero rows hit the SCALE_EPS
    # floor instead of dividing by zero.
    x = np.zeros((2, 4, HD), np.float32)
    x[0, 0] = 1e30
    x[0, 1] = -1e30
    x[1, 2] = 1e-30
    codes, scales = quant.quantize(jnp.asarray(x), mode)
    y = np.asarray(quant.dequantize(codes, scales))
    assert np.isfinite(y).all()
    assert np.all(y[1, :2] == 0.0) and np.all(y[0, 2:] == 0.0)


def test_quant_mode_validation():
    with pytest.raises(ValueError):
        quant.quantize(jnp.zeros((2, 4)), "int4")
    with pytest.raises(ValueError):
        quant.quant_dtype("bf16")


# -- cache_update: fused quantize+scatter vs quantize-then-oracle -------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("heads", [(KVH, HD), (1, RK)])
def test_quant_cache_update_parity(mode, heads):
    h, d = heads
    r = rng(1)
    shape = (B, C, h, d) if h > 1 else (B, C, d)
    cache = quant.quantize(
        jnp.asarray(r.normal(size=shape), jnp.float32), mode)[0]
    scales = jnp.zeros(shape[:-1], jnp.float32)
    new = jnp.asarray(r.normal(size=(B, 1) + shape[2:]) * 3, jnp.float32)
    slots = jnp.asarray([0, 17, 63], jnp.int32)
    ref_c, ref_s = quant_cache_update_ref(cache, scales, new, slots, mode)
    out_c, out_s = quant_cache_update(cache, scales, new, slots, mode,
                                      impl="pallas_interpret")
    bitexact(ref_c, out_c)
    bitexact(ref_s, out_s)
    # written rows round-trip the incoming values (fp8_e4m3 carries a
    # 3-bit mantissa: ~6% relative error on top of the absmax step)
    deq = np.asarray(quant.dequantize(ref_c, ref_s))
    for b, s in enumerate([0, 17, 63]):
        row = np.asarray(new)[b, 0]
        amax = float(np.max(np.abs(row)))
        tol = 0.51 * amax / 127 if mode == "int8" else 0.07 * amax
        close(deq[b, s], row, atol=tol)


@pytest.mark.parametrize("mode", MODES)
def test_quant_paged_cache_update_parity(mode):
    r = rng(2)
    pool = quant.quantize(
        jnp.asarray(r.normal(size=(P, PS, KVH, HD)), jnp.float32), mode)[0]
    scales = jnp.zeros((P, PS, KVH), jnp.float32)
    new = jnp.asarray(r.normal(size=(B, T, KVH, HD)) * 2, jnp.float32)
    pt = jnp.asarray(r.permutation(P - 1)[: B * NB].reshape(B, NB) + 1,
                     jnp.int32)
    starts = jnp.asarray([0, 5, 30], jnp.int32)
    valids = jnp.asarray([T, 4, T], jnp.int32)
    ref_p, ref_s = quant_paged_cache_update_ref(pool, scales, new, pt,
                                                starts, valids, mode)
    out_p, out_s = quant_paged_cache_update(pool, scales, new, pt, starts,
                                            valids, mode,
                                            impl="pallas_interpret")
    bitexact(ref_p, out_p)
    bitexact(ref_s, out_s)


# -- decode attention: in-register dequant vs ref / lax -----------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("ring", [False, True])
def test_quant_decode_parity(mode, ring):
    r = rng(3)
    q = jnp.asarray(r.normal(size=(B, KVH, G, HD)), jnp.float32)
    k = jnp.asarray(r.normal(size=(B, C, KVH, HD)) * 3, jnp.float32)
    v = jnp.asarray(r.normal(size=(B, C, KVH, HD)), jnp.float32)
    lens = jnp.asarray([5, 63, 130 if ring else 31], jnp.int32)
    kc, ks = quant.quantize(k, mode)
    vc, vs = quant.quantize(v, mode)
    ref = decode_attention_ref(q, kc, vc, lens, ring=ring, scale=0.3,
                               block_k=16, k_scale=ks, v_scale=vs)
    pl = decode_attention_pallas(q, kc, vc, lens, ring=ring, scale=0.3,
                                 block_k=16, k_scale=ks, v_scale=vs,
                                 interpret=True)
    lx = decode_attention_lax(q, kc, vc, lens, ring=ring, scale=0.3,
                              k_scale=ks, v_scale=vs)
    bitexact(ref, pl)
    close(ref, lx)


@pytest.mark.parametrize("mode", MODES)
def test_quant_decode_mla_alias(mode):
    # MLA latent rows quantize ONCE; the same codes+scales serve as key
    # (full width) and value (v_width prefix).  Slice-then-dequant ==
    # dequant-then-slice, so v_scale defaults to k_scale.
    r = rng(4)
    kv = jnp.asarray(r.normal(size=(B, C, 1, RK)), jnp.float32)
    q1 = jnp.asarray(r.normal(size=(B, 1, G, RK)), jnp.float32)
    lens = jnp.asarray([5, 20, 63], jnp.int32)
    kvc, kvs = quant.quantize(kv, mode)
    ref = decode_attention_ref(q1, kvc, kvc[..., :8], lens, scale=0.3,
                               block_k=16, k_scale=kvs, v_scale=kvs)
    pl = decode_attention_pallas(q1, kvc, kvc, lens, scale=0.3, block_k=16,
                                 v_width=8, k_scale=kvs, interpret=True)
    lx = decode_attention_lax(q1, kvc, kvc, lens, scale=0.3, v_width=8,
                              k_scale=kvs)
    bitexact(ref, pl)
    close(ref, lx)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("window", [None, 24])
def test_quant_decode_paged_parity(mode, window):
    r = rng(5)
    kp = jnp.asarray(r.normal(size=(P, PS, KVH, HD)) * 2, jnp.float32)
    vp = jnp.asarray(r.normal(size=(P, PS, KVH, HD)), jnp.float32)
    pt = jnp.asarray(r.permutation(P - 1)[: B * NB].reshape(B, NB) + 1,
                     jnp.int32)
    lens = jnp.asarray([3, 30, 62], jnp.int32)
    kpc, kps = quant.quantize(kp, mode)
    vpc, vps = quant.quantize(vp, mode)
    q2 = jnp.asarray(r.normal(size=(B, KVH, G, HD)), jnp.float32)
    ref = decode_attention_paged_ref(q2, kpc, vpc, pt, lens, scale=0.3,
                                     window=window, k_scale=kps, v_scale=vps)
    pl = decode_attention_paged_pallas(q2, kpc, vpc, pt, lens, scale=0.3,
                                       window=window, k_scale=kps,
                                       v_scale=vps, interpret=True)
    lx = decode_attention_paged_lax(q2, kpc, vpc, pt, lens, scale=0.3,
                                    window=window, k_scale=kps, v_scale=vps)
    bitexact(ref, pl)
    close(ref, lx)


@pytest.mark.parametrize("mode", MODES)
def test_quant_decode_paged_mla_alias(mode):
    r = rng(6)
    kvp = jnp.asarray(r.normal(size=(P, PS, 1, RK)), jnp.float32)
    kvpc, kvps = quant.quantize(kvp, mode)
    pt = jnp.asarray(r.permutation(P - 1)[: B * NB].reshape(B, NB) + 1,
                     jnp.int32)
    q3 = jnp.asarray(r.normal(size=(B, 1, G, RK)), jnp.float32)
    lens = jnp.asarray([3, 30, 62], jnp.int32)
    ref = decode_attention_paged_ref(q3, kvpc, kvpc, pt, lens, scale=0.3,
                                     v_width=8, k_scale=kvps)
    pl = decode_attention_paged_pallas(q3, kvpc, kvpc, pt, lens, scale=0.3,
                                       v_width=8, k_scale=kvps,
                                       interpret=True)
    lx = decode_attention_paged_lax(q3, kvpc, kvpc, pt, lens, scale=0.3,
                                    v_width=8, k_scale=kvps)
    bitexact(ref, pl)
    close(ref, lx)


# -- prefill attention: quantized cache prefix + fp chunk ---------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("ring,window", [(False, None), (True, 48)])
def test_quant_prefill_parity(mode, ring, window):
    r = rng(7)
    q = jnp.asarray(r.normal(size=(B, KVH, T, G, HD)), jnp.float32)
    kx = jnp.asarray(r.normal(size=(B, T, KVH, HD)), jnp.float32)
    vx = jnp.asarray(r.normal(size=(B, T, KVH, HD)), jnp.float32)
    kc = jnp.asarray(r.normal(size=(B, C, KVH, HD)) * 2, jnp.float32)
    vc = jnp.asarray(r.normal(size=(B, C, KVH, HD)), jnp.float32)
    offs = jnp.asarray([0, 17, 60], jnp.int32)
    kcc, kcs = quant.quantize(kc, mode)
    vcc, vcs = quant.quantize(vc, mode)
    ref = prefill_attention_ref(q, kx, vx, kcc, vcc, offs, ring=ring,
                                window=window, scale=0.3, block_k=16,
                                k_scale=kcs, v_scale=vcs)
    pl = prefill_attention_pallas(q, kx, vx, kcc, vcc, offs, ring=ring,
                                  window=window, scale=0.3, block_k=16,
                                  k_scale=kcs, v_scale=vcs, interpret=True)
    lx = prefill_attention_lax(q, kx, vx, kcc, vcc, offs, ring=ring,
                               window=window, scale=0.3, k_scale=kcs,
                               v_scale=vcs)
    bitexact(ref, pl)
    close(ref, lx)


@pytest.mark.parametrize("mode", MODES)
def test_quant_prefill_mla_alias(mode):
    r = rng(8)
    kvx = jnp.asarray(r.normal(size=(B, T, 1, RK)), jnp.float32)
    kvc = jnp.asarray(r.normal(size=(B, C, 1, RK)), jnp.float32)
    q1 = jnp.asarray(r.normal(size=(B, 1, T, G, RK)), jnp.float32)
    offs = jnp.asarray([0, 17, 60], jnp.int32)
    kvcc, kvcs = quant.quantize(kvc, mode)
    ref = prefill_attention_ref(q1, kvx, kvx[..., :8], kvcc, kvcc[..., :8],
                                offs, scale=0.3, block_k=16, k_scale=kvcs,
                                v_scale=kvcs)
    pl = prefill_attention_pallas(q1, kvx, kvx, kvcc, kvcc, offs, scale=0.3,
                                  block_k=16, v_width=8, k_scale=kvcs,
                                  interpret=True)
    lx = prefill_attention_lax(q1, kvx, kvx, kvcc, kvcc, offs, scale=0.3,
                               v_width=8, k_scale=kvcs)
    bitexact(ref, pl)
    close(ref, lx)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("window", [None, 24])
def test_quant_prefill_paged_parity(mode, window):
    r = rng(9)
    kp = jnp.asarray(r.normal(size=(P, PS, KVH, HD)) * 2, jnp.float32)
    vp = jnp.asarray(r.normal(size=(P, PS, KVH, HD)), jnp.float32)
    pt = jnp.asarray(r.permutation(P - 1)[: B * NB].reshape(B, NB) + 1,
                     jnp.int32)
    kx = jnp.asarray(r.normal(size=(B, T, KVH, HD)), jnp.float32)
    vx = jnp.asarray(r.normal(size=(B, T, KVH, HD)), jnp.float32)
    q2 = jnp.asarray(r.normal(size=(B, KVH, T, G, HD)), jnp.float32)
    offs = jnp.asarray([0, 17, 55], jnp.int32)
    kpc, kps = quant.quantize(kp, mode)
    vpc, vps = quant.quantize(vp, mode)
    ref = prefill_attention_paged_ref(q2, kx, vx, kpc, vpc, pt, offs,
                                      window=window, scale=0.3,
                                      k_scale=kps, v_scale=vps)
    pl = prefill_attention_paged_pallas(q2, kx, vx, kpc, vpc, pt, offs,
                                        window=window, scale=0.3,
                                        k_scale=kps, v_scale=vps,
                                        interpret=True)
    lx = prefill_attention_paged_lax(q2, kx, vx, kpc, vpc, pt, offs,
                                     window=window, scale=0.3,
                                     k_scale=kps, v_scale=vps)
    bitexact(ref, pl)
    close(ref, lx)


@pytest.mark.parametrize("mode", MODES)
def test_quant_prefill_paged_mla_alias(mode):
    r = rng(10)
    kvx = jnp.asarray(r.normal(size=(B, T, 1, RK)), jnp.float32)
    kvp = jnp.asarray(r.normal(size=(P, PS, 1, RK)), jnp.float32)
    kvpc, kvps = quant.quantize(kvp, mode)
    pt = jnp.asarray(r.permutation(P - 1)[: B * NB].reshape(B, NB) + 1,
                     jnp.int32)
    q3 = jnp.asarray(r.normal(size=(B, 1, T, G, RK)), jnp.float32)
    offs = jnp.asarray([0, 17, 55], jnp.int32)
    ref = prefill_attention_paged_ref(q3, kvx, kvx, kvpc, kvpc, pt, offs,
                                      scale=0.3, v_width=8, k_scale=kvps)
    pl = prefill_attention_paged_pallas(q3, kvx, kvx, kvpc, kvpc, pt, offs,
                                        scale=0.3, v_width=8, k_scale=kvps,
                                        interpret=True)
    lx = prefill_attention_paged_lax(q3, kvx, kvx, kvpc, kvpc, pt, offs,
                                     scale=0.3, v_width=8, k_scale=kvps)
    bitexact(ref, pl)
    close(ref, lx)
