"""Fault-tolerant measurement plane: fault injection, supervised reads,
hardened samplers, degraded spans, and fail-safe governor/telemetry.

Everything timing-sensitive runs on a fake clock and an injected sleep
function — the fault plans in :mod:`repro.core.faults` select by read
index or armed-relative time, so blackout/flap/recovery schedules are
bit-exact without sleeping.  The few integration tests that need real
threads (sampler survival, engine deadlines, HTTP hardening) assert
properties that hold at any speed: the thread is still alive, the
request finished with reason ``timeout``, the endpoint answered 400.
"""
import json
import math
import threading
import time
import urllib.error
import urllib.request
import warnings

import pytest

import repro.core as pmt
from repro.core.backends.dummy import DummySensor
from repro.core.faults import FAULT_KINDS, Fault, FaultInjectingSensor
from repro.core.sampler import (DumpThread, RingSampler, SamplerCoverageGap,
                                SamplerReadError)
from repro.core.sensor import Sample, Sensor, SensorError
from repro.core.supervisor import DEGRADED, FAILED, OK, SensorSupervisor
from repro.telemetry import HealthEvent, PowerRecorder, TelemetryServer


class Clock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class ScriptSensor(Sensor):
    """Replays a scripted list of ``Sample``s / exceptions / callables.

    The last item repeats forever, so "heal after N reads" scripts stay
    short; ``heal()`` truncates to the final (healthy) item.
    """

    name = "script"
    kind = "measured"
    native_period_s = 0.0001

    def __init__(self, script, clock=None):
        super().__init__(clock=clock)
        self.script = list(script)
        self.reads = 0

    def _sample(self) -> Sample:
        item = self.script[min(self.reads, len(self.script) - 1)]
        self.reads += 1
        if isinstance(item, Exception):
            raise item
        if callable(item):
            item = item()
        return item

    def heal(self):
        self.script = [self.script[-1]]
        self.reads = 0


def J(x):
    return Sample(joules=float(x))


def W(x):
    return Sample(watts=float(x))


# -- fault plans -------------------------------------------------------------

class TestFaultPlan:
    def test_validation(self):
        with pytest.raises(ValueError):
            Fault("frobnicate", start=0)
        with pytest.raises(ValueError):
            Fault("error")                       # no selector
        with pytest.raises(ValueError):
            Fault("error", start=0, t0_s=0.0)    # both selectors
        with pytest.raises(ValueError):
            Fault("flap", start=0, period=2, duty=3)
        assert set(FAULT_KINDS) >= {"error", "hang", "nan", "negative",
                                    "spike", "stuck", "reset", "flap"}

    def test_index_window_error(self):
        clock = Clock()
        fs = FaultInjectingSensor(DummySensor(watts=42.0, clock=clock),
                                  plan=[Fault("error", start=2, count=2)])
        outcomes = []
        for _ in range(5):
            clock.advance(0.1)
            try:
                fs.read()
                outcomes.append("ok")
            except SensorError:
                outcomes.append("err")
        assert outcomes == ["ok", "ok", "err", "err", "ok"]
        assert fs.injected["error"] == 2

    def test_time_window_rebased_by_arm(self):
        clock = Clock()
        fs = FaultInjectingSensor(
            DummySensor(watts=42.0, clock=clock),
            plan=[Fault("error", t0_s=1.0, t1_s=2.0)])
        fs.arm()                                 # t=0: window is [1, 2)
        fs.read()                                # rel_t = 0: healthy
        clock.advance(1.5)
        with pytest.raises(SensorError):
            fs.read()                            # rel_t = 1.5: blackout
        clock.advance(1.0)
        fs.read()                                # rel_t = 2.5: recovered
        fs.arm()                                 # rebase: window moves out
        clock.advance(0.5)
        fs.read()                                # rel_t = 0.5 again
        with pytest.raises(SensorError):
            clock.advance(1.0)                   # rel_t = 1.5
            fs.read()

    def test_nan_negative_spike_transforms(self):
        clock = Clock()
        for kind, check in [
                ("nan", lambda w: math.isnan(w)),
                ("negative", lambda w: w == -42.0),
                ("spike", lambda w: w == pytest.approx(420.0))]:
            fs = FaultInjectingSensor(DummySensor(watts=42.0, clock=clock),
                                      plan=[Fault(kind, start=0, count=1)])
            _t, _j, w = fs.read_raw()
            assert check(w), (kind, w)
            assert fs.injected[kind] == 1

    def test_stuck_freezes_last_good_value(self):
        clock = Clock()
        inner = DummySensor(watts_fn=lambda t: 10.0 + t, clock=clock)
        fs = FaultInjectingSensor(inner,
                                  plan=[Fault("stuck", start=2, count=2)])
        seen = []
        for _ in range(5):
            clock.advance(1.0)
            seen.append(fs.read_raw()[2])
        # reads 2 and 3 replay read 1's watts; read 4 is live again
        assert seen[2] == seen[1] and seen[3] == seen[1]
        assert seen[4] > seen[1]
        assert fs.injected["stuck"] == 2

    def test_reset_rolls_raw_counter_backwards(self):
        clock = Clock()
        inner = ScriptSensor([J(10), J(20), J(30), J(40)], clock=clock)
        fs = FaultInjectingSensor(inner,
                                  plan=[Fault("reset", start=2, count=None,
                                              reset_to=0.0)])
        js = []
        for _ in range(4):
            clock.advance(1.0)
            js.append(fs.read_raw()[1])
        # the faulted counter restarts from 0 — exactly the RAPL
        # wraparound shape the supervisor's rebase must absorb
        assert js == [10.0, 20.0, 0.0, 10.0]

    def test_flap_duty_cycle(self):
        clock = Clock()
        fs = FaultInjectingSensor(
            DummySensor(watts=42.0, clock=clock),
            plan=[Fault("flap", start=0, period=3, duty=1)])
        outcomes = []
        for _ in range(6):
            clock.advance(0.1)
            try:
                fs.read()
                outcomes.append("ok")
            except SensorError:
                outcomes.append("err")
        assert outcomes == ["err", "ok", "ok", "err", "ok", "ok"]


# -- supervisor --------------------------------------------------------------

class TestSupervisor:
    def test_passthrough_ok_fast_path(self):
        clock = Clock()
        sup = SensorSupervisor([DummySensor(watts=42.0, clock=clock)],
                               clock=clock)
        for _ in range(3):
            clock.advance(0.1)
            sup.read()
        assert sup.state == OK
        h = sup.health()
        assert h["state"] == OK and h["active_index"] == 0
        assert h["counters"]["reads"] == 3
        assert h["counters"]["failures"] == 0

    def test_counter_reset_rebase_is_bit_exact(self):
        clock = Clock()
        inner = ScriptSensor([J(10), J(20), J(5), J(15)], clock=clock)
        sup = SensorSupervisor([inner], clock=clock, retries=0)
        js = []
        for _ in range(4):
            clock.advance(1.0)
            js.append(sup.read_raw()[1])
        # raw 10,20,5,15: the 20->5 regression is a reset; 5 J of the
        # new epoch counts as accumulation since the reset
        assert js == [10.0, 20.0, 25.0, 35.0]
        assert sup.health()["counters"]["counter_resets"] == 1

    def test_retry_backoff_schedule_is_deterministic(self):
        clock = Clock()
        sleeps = []
        inner = ScriptSensor([SensorError("a"), SensorError("b"), W(5.0)],
                             clock=clock)
        sup = SensorSupervisor([inner], clock=clock, retries=2,
                               backoff_s=0.01, backoff_jitter=0.1,
                               sleep_fn=sleeps.append)
        sup.read()
        expected = [0.01 * (1.0 + 0.1 * (((i * 2654435761) & 0xFF) / 255.0)
                            ) * (2.0 ** (i - 1))
                    for i in (1, 2)]
        assert sleeps == pytest.approx(expected)
        assert sup.health()["counters"]["retries"] == 2
        assert sup.state == OK

    def test_failover_and_failback_keep_joules_continuous(self):
        clock = Clock()
        primary = ScriptSensor([J(100), SensorError("down"), J(130)],
                               clock=clock)
        fallback = ScriptSensor([J(7), J(8), J(9)], clock=clock)
        transitions = []
        sup = SensorSupervisor(
            [primary, fallback], clock=clock, retries=0,
            breaker_threshold=10, sleep_fn=lambda s: None,
            on_transition=lambda old, new, d: transitions.append((old, new)))
        js = []
        for _ in range(3):
            clock.advance(1.0)
            js.append(sup.read_raw()[1])
        assert transitions == [(OK, DEGRADED), (DEGRADED, OK)]
        c = sup.health()["counters"]
        assert c["failovers"] == 1 and c["failbacks"] == 1
        # one continuous non-decreasing series across both switches
        assert js == sorted(js)

    def test_breaker_opens_skips_and_half_open_probes(self):
        clock = Clock()
        primary = ScriptSensor([SensorError("dead")], clock=clock)
        fallback = DummySensor(watts=7.0, clock=clock)
        sup = SensorSupervisor([primary, fallback], clock=clock, retries=0,
                               breaker_threshold=2, breaker_cooldown_s=1.0,
                               sleep_fn=lambda s: None)
        clock.advance(0.1)
        sup.read()                               # fail 1 -> fallback
        sup.read()                               # fail 2 -> breaker opens
        assert sup.health()["backends"][0]["breaker"] == "open"
        assert sup.health()["counters"]["breaker_opens"] == 1
        attempts = primary.reads
        sup.read()                               # open: primary skipped
        assert primary.reads == attempts
        assert sup.state == DEGRADED
        clock.advance(1.5)                       # past the cooldown
        sup.read()                               # half-open probe fails
        assert primary.reads == attempts + 1
        assert sup.health()["backends"][0]["breaker"] == "open"
        primary.heal()
        primary.script = [J(50.0)]
        clock.advance(1.5)
        sup.read()                               # probe succeeds: closed
        assert sup.health()["backends"][0]["breaker"] == "closed"
        assert sup.state == OK

    def test_whole_chain_exhausted_raises_and_recovers(self):
        clock = Clock()
        a = ScriptSensor([SensorError("a")], clock=clock)
        b = ScriptSensor([SensorError("b")], clock=clock)
        sup = SensorSupervisor([a, b], clock=clock, retries=0,
                               breaker_threshold=99, sleep_fn=lambda s: None)
        clock.advance(0.1)
        with pytest.raises(SensorError):
            sup.read()
        assert sup.state == FAILED
        a.script = [W(42.0)]
        clock.advance(0.1)
        sup.read()
        assert sup.state == OK

    def test_hang_fault_trips_read_deadline(self):
        clock = Clock()
        hung = FaultInjectingSensor(
            DummySensor(watts=42.0, clock=clock),
            plan=[Fault("hang", start=1, count=None, hang_s=0.5)],
            clock=clock, sleep_fn=clock.advance)
        fallback = DummySensor(watts=7.0, clock=clock)
        sup = SensorSupervisor([hung, fallback], clock=clock,
                               deadline_s=0.1, retries=0,
                               breaker_threshold=99, sleep_fn=lambda s: None)
        clock.advance(0.1)
        sup.read()                               # read 0: fast, primary
        assert sup.health()["active_index"] == 0
        sup.read()                               # read 1 hangs 0.5s > 0.1s
        assert sup.health()["counters"]["timeouts"] == 1
        assert sup.health()["active_index"] == 1
        assert sup.state == DEGRADED

    def test_spike_gate_rejects_outlier_then_recovers(self):
        clock = Clock()
        inner = ScriptSensor([W(50.0)] * 16 + [W(5000.0), W(50.0)],
                             clock=clock)
        sup = SensorSupervisor([inner], clock=clock, retries=0,
                               spike_sigma=8.0, sleep_fn=lambda s: None)
        for _ in range(16):
            clock.advance(0.1)
            sup.read()
        clock.advance(0.1)
        with pytest.raises(SensorError):
            sup.read()                           # 5 kW vs a 50 W band
        assert sup.health()["counters"]["spikes_rejected"] == 1
        clock.advance(0.1)
        sup.read()
        assert sup.state == OK


# -- sensor base-class sanitization -----------------------------------------

class TestSensorSanitize:
    @pytest.mark.parametrize("kind", ["nan", "negative"])
    def test_bad_watts_interval_dropped_not_integrated(self, kind):
        clock = Clock()
        fs = FaultInjectingSensor(DummySensor(watts=42.0, clock=clock),
                                  plan=[Fault(kind, start=1, count=1)],
                                  clock=clock)
        fs.read()                                # t=0: baseline
        clock.advance(1.0)
        st = fs.read()                           # faulted interval
        assert st.joules == pytest.approx(0.0)   # dropped, not poisoned
        clock.advance(1.0)
        st = fs.read()                           # good again: integrates
        assert math.isfinite(st.joules)
        assert st.joules == pytest.approx(42.0)  # one good 42 W second


# -- hardened samplers -------------------------------------------------------

class TestHardenedSampler:
    def test_gap_open_close_and_overlap(self):
        clock = Clock()
        sensor = ScriptSensor([J(1.0)], clock=clock)
        ring = RingSampler(sensor, period_s=1.0)     # never started: no thread
        clock.t = 1.0
        ring.sample_now()
        sensor.script = [SensorError("blackout")]
        clock.t = 2.0
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", SamplerReadError)
            with pytest.raises(SensorError):
                ring.sample_now()
        assert ring.read_errors == 1
        h = ring.health()
        assert h["state"] == FAILED and h["in_gap"]
        assert ring.gap_overlaps(1.5, 2.5)           # straddles the open gap
        sensor.script = [J(2.0)]
        clock.t = 3.0
        ring.sample_now()                            # gap closes at t=3
        h = ring.health()
        assert h["state"] == OK and h["gaps"] == 1
        assert ring.gap_overlaps(1.5, 2.0)           # inside [1, 3]
        assert ring.gap_overlaps(0.5, 1.5)
        assert not ring.gap_overlaps(0.0, 0.9)
        assert not ring.gap_overlaps(3.1, 4.0)
        clock.t = 5.0
        assert ring.staleness_s() == pytest.approx(2.0)

    def test_sampler_thread_survives_read_errors(self):
        sensor = FaultInjectingSensor(
            DummySensor(watts=42.0),
            plan=[Fault("error", start=5, count=5)])
        ring = RingSampler(sensor, period_s=0.001)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", SamplerReadError)
            ring.start()
            deadline = time.monotonic() + 5.0
            while sensor._index < 20 and time.monotonic() < deadline:
                time.sleep(0.005)
            assert sensor._index >= 20, "sampler stopped ticking"
            assert ring.is_alive(), "read errors killed the sampler thread"
            before = ring.last_ts()
            time.sleep(0.01)
            ring.stop()
        assert ring.read_errors == 5
        assert ring.health()["gaps"] >= 1            # blackout recorded
        assert ring.last_ts() > before               # still publishing after

    def test_dump_thread_skips_row_on_read_error(self, tmp_path):
        clock = Clock()
        sensor = ScriptSensor([W(10.0), SensorError("x"), W(10.0)],
                              clock=clock)
        dump = DumpThread(sensor, str(tmp_path / "d.csv"), period_s=1.0)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", SamplerReadError)
            clock.advance(1.0)
            dump._tick()
            clock.advance(1.0)
            dump._tick()                             # failed read: no raise
            clock.advance(1.0)
            dump._tick()
        assert dump.read_errors == 1
        dump._writer.close()

    def test_degraded_span_through_session(self):
        # A region that straddles a scripted blackout resolves degraded:
        # the paper's interpolation assumption is violated and the
        # record says so instead of silently reporting made-up joules.
        sensor = FaultInjectingSensor(
            DummySensor(watts=50.0),
            plan=[Fault("error", start=8, count=10_000)])
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", SamplerReadError)
            warnings.simplefilter("ignore", SamplerCoverageGap)
            with pmt.Session([sensor], pool=pmt.SensorPool(),
                             period_s=0.001) as sess:
                mem = sess.add_exporter(pmt.MemoryExporter())
                with sess.region("blackout"):
                    time.sleep(0.1)              # sampler hits read #8
                sess.flush()
                stats = sess.stats()
                health = sess.health()
        assert mem.records, "region produced no records"
        assert any(r.degraded for r in mem.records)
        assert stats["degraded"] >= 1
        assert health["dummy"]["read_errors"] > 0
        # degraded flag survives the JSON round trip
        rt = pmt.RegionRecord.from_json(mem.records[0].as_json())
        assert rt.degraded == mem.records[0].degraded


# -- governor fail-safe ------------------------------------------------------

def governed(clock, **kw):
    rec = PowerRecorder()
    gov = __import__("repro.serve.governor",
                     fromlist=["PowerGovernor"]).PowerGovernor(
        rec, window_s=0.5, clock=clock, **kw)
    return gov, rec


def feed(rec, clock, watts, seconds=1.0, dt=0.01):
    end = clock.t + seconds
    while clock.t < end:
        clock.advance(dt)
        rec.add_watts("dummy", clock.t, watts)


class TestGovernorFailSafe:
    def test_fail_closed_blocks_on_stale_signal(self):
        clock = Clock()
        gov, rec = governed(clock, cap_watts=100.0, signal_ttl_s=1.0,
                            fail_mode="closed")
        feed(rec, clock, 40.0)
        assert not gov.signal_stale()
        assert gov.admission_allowed()
        clock.advance(5.0)                       # sampler went dark
        assert gov.signal_stale()
        assert not gov.admission_allowed()
        assert gov.prefill_chunk_budget(decode_live=True) == 0
        # liveness: a stale signal must never blind-pause live decode
        assert gov.maybe_pause_decode() == 0.0
        actions = [d.action for d in gov.decisions]
        assert "signal_stale" in actions
        feed(rec, clock, 40.0)                   # signal recovers
        assert not gov.signal_stale()
        assert gov.admission_allowed()
        actions = [d.action for d in gov.decisions]
        assert actions.count("signal_stale") == 1
        assert actions.count("signal_fresh") == 1
        st = gov.stats()
        assert st["signal_ttl_s"] == 1.0
        assert st["fail_mode"] == "closed"
        assert st["signal_stale"] is False

    def test_fail_open_runs_unthrottled_on_stale_signal(self):
        clock = Clock()
        gov, rec = governed(clock, cap_watts=100.0, signal_ttl_s=1.0,
                            fail_mode="open")
        feed(rec, clock, 95.0)                   # over the admit threshold
        assert not gov.admission_allowed()
        clock.advance(5.0)
        assert gov.signal_stale()
        # fail-open: the frozen 95 W reading no longer gates anything
        assert gov.admission_allowed()
        assert gov.prefill_chunk_budget(decode_live=True) == 1
        assert gov.maybe_pause_decode() == 0.0

    def test_cold_start_is_not_stale(self):
        clock = Clock()
        gov, _rec = governed(clock, cap_watts=100.0, signal_ttl_s=0.5)
        clock.advance(100.0)
        assert not gov.signal_stale()            # no sample yet: cold start
        assert gov.admission_allowed()

    def test_constructor_validation(self):
        rec = PowerRecorder()
        from repro.serve.governor import PowerGovernor
        with pytest.raises(ValueError):
            PowerGovernor(rec, cap_watts=10.0, signal_ttl_s=0.0)
        with pytest.raises(ValueError):
            PowerGovernor(rec, cap_watts=10.0, fail_mode="explode")

    def test_last_watts_ts_is_min_over_backends(self):
        rec = PowerRecorder()
        rec.add_watts("a", 5.0, 10.0)
        rec.add_watts("b", 2.0, 10.0)
        # the summed signal is only as fresh as its most stale backend
        assert rec.last_watts_ts() == pytest.approx(2.0)
        assert rec.last_watts_ts(backend="a") == pytest.approx(5.0)
        assert rec.last_watts_ts(backend="nope") is None


# -- health events + telemetry hardening ------------------------------------

class _FakeSampler:
    def __init__(self):
        self.state = OK

    def health(self):
        return {"state": self.state, "read_errors": 2, "gaps": 1}

    def last_ts(self):
        return 1.5

    def timeline(self):
        import numpy as np
        z = np.zeros(0)
        return z, z, z


class TestHealthEvents:
    def test_transitions_emit_events_and_fan_out(self):
        rec = PowerRecorder()
        fake = _FakeSampler()
        got = []
        rec.subscribe_health(got.append)
        rec._poll_health([("dummy", fake)])      # ok baseline: no event
        assert got == []
        fake.state = FAILED
        rec._poll_health([("dummy", fake)])
        fake.state = OK
        rec._poll_health([("dummy", fake)])
        assert [(e.state, e.prev_state) for e in got] == \
            [(FAILED, OK), (OK, FAILED)]
        assert got[0].backend == "dummy"
        assert got[0].timestamp_s == pytest.approx(1.5)
        payload = json.loads(got[0].as_json())
        assert payload["state"] == FAILED
        h = rec.health()
        assert h["state"] == OK
        assert h["health_events"] == 2
        assert rec.stats()["health_events"] == 2

    def test_raising_health_subscriber_is_kept(self):
        rec = PowerRecorder()
        fake = _FakeSampler()
        got = []

        def bad(ev):
            raise RuntimeError("boom")

        rec.subscribe_health(bad)
        rec.subscribe_health(got.append)
        fake.state = DEGRADED
        with warnings.catch_warnings(record=True):
            warnings.simplefilter("always")
            rec._poll_health([("dummy", fake)])
            fake.state = OK
            rec._poll_health([("dummy", fake)])
        assert len(got) == 2                     # bad sub never blocked fan-out


@pytest.fixture()
def served():
    rec = PowerRecorder()
    srv = TelemetryServer(rec).start()
    yield rec, srv
    srv.close()
    rec.close()


def get_error(url):
    try:
        with urllib.request.urlopen(url, timeout=5.0):
            pass
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read().decode())
    raise AssertionError(f"expected an HTTP error from {url}")


class TestServerHardening:
    @pytest.mark.parametrize("query", [
        "/timeline?window=abc",
        "/timeline?window=-1",
        "/timeline?window=0",
        "/timeline?window=inf",
        "/timeline?since=nan",
        "/requests?tenant=../etc",
        "/requests?tenant=" + "x" * 65,
        "/requests?tenant=a%20b",
    ])
    def test_malformed_query_is_json_400(self, served, query):
        _rec, srv = served
        code, body = get_error(srv.url + query)
        assert code == 400
        assert "error" in body

    def test_valid_tenant_filter_passes(self, served):
        rec, srv = served
        with urllib.request.urlopen(srv.url + "/requests?tenant=t-0.a",
                                    timeout=5.0) as resp:
            body = json.loads(resp.read().decode())
        assert body["tenant"] == "t-0.a" and body["count"] == 0

    def test_health_endpoint(self, served):
        rec, srv = served
        with urllib.request.urlopen(srv.url + "/health",
                                    timeout=5.0) as resp:
            body = json.loads(resp.read().decode())
        assert body["state"] == OK
        assert body["backends"] == {}

    def test_sse_stream_delivers_health_events(self, served):
        rec, srv = served
        resp = urllib.request.urlopen(srv.url + "/stream", timeout=5.0)
        for _ in range(3):
            resp.readline()                      # hello event
        fake = _FakeSampler()
        fake.state = DEGRADED
        rec._poll_health([("dummy", fake)])
        deadline = time.monotonic() + 5.0
        event = data = None
        while time.monotonic() < deadline:
            line = resp.readline()
            if line == b"event: health\n":
                event = "health"
            elif event and line.startswith(b"data: "):
                data = json.loads(line[len(b"data: "):].decode())
                break
        resp.close()
        assert data is not None, "health event never arrived on /stream"
        assert data["state"] == DEGRADED and data["backend"] == "dummy"


# -- engine request deadlines ------------------------------------------------

@pytest.fixture(scope="module")
def smollm():
    import dataclasses

    import jax

    from repro import configs
    from repro.models import model as M
    cfg = dataclasses.replace(configs.get_config("smollm-135m",
                                                 reduced=True),
                              dtype="float32")
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_engine(cfg, params, **kw):
    import jax.numpy as jnp

    from repro.serve.engine import ServeEngine
    kw.setdefault("batch_size", 1)
    kw.setdefault("max_len", 128)
    kw.setdefault("session", None)
    kw.setdefault("cache_dtype", jnp.float32)
    return ServeEngine(cfg, params, **kw)


class TestEngineDeadlines:
    def test_deadline_validation(self, smollm):
        from repro.serve.engine import Request
        cfg, params = smollm
        eng = make_engine(cfg, params)
        with pytest.raises(ValueError):
            eng.generate([Request(prompt=[1], max_new_tokens=1,
                                  deadline_s=-1.0)])
        wave = make_engine(cfg, params, mode="wave")
        with pytest.raises(ValueError):
            wave.generate([Request(prompt=[1], max_new_tokens=1,
                                   deadline_s=1.0)])

    def test_waiting_request_times_out(self, smollm):
        from repro.serve.engine import Request
        cfg, params = smollm
        eng = make_engine(cfg, params, batch_size=1)
        eng.generate([Request(prompt=[1, 2], max_new_tokens=2)])  # warmup
        slow = Request(prompt=[1] * 5, max_new_tokens=24)
        doomed = Request(prompt=[2] * 5, max_new_tokens=4,
                         deadline_s=0.001)
        done = eng.generate([slow, doomed])
        assert done[0].finish_reason == "length"
        assert len(done[0].out) == 24
        # one slot, held by `slow` well past the 1 ms deadline: `doomed`
        # is swept from the waiting queue without ever being admitted
        assert done[1].finish_reason == "timeout"
        assert done[1].out == []
        assert eng.stats()["requests_timed_out"] == 1

    def test_mid_generation_timeout_keeps_partial_output(self, smollm):
        from repro.serve.engine import Request
        cfg, params = smollm
        eng = make_engine(cfg, params, batch_size=1, max_len=128)
        eng.generate([Request(prompt=[1, 2], max_new_tokens=2)])  # warmup
        r = Request(prompt=[3] * 5, max_new_tokens=124, deadline_s=0.02)
        done = eng.generate([r])
        assert done[0].finish_reason == "timeout"
        assert len(done[0].out) < 124                # cut short...
        assert eng.live_slots == 0                   # ...slot reclaimed
        assert eng.stats()["requests_timed_out"] == 1

    def test_no_deadline_unchanged(self, smollm):
        from repro.serve.engine import Request
        cfg, params = smollm
        eng = make_engine(cfg, params, batch_size=2)
        done = eng.generate([Request(prompt=[4] * 3, max_new_tokens=3)
                             for _ in range(2)])
        assert all(r.finish_reason == "length" for r in done)
        assert all(len(r.out) == 3 for r in done)
        assert eng.stats()["requests_timed_out"] == 0
