"""Data pipeline, optimizers, compression, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
# Real hypothesis when installed; deterministic-grid fallback otherwise.
from strategies import given, settings, st

from repro.data.pipeline import (DataConfig, SyntheticLMDataset,
                                 host_batch_iterator)
from repro.optim.optimizers import (OptimizerConfig, clip_by_global_norm,
                                    make_optimizer, wsd_schedule)
from repro.optim.compression import compress_int8, decompress_int8
from repro.checkpoint.manager import (CheckpointManager, CheckpointMeta,
                                      latest_step, restore, save)


# -- data ------------------------------------------------------------------------

def test_data_deterministic_and_skippable():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=8, seed=7)
    ds = SyntheticLMDataset(cfg)
    b5a = ds.batch(5)
    b5b = SyntheticLMDataset(cfg).batch(5)       # fresh instance
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    # skip-ahead: iterator starting at 5 equals direct batch(5) slice
    it = host_batch_iterator(cfg, host_id=1, num_hosts=4, start_step=5)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], b5a["tokens"][2:4])


def test_data_hosts_partition_batch():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8, seed=1)
    parts = [next(host_batch_iterator(cfg, h, 4)) for h in range(4)]
    glued = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(glued,
                                  SyntheticLMDataset(cfg).batch(0)["tokens"])


def test_data_targets_are_shifted_tokens():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2, seed=3)
    b = SyntheticLMDataset(cfg).batch(0)
    # targets[t] is the next token of an extended stream; check learnable
    # bigram structure exists: same (token) pairs recur
    assert b["tokens"].shape == b["targets"].shape == (2, 16)


# -- optimizers ---------------------------------------------------------------------

@pytest.mark.parametrize("name,lr,steps", [("adamw", 0.05, 60),
                                           ("adafactor", 0.2, 120)])
def test_optimizer_descends_quadratic(name, lr, steps):
    # adafactor's RMS-1 update clipping caps the per-step move at ~lr,
    # so it needs a larger lr / more steps on this toy problem.
    ocfg = OptimizerConfig(name=name, lr=lr, warmup_steps=1,
                           decay_steps=100000, weight_decay=0.0)
    init, update = make_optimizer(ocfg)
    params = {"w": jnp.ones((4, 4)) * 5.0, "b": jnp.ones((4,)) * 3.0}
    state = init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    l0 = float(loss(params))
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state = update(g, state, params)
    assert float(loss(params)) < 0.2 * l0


def test_adafactor_factored_state_is_small():
    ocfg = OptimizerConfig(name="adafactor", factored_min_dim=128)
    init, _ = make_optimizer(ocfg)
    params = {"big": jnp.zeros((512, 256)), "small": jnp.zeros((16,))}
    st_ = init(params)
    big = st_.inner["big"]
    assert set(big) == {"vr", "vc"}
    assert big["vr"].shape == (512,) and big["vc"].shape == (256,)
    assert set(st_.inner["small"]) == {"v"}


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    total = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert abs(total - 1.0) < 1e-4


def test_wsd_schedule_shape():
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=10, decay_steps=100,
                           min_lr_frac=0.1)
    lrs = [float(wsd_schedule(ocfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100, 1000)]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup rises
    assert lrs[2] >= lrs[3] >= lrs[4]        # decay falls
    assert abs(lrs[-1] - 1e-4) < 1e-6        # floor at min_lr_frac


# -- compression ----------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=1000))
@settings(max_examples=30, deadline=None)
def test_int8_compression_unbiased(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (256,)) * 0.01
    # average many stochastic roundings -> unbiased estimate
    outs = []
    for i in range(32):
        q, s = compress_int8(x, jax.random.PRNGKey(seed * 64 + i))
        outs.append(decompress_int8(q, s))
    err = np.abs(np.mean(outs, axis=0) - np.asarray(x)).max()
    scale = float(jnp.abs(x).max()) / 127.0
    assert err < 2.0 * scale   # bias well under one quantization step


def test_int8_roundtrip_range():
    x = jnp.asarray([-3.0, -1.0, 0.0, 1.0, 3.0])
    q, s = compress_int8(x, jax.random.PRNGKey(0))
    y = decompress_int8(q, s)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0.05)


# -- checkpointing ---------------------------------------------------------------------

def _tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    meta = CheckpointMeta(step=7, cumulative_joules=123.5, data_step=7)
    save(d, 7, _tree(), meta)
    assert latest_step(d) == 7
    restored, m2 = restore(d, _tree())
    np.testing.assert_array_equal(restored["w"], _tree()["w"])
    assert m2.cumulative_joules == 123.5


def test_checkpoint_corruption_falls_back(tmp_path):
    d = str(tmp_path)
    save(d, 1, _tree(), CheckpointMeta(step=1))
    save(d, 2, _tree(), CheckpointMeta(step=2))
    # corrupt the newest checkpoint's first leaf
    leaf = os.path.join(d, "step_00000002", "leaf_00000.npy")
    with open(leaf, "r+b") as f:
        f.seek(-4, 2)
        f.write(b"\xff\xff\xff\xff")
    restored, meta = restore(d, _tree())
    assert meta.step == 1   # fell back to the previous valid one


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=2, async_save=True)
    for s in range(1, 5):
        mgr.maybe_save(s, _tree(), CheckpointMeta(step=s))
    mgr.finalize()
    steps = [latest_step(str(tmp_path))]
    assert steps[0] == 4
    from repro.checkpoint.manager import _valid_steps
    assert len(_valid_steps(str(tmp_path))) == 2   # gc kept 2


def test_elastic_reshard_hook(tmp_path):
    """restore() re-places leaves through shard_fn (elastic restore)."""
    d = str(tmp_path)
    save(d, 3, _tree(), CheckpointMeta(step=3))
    calls = []

    def shard_fn(leaf, i):
        calls.append(i)
        return jnp.asarray(leaf)  # placement hook; any mesh would do

    restored, _ = restore(d, _tree(), shard_fn=shard_fn)
    assert len(calls) == len(jax.tree.leaves(_tree()))
    assert isinstance(restored["w"], jax.Array)
