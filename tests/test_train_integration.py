"""End-to-end: train reduced smollm on the synthetic pipeline with PMT
monitoring, checkpoint/restart continuity (incl. energy accounting), and
the roofline cost plumbing on a tiny compile."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as pmt
from repro import configs
from repro.checkpoint.manager import CheckpointManager, CheckpointMeta, \
    restore
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.optim.optimizers import OptimizerConfig
from repro.train.steps import init_train_state, make_train_step


def _setup(seed=0):
    cfg = configs.get_config("smollm-135m", reduced=True)
    ocfg = OptimizerConfig(lr=3e-3, warmup_steps=5, decay_steps=200,
                           weight_decay=0.0)
    state, _ = init_train_state(jax.random.PRNGKey(seed), cfg, ocfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      seed=3)
    return cfg, ocfg, state, dcfg


def test_loss_decreases_and_energy_accounted(tmp_path):
    cfg, ocfg, state, dcfg = _setup()
    ds = SyntheticLMDataset(dcfg)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    mon = pmt.PowerMonitor(["cpuutil", "dummy"],
                           log_path=str(tmp_path / "energy.csv"))
    losses = []
    for s in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
        with mon.measure_step(s, tokens=8 * 32):
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses
    assert mon.cumulative_joules > 0
    recs = mon.records()
    assert {r.sensor for r in recs} == {"cpuutil", "dummy"}
    csv = open(tmp_path / "energy.csv").read().splitlines()
    assert len(csv) == 1 + 2 * 30   # header + 2 sensors x 30 steps
    mon.close()


def test_checkpoint_restart_bitexact_with_energy(tmp_path):
    cfg, ocfg, state, dcfg = _setup()
    ds = SyntheticLMDataset(dcfg)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    mgr = CheckpointManager(str(tmp_path), every=5, keep=3,
                            async_save=False)
    mon = pmt.PowerMonitor(["dummy"])

    # run 10 steps, checkpointing at 5 and 10
    s1 = state
    for s in range(1, 11):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
        with mon.measure_step(s):
            s1, _ = step_fn(s1, batch)
        mgr.maybe_save(s, s1, CheckpointMeta(
            step=s, data_step=s,
            cumulative_joules=mon.cumulative_joules))
    mgr.finalize()

    # restart from step 10, run to 15
    restored, meta = restore(str(tmp_path), s1)
    assert meta.step == 10 and meta.cumulative_joules > 0
    mon2 = pmt.PowerMonitor(["dummy"], initial_joules=meta.cumulative_joules)
    assert mon2.cumulative_joules == meta.cumulative_joules
    s2 = restored
    for s in range(meta.data_step + 1, 16):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
        s2, _ = step_fn(s2, batch)

    # reference: uninterrupted run to 15 from the same init
    _, _, ref, _ = _setup()
    for s in range(1, 16):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
        ref, _ = step_fn(ref, batch)

    for a, b in zip(jax.tree.leaves(s2.params), jax.tree.leaves(ref.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_straggler_detection_flags_slow_odd_host():
    power = [200.0] * 15 + [120.0]   # host 15: low power (throttling)
    times = [1.0] * 15 + [1.8]       # ... and slow
    verdicts = pmt.detect_stragglers(power, times)
    assert verdicts[15].is_straggler
    assert not any(v.is_straggler for v in verdicts[:15])
    # slow alone (power normal) is NOT flagged by the power detector
    v2 = pmt.detect_stragglers([200.0] * 16, times)
    assert not v2[15].is_straggler


def test_roofline_plumbing_tiny():
    """lower+cost+collective parse on a 1-device mesh — the same code
    path dryrun uses, minus the 512-device requirement."""
    from repro.launch.mesh import make_smoke_mesh
    from repro.roofline.terms import costs_from_compiled
    from repro.sharding.specs import axis_rules

    mesh = make_smoke_mesh()
    cfg = configs.get_config("smollm-135m", reduced=True)
    from repro.models import model as M
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    fwd = M.build_forward(cfg)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
    with mesh, axis_rules({"batch": "data"}, {"data": 1, "model": 1}):
        compiled = jax.jit(fwd).lower(params, batch).compile()
    costs = costs_from_compiled(compiled)
    assert costs.flops > 0
    assert costs.hbm_bytes > 0
    assert costs.coll_bytes == 0  # single device: no collectives


def test_hlo_collective_parser_synthetic():
    from repro.roofline.hlo import collective_bytes
    text = """
ENTRY %main {
  %ar = f32[128,256]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8]
  %ag = bf16[64,512]{1,0} all-gather(%y), channel_id=2, replica_groups=[2,4]<=[8]
  %rs = f32[32]{0} reduce-scatter(%z), channel_id=3, replica_groups=[1,8]<=[8]
  %done = f32[8] all-reduce-done(%w), channel_id=9, replica_groups=[2,4]<=[8]
}
"""
    stats = collective_bytes(text)
    assert stats.bytes_by_kind["all-reduce"] == 128 * 256 * 4
    assert stats.bytes_by_kind["all-gather"] == 64 * 512 * 2 / 4
    assert stats.bytes_by_kind["reduce-scatter"] == 32 * 4 * 8
    assert stats.count_by_kind["all-reduce"] == 1  # -done skipped
