"""Serve-path correctness: prefill + one decode step must reproduce the
full-forward logits at the same position (teacher forcing), for every
cache type: GQA KV, sliding-window ring buffer, MLA latent (absorbed
decode), mamba conv/ssm state, m/sLSTM state, whisper cross-attention."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M

B, T = 2, 32


def fp32(cfg):
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:
        # decode never drops tokens (capacity 1 per single token); make
        # the full-sequence forward drop-free too so the comparison is
        # apples-to-apples (token dropping is train-time semantics)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    return cfg


def make_batch(cfg, tokens, patch=4):
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        # fixed patch length for fwd AND prefill (the model reads the
        # actual shape, patch_frac only drives the dry-run specs)
        batch["patch_embeds"] = 0.01 * jnp.ones(
            (tokens.shape[0], patch, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frame_embeds"] = 0.01 * jnp.ones(
            (tokens.shape[0], cfg.enc_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_decode_matches_forward(arch):
    cfg = fp32(configs.get_config(arch, reduced=True))
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)

    # full forward logits at position T-1 (predicting token T)
    fwd = M.build_forward(cfg)
    hidden, _ = jax.jit(fwd)(params, make_batch(cfg, tokens))
    from repro.models import layers
    full_logits = layers.logits_from_hidden(
        cfg, params["embed"], hidden[:, -1:])[:, 0]

    # prefill T-1 tokens, then decode token T-1
    prefill, decode, _ = M.make_serve_fns(cfg)
    pf_batch = make_batch(cfg, tokens[:, :T - 1])
    _, caches = jax.jit(lambda p, b: prefill(p, b, T + 4))(params, pf_batch)
    step_logits, _ = jax.jit(decode)(params, caches, tokens[:, T - 1:T],
                                     jnp.asarray(T - 1, jnp.int32))

    # fp32, but computation ORDER differs between the paths (absorbed vs
    # materialized MLA, chunked scans, cache layouts) — tolerance covers
    # accumulation-order rounding, not semantic drift
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(full_logits),
        rtol=5e-2, atol=2e-2)


def test_sliding_window_ring_cache_long_decode():
    """gemma2 local layers: decode far past the window size stays finite
    and matches a fresh prefill at the same length."""
    cfg = fp32(configs.get_config("gemma2-27b", reduced=True))
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    n = cfg.sliding_window * 2  # decode well past the ring size
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, n), 0,
                                cfg.vocab_size)
    prefill, decode, _ = M.make_serve_fns(cfg)
    _, caches = jax.jit(lambda p, b: prefill(p, b, n + 8))(
        params, {"tokens": tokens[:, :8]})
    dec = jax.jit(decode)
    logits = None
    for t in range(8, min(n, 8 + cfg.sliding_window + 12)):
        logits, caches = dec(params, caches, tokens[:, t:t + 1],
                             jnp.asarray(t, jnp.int32))
    assert np.isfinite(np.asarray(logits)).all()


def test_mla_absorbed_decode_matches_forward():
    # covered by the parametrized test, but assert the cache is latent-
    # sized (the point of MLA): per token bytes << per-head cache
    cfg = fp32(configs.get_config("deepseek-v3-671b", reduced=True))
    caches = jax.eval_shape(lambda: M.init_caches(cfg, 1, 16))
    flat = jax.tree.leaves(caches)
    latent_bytes = sum(np.prod(l.shape) * l.dtype.itemsize for l in flat)
    full_kv_bytes = (cfg.num_layers * 16 * cfg.num_heads
                     * (cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
                        + cfg.mla.v_head_dim) * 2)
    assert latent_bytes < 0.5 * full_kv_bytes


def test_engine_generates():
    from repro.serve.engine import Request, ServeEngine
    cfg = fp32(configs.get_config("smollm-135m", reduced=True))
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=8),
            Request(prompt=[4, 5], max_new_tokens=8),
            Request(prompt=[6], max_new_tokens=4)]
    done = eng.generate(reqs)
    assert len(done) == 3
    assert all(len(r.out) == r.max_new_tokens for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out)
