"""Tests for the zero-allocation sampling core: NumPy ring + seqlock
readers, vectorized/async span resolution, eviction flagging, and parity
with the scalar resolution path of the previous revision."""
import threading
import time
import tracemalloc

import numpy as np
import pytest

import repro.core as pmt
from repro.core.resolver import batch_joules_at
from repro.core.sampler import (LegacyRingSampler, RingSampler,
                                SamplerWindowEvicted)
from repro.core.sensor import Sample, Sensor
from repro.core.session import SensorPool, Session, _joules_at
from repro.core.state import State


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _publish_rows(sampler, ts, js, ws=None):
    """Write synthetic rows directly (no sensor, no thread)."""
    if ws is None:
        ws = [float("nan")] * len(ts)
    with sampler._write_mutex:
        for t, j, w in zip(ts, js, ws):
            sampler._publish(float(t), float(j), float(w))


def _dummy_sampler(capacity=64, **kw):
    sensor = pmt.create("dummy", **kw)
    return RingSampler(sensor, period_s=0.001, capacity=capacity)


# ---------------------------------------------------------------------------
# Vectorized interpolation parity with the scalar reference
# ---------------------------------------------------------------------------

def _synthetic_timeline(n=500, seed=0, dup_frac=0.05):
    rng = np.random.default_rng(seed)
    dt = rng.uniform(0.0, 0.002, size=n)
    dt[rng.random(n) < dup_frac] = 0.0         # duplicate timestamps
    ts = np.cumsum(dt)
    js = np.cumsum(rng.uniform(0.0, 0.1, size=n))
    return ts, js


def test_batch_joules_at_matches_scalar_reference():
    ts, js = _synthetic_timeline()
    states = [State(timestamp_s=float(t), joules=float(j))
              for t, j in zip(ts, js)]
    ts_list = [float(t) for t in ts]
    rng = np.random.default_rng(7)
    # Interior points, exact sample points (incl. duplicates), and
    # points clamped off both ends.
    queries = np.concatenate([
        rng.uniform(ts[0], ts[-1], size=400),
        ts[rng.integers(0, len(ts), size=100)],
        np.array([ts[0] - 1.0, ts[-1] + 1.0, ts[0], ts[-1]]),
    ])
    got = batch_joules_at(ts, js, queries)
    want = np.array([_joules_at(states, ts_list, float(t))
                     for t in queries])
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-9)


def test_batch_joules_at_single_sample_timeline():
    ts = np.array([1.0])
    js = np.array([5.0])
    out = batch_joules_at(ts, js, np.array([0.0, 1.0, 2.0]))
    np.testing.assert_allclose(out, [5.0, 5.0, 5.0])


def test_window_arrays_straddles_ring_seam():
    """Parity must survive wraparound: the seam-unrolled window equals
    the logical tail of the write stream."""
    s = _dummy_sampler(capacity=16)
    n = 40
    ts = np.arange(n, dtype=np.float64)
    js = 2.0 * ts
    _publish_rows(s, ts, js)
    full_ts, full_js, _ = s.timeline()
    np.testing.assert_array_equal(full_ts, ts[-16:])
    np.testing.assert_array_equal(full_js, js[-16:])
    # A window that straddles the physical seam (wrap at index 40%16=8).
    wts, wjs, evicted = s.window_arrays(30.2, 36.5)
    assert not evicted
    np.testing.assert_array_equal(wts, np.arange(30, 38, dtype=np.float64))
    states = [State(timestamp_s=float(t), joules=float(j))
              for t, j in zip(full_ts, full_js)]
    for q in (30.2, 33.0, 36.5, 31.999):
        got = batch_joules_at(wts, wjs, np.array([q]))[0]
        want = _joules_at(states, list(full_ts), q)
        assert got == pytest.approx(want, abs=1e-9)


def test_session_resolution_parity_array_vs_legacy(monkeypatch):
    """End-to-end: the async array core and the legacy list core resolve
    identical joules on a deterministic virtual-clock timeline."""
    results = {}
    for legacy in (False, True):
        monkeypatch.setenv("PMT_LEGACY_RING", "1" if legacy else "0")
        clk = FakeClock()
        sensor = pmt.create("dummy", watts=75.0, clock=clk)
        with Session([sensor], pool=SensorPool()) as sess:
            with sess.region("a") as ra:
                clk.advance(1.5)
                with sess.region("b") as rb:
                    clk.advance(0.25)
            results[legacy] = (ra.measurements[0].joules,
                               rb.measurements[0].joules)
    assert results[False] == pytest.approx(results[True], abs=1e-9)
    assert results[False][0] == pytest.approx(75.0 * 1.75, abs=1e-6)
    assert results[False][1] == pytest.approx(75.0 * 0.25, abs=1e-6)


# ---------------------------------------------------------------------------
# Seqlock: torn-read detection under a hammering writer
# ---------------------------------------------------------------------------

def test_seqlock_torture_no_torn_reads():
    """One writer publishing as fast as it can, N readers copying: every
    copy must be internally consistent (ts sorted, js == 2*ts row-wise).
    A torn read (row half-written or slice straddling an in-flight
    overwrite) would break the js == 2*ts invariant."""
    s = _dummy_sampler(capacity=256)
    stop = threading.Event()
    errors = []

    def writer():
        t = 0.0
        with s._write_mutex:
            pass
        while not stop.is_set():
            t += 1.0
            with s._write_mutex:
                s._publish(t, 2.0 * t, 0.0)

    def reader():
        copies = 0
        try:
            while not stop.is_set():
                ts, js, _ = s.timeline()
                if ts.size:
                    if np.any(np.diff(ts) < 0):
                        raise AssertionError("unsorted timeline copy")
                    if not np.array_equal(js, 2.0 * ts):
                        raise AssertionError("torn read: js != 2*ts")
                wts, wjs, _ = s.window_arrays(float(ts[0]) if ts.size
                                              else 0.0, 1e18)
                if wts.size and not np.array_equal(wjs, 2.0 * wts):
                    raise AssertionError("torn window read")
                copies += 1
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)
        else:
            errors.append(None) if copies == 0 else None

    w = threading.Thread(target=writer)
    readers = [threading.Thread(target=reader) for _ in range(4)]
    w.start()
    for r in readers:
        r.start()
    time.sleep(0.4)
    stop.set()
    w.join(timeout=5)
    for r in readers:
        r.join(timeout=5)
    assert errors == []


def test_readers_never_wait_on_slow_sensor_io():
    """Satellite: the old core's sample_now held a lock across sensor
    I/O.  Now a 100 ms sensor read in flight must not delay readers."""

    class SlowSensor(Sensor):
        name = "slow"
        kind = "modeled"
        native_period_s = 3600.0

        def _sample(self):
            time.sleep(0.1)
            return Sample(watts=1.0)

    s = RingSampler(SlowSensor(), capacity=64)
    _publish_rows(s, [0.0, 1.0], [0.0, 1.0])
    t = threading.Thread(target=s.sample_now)
    t.start()
    time.sleep(0.02)               # the slow read is now in flight
    t0 = time.perf_counter()
    ts, js, _ = s.timeline()
    s.window_arrays(0.0, 1.0)
    reader_s = time.perf_counter() - t0
    t.join()
    assert ts.size >= 2
    assert reader_s < 0.05, f"reader stalled {reader_s:.3f}s on sensor I/O"


# ---------------------------------------------------------------------------
# Zero-allocation steady state
# ---------------------------------------------------------------------------

def test_tick_retains_zero_allocations_in_steady_state():
    """After warm-up, N sampler ticks must not grow traced memory: the
    ring is written in place, no States are retained, nothing
    accumulates.  (The legacy list core fails this by design — it
    appends a State per tick.)"""
    sensor = pmt.create("dummy", watts=5.0)
    s = RingSampler(sensor, period_s=0.001, capacity=4096)
    for _ in range(256):           # warm up: caches, small-int pool, ...
        s._tick()
    tracemalloc.start()
    try:
        snap1 = tracemalloc.take_snapshot()
        for _ in range(1024):
            s._tick()
        snap2 = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    growth = 0
    for stat in snap2.compare_to(snap1, "filename"):
        fname = stat.traceback[0].filename
        if "repro" in fname and stat.size_diff > 0:
            growth += stat.size_diff
    # The residual is the O(1) set of live floats (the sensor's
    # integration state, rebound each tick) — ~1 KiB regardless of tick
    # count.  Per-tick retention (the legacy core's State + list slots,
    # >= 56 B/tick) would exceed 57 KiB here.
    assert growth < 4096, \
        f"sampler tick retained {growth}B over 1024 ticks"


def test_legacy_tick_retains_memory_for_contrast():
    sensor = pmt.create("dummy", watts=5.0)
    s = LegacyRingSampler(sensor, period_s=0.001, maxlen=1 << 20)
    for _ in range(64):
        s._tick()
    tracemalloc.start()
    try:
        snap1 = tracemalloc.take_snapshot()
        for _ in range(1024):
            s._tick()
        snap2 = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    growth = sum(st.size_diff
                 for st in snap2.compare_to(snap1, "filename")
                 if "repro" in st.traceback[0].filename
                 and st.size_diff > 0)
    assert growth > 10_000         # a State + 2 list slots per tick


# ---------------------------------------------------------------------------
# Eviction: spans longer than the ring flag instead of silently lying
# ---------------------------------------------------------------------------

def test_span_outliving_ring_flags_window_evicted(monkeypatch):
    monkeypatch.setenv("PMT_RING_CAPACITY", "32")
    with Session(["dummy"], pool=SensorPool(), period_s=0.001) as sess:
        with sess.region("long") as r:
            time.sleep(0.3)        # ~300 ticks >> 32-slot ring
        with pytest.warns(SamplerWindowEvicted):
            m = r.measurements[0]
        assert m.window_evicted
        assert sess.stats()["evicted"] >= 1
    # MemoryExporter records carry the flag too
    mem = pmt.MemoryExporter()
    monkeypatch.setenv("PMT_RING_CAPACITY", "32")
    with Session(["dummy"], pool=SensorPool(), period_s=0.001,
                 exporters=[mem]) as sess:
        with sess.region("long"):
            time.sleep(0.3)
        with pytest.warns(SamplerWindowEvicted):
            sess.flush()
    assert any(rec.window_evicted for rec in mem.records)


def test_short_span_is_not_flagged():
    with Session(["dummy"], pool=SensorPool()) as sess:
        with sess.region("short") as r:
            time.sleep(0.005)
        assert r.measurements[0].window_evicted is False


def test_writer_marks_pinned_bracket_eviction():
    s = _dummy_sampler(capacity=8)
    _publish_rows(s, np.arange(8.0), np.arange(8.0))
    tok = s.pin(0.5)               # bracketed by sample at t=0
    assert not s.pin_evicted(tok)
    _publish_rows(s, [8.0, 9.0], [8.0, 9.0])   # wraps over t=0 and t=1
    assert s.pin_evicted(tok)
    assert s.evictions >= 1
    s.unpin(tok)
    assert not s.pin_evicted(tok)


# ---------------------------------------------------------------------------
# Async resolution behaviour
# ---------------------------------------------------------------------------

def test_spans_resolve_in_background_without_access():
    """Closed regions reach exporters via the resolver thread alone —
    no measurements access, no flush."""
    mem = pmt.MemoryExporter()
    with Session(["dummy"], pool=SensorPool(), exporters=[mem]) as sess:
        for i in range(5):
            with sess.region(f"bg{i}"):
                pass
        deadline = time.time() + 5.0
        while len(mem.records) < 5 and time.time() < deadline:
            time.sleep(0.01)
    assert sorted(r.path for r in mem.records) == [f"bg{i}"
                                                   for i in range(5)]


def test_async_resolution_defers_instead_of_sampling():
    """The resolver must not perturb the sensor: spans the ring does not
    cover yet wait for the background tick instead of forcing reads."""

    class CountingSensor(Sensor):
        name = "counting2"
        kind = "modeled"
        native_period_s = 3600.0   # background thread effectively idle

        def __init__(self, **kw):
            super().__init__(**kw)
            self.samples = 0

        def _sample(self):
            self.samples += 1
            return Sample(watts=1.0)

    sensor = CountingSensor()
    with Session([sensor], pool=SensorPool()) as sess:
        time.sleep(0.05)
        before = sensor.samples
        handles = []
        for i in range(10):
            with sess.region(f"r{i}") as h:
                pass
            handles.append(h)
        time.sleep(0.15)           # several resolver polls
        assert sensor.samples == before      # deferred, not sampled
        assert not any(h.resolved for h in handles)
        # Forcing resolution takes one closing sample for the batch.
        ms = sess.flush()
        assert len(ms) == 10
        assert sensor.samples > before
        assert all(h.resolved for h in handles)


def test_on_resolved_callback_fires_exactly_once():
    calls = []
    with Session(["dummy"], pool=SensorPool()) as sess:
        with sess.region("cb", on_resolved=calls.append) as r:
            pass
        r.measurements
        r.measurements
        sess.flush()
    assert len(calls) == 1
    assert calls[0][0].sensor == "dummy"


def test_queue_overflow_counts_drops_and_handles_still_resolve():
    with Session(["dummy"], pool=SensorPool(), max_pending=4) as sess:
        # Stop the background resolver so the queue deterministically
        # fills (otherwise a well-timed drain could empty it mid-loop).
        sess._stop_resolver()
        handles = []
        for i in range(10):
            with sess.region(f"o{i}") as h:
                pass
            handles.append(h)
        # The 6 oldest spans fell off the bounded auto-resolve queue...
        assert sess.stats()["dropped"] == 6
        # ...and every handle still resolves on demand.
        for h in handles:
            assert h.measurements[0].sensor == "dummy"
    assert sess.stats()["dropped"] == 6


def test_on_resolved_callback_may_reenter_session():
    """Regression: callbacks used to fire under the resolve lock, so a
    callback touching the session deadlocked.  They now run after the
    lock is released and may call stats()/flush()/measurements."""
    seen = []
    with Session(["dummy"], pool=SensorPool()) as sess:
        def cb(ms):
            seen.append((ms[0].sensor, sess.stats()["resolved"]))
            sess.flush()                      # re-enter: must not hang
        with sess.region("reent", on_resolved=cb) as r:
            pass
        done = threading.Event()
        t = threading.Thread(target=lambda: (r.measurements, done.set()))
        t.start()
        assert done.wait(timeout=10.0), "callback deadlocked the session"
        t.join()
    assert seen and seen[0][0] == "dummy" and seen[0][1] >= 1


def test_flush_returns_background_settled_spans():
    """flush() keeps the PR-1 contract: every span closed since the last
    flush comes back, even ones the resolver settled on its own."""
    with Session(["dummy"], pool=SensorPool()) as sess:
        with sess.region("bg"):
            pass
        # Wait until the background resolver has fully settled the span.
        deadline = time.time() + 5.0
        while sess.stats()["resolved"] < 1 and time.time() < deadline:
            time.sleep(0.01)
        assert sess.stats()["resolved"] == 1
        with sess.region("fg") as r:
            pass
        r.measurements                        # settle via handle access
        out = sess.flush()
        assert [ms[0].label for ms in out] == ["bg", "fg"]
        assert sess.flush() == []             # drained


def test_flush_surfaces_unresolvable_spans_as_errors():
    pool = SensorPool()
    sess = Session(["dummy"], pool=pool)
    with sess.region("orphan") as r:
        pass
    # Yank the lease out from under the pending span.
    sess._release_leases()
    sess.flush()
    assert sess.stats()["resolve_errors"] == 1
    with pytest.raises(pmt.SensorError):
        r.measurements
    with pytest.warns(UserWarning):
        sess.close()


def test_close_is_bounded_and_idempotent():
    sess = Session(["dummy"], pool=SensorPool())
    with sess.region("x"):
        pass
    t0 = time.perf_counter()
    sess.close(timeout=2.0)
    assert time.perf_counter() - t0 < 5.0
    sess.close()                   # idempotent
    assert sess.stats()["resolved"] >= 1
