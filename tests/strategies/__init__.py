"""Shared Hypothesis strategies + settings profiles for property tests.

Import ``given`` / ``settings`` / ``st`` from here instead of from
``hypothesis`` directly::

    from strategies import HAS_HYPOTHESIS, given, settings, st

Where hypothesis is installed this re-exports the real thing, registers
the shared settings profiles (``ci`` / ``dev``; select with the
``HYPOTHESIS_PROFILE`` env var), and exposes ``STANDARD_SETTINGS`` /
``THOROUGH_SETTINGS`` decorators for consistent test intensity.

Where hypothesis is **absent** (the minimal container), property tests
degrade gracefully instead of killing collection: the fallback ``given``
runs each test against a small deterministic grid of in-bounds values
drawn from the declared ``st.floats`` strategies — far weaker than real
property testing, but the identities still get exercised and the rest of
the suite still runs.
"""
from __future__ import annotations

import os

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True

    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.register_profile("dev", max_examples=100, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))

    STANDARD_SETTINGS = settings(max_examples=50, deadline=None)
    THOROUGH_SETTINGS = settings(max_examples=500, deadline=None)

except ImportError:
    HAS_HYPOTHESIS = False

    import random

    class _Strategy:
        """Base stand-in: boundary examples + seeded random draws."""

        def fixed(self):
            return []

        def one(self, rng: random.Random):  # pragma: no cover - abstract
            raise NotImplementedError

        def draws(self, rng: random.Random, n: int):
            out = list(self.fixed())[:n]
            while len(out) < n:
                out.append(self.one(rng))
            return out

    class _Floats(_Strategy):
        def __init__(self, min_value=0.0, max_value=1.0, **_):
            self.lo = float(min_value)
            self.hi = float(max_value)

        def fixed(self):
            return [self.lo, self.hi, 0.5 * (self.lo + self.hi)]

        def one(self, rng):
            return rng.uniform(self.lo, self.hi)

    class _Integers(_Strategy):
        def __init__(self, min_value=0, max_value=1, **_):
            self.lo = int(min_value)
            self.hi = int(max_value)

        def fixed(self):
            return [self.lo, self.hi, (self.lo + self.hi) // 2]

        def one(self, rng):
            return rng.randint(self.lo, self.hi)

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def fixed(self):
            return [self.elements[0], self.elements[-1]]

        def one(self, rng):
            return rng.choice(self.elements)

    class _Lists(_Strategy):
        def __init__(self, elements: _Strategy, min_size=0, max_size=5, **_):
            self.elements = elements
            self.lo = int(min_size)
            self.hi = int(max_size)

        def one(self, rng):
            size = rng.randint(self.lo, self.hi)
            return [self.elements.one(rng) for _ in range(size)]

    class _StFallback:
        """Only what this repo's property tests use; extend as needed."""

        floats = staticmethod(_Floats)
        integers = staticmethod(_Integers)
        sampled_from = staticmethod(_SampledFrom)
        lists = staticmethod(_Lists)

        def __getattr__(self, name):
            raise NotImplementedError(
                f"strategies fallback has no st.{name}; install hypothesis "
                f"(see requirements-dev.txt) or add a stub here")

    st = _StFallback()

    _N_EXAMPLES = 5

    def given(*pos_strategies, **kw_strategies):
        """Deterministic-grid replacement for ``hypothesis.given``.

        Positional strategies map to the test's positional parameters in
        order; keyword strategies by name — the two styles this repo's
        property tests use.
        """

        def decorate(fn):
            def runner(*fargs, **fkwargs):
                rng = random.Random(0)
                pos_cols = [s.draws(rng, _N_EXAMPLES)
                            for s in pos_strategies]
                kw_cols = {name: strat.draws(rng, _N_EXAMPLES)
                           for name, strat in kw_strategies.items()}
                for i in range(_N_EXAMPLES):
                    fn(*fargs, *[c[i] for c in pos_cols],
                       **fkwargs,
                       **{name: col[i] for name, col in kw_cols.items()})

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return decorate

    def settings(*args, **kwargs):
        """No-op replacement for ``hypothesis.settings`` (decorator form)."""
        if args and callable(args[0]):   # used bare: @settings
            return args[0]

        def decorate(fn):
            return fn

        return decorate

    def _identity(fn):
        return fn

    STANDARD_SETTINGS = _identity
    THOROUGH_SETTINGS = _identity

__all__ = ["HAS_HYPOTHESIS", "given", "settings", "st",
           "STANDARD_SETTINGS", "THOROUGH_SETTINGS"]
