"""PowerGovernor: deterministic control-law units + engine integration.

The unit tests drive the governor with a fake clock and a synthetic
watts trace injected straight into a ``PowerRecorder`` — no threads, no
sleeping, no engine — so every lever (admission gate, predictive step
learning, hold spacing, chunk budget, decode pause, tenant quotas) is
checked against exact numbers.

The integration tests close the real loop: a live engine on a
load-coupled dummy sensor (watts tracks the engine's ``live_slots``
gauge), where holding the cap *requires* the governor to limit
concurrency — the acceptance gate is the bench's: smoothed window power
stays under ``cap * 1.05`` after ramp-in while every request completes
in full.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import pytest

import repro.core as pmt
from repro import configs
from repro.core.backends.dummy import DummySensor
from repro.core.export import MemoryExporter, RegionRecord
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine
from repro.serve.governor import PowerGovernor
from repro.telemetry import PowerRecorder

IDLE_W, SLOT_W = 50.0, 15.0


class Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def feed(rec, clock, watts, seconds=1.0, dt=0.01):
    """Advance the fake clock while appending a flat watts trace."""
    end = clock.t + seconds
    while clock.t < end:
        clock.advance(dt)
        rec.add_watts("dummy", clock.t, watts)


def governed(cap=100.0, **kw):
    clock = Clock()
    rec = PowerRecorder()
    gov = PowerGovernor(rec, cap_watts=cap, window_s=0.5, clock=clock,
                        **kw)
    return gov, rec, clock


def rec_for(path, joules):
    return RegionRecord(path=path, label=path.rsplit("/", 1)[-1], depth=0,
                        sensor="dummy", kind="modeled", start_s=0.0,
                        end_s=1.0, seconds=1.0, joules=joules,
                        watts=joules)


class TestAdmissionGate:
    def test_blocks_over_threshold_resumes_under(self):
        gov, rec, clock = governed(cap=100.0)
        feed(rec, clock, 95.0)
        assert not gov.admission_allowed()       # 95 >= 90
        # repeated consultation is one transition, not a decision flood
        for _ in range(5):
            assert not gov.admission_allowed()
        assert [d.action for d in gov.decisions] == ["admit_block"]
        feed(rec, clock, 40.0)
        assert gov.admission_allowed()
        assert [d.action for d in gov.decisions] == \
            ["admit_block", "admit_resume"]

    def test_no_cap_is_wide_open(self):
        gov, rec, clock = governed(cap=None)
        feed(rec, clock, 10_000.0)
        assert gov.admission_allowed()
        assert gov.prefill_chunk_budget(True) == 1
        assert gov.maybe_pause_decode() == 0.0
        assert gov.throttle_count == 0

    def test_hold_spaces_admissions_even_without_signal(self):
        gov, _rec, clock = governed(cap=100.0)
        # no watts samples at all: first admission passes, the next is
        # held until admit_hold_s elapses — the cold-start guard that
        # keeps the first scheduler pass from filling every slot.
        assert gov.admission_allowed()
        gov.note_admitted(Request(prompt=[1], max_new_tokens=1))
        assert not gov.admission_allowed()
        assert [d.action for d in gov.decisions] == ["admit_hold"]
        clock.advance(gov.admit_hold_s + 0.01)
        assert gov.admission_allowed()

    def test_predictive_step_blocks_before_overshoot(self):
        gov, rec, clock = governed(cap=100.0)
        feed(rec, clock, 50.0)
        assert gov.admission_allowed()
        r = Request(prompt=[1], max_new_tokens=1)
        r.id = 0
        gov.note_admitted(r)                     # pre-admission w = 50
        feed(rec, clock, 80.0)                   # slot cost 30 W, settles
        assert gov.admission_allowed() or True   # settles the step
        assert gov._step_w == pytest.approx(30.0, abs=3.0)
        # 75 W is under the 90 W threshold, but 75 + ~30 > 100: blocked
        feed(rec, clock, 75.0)
        assert not gov.admission_allowed()
        # 60 + ~30 <= 100 (hold long expired): admissible again
        feed(rec, clock, 60.0)
        assert gov.admission_allowed()

    def test_constructor_validation(self):
        rec = PowerRecorder()
        with pytest.raises(ValueError):
            PowerGovernor(rec, cap_watts=-5.0)
        with pytest.raises(ValueError):
            PowerGovernor(rec, cap_watts=10.0, admit_frac=1.5)
        with pytest.raises(ValueError):
            PowerGovernor(rec, cap_watts=10.0, max_chunks_per_step=0)


class TestChunkAndPauseLevers:
    def test_chunk_budget_tiers(self):
        gov, rec, clock = governed(cap=100.0)
        feed(rec, clock, 95.0)
        assert gov.prefill_chunk_budget(decode_live=True) == 0
        feed(rec, clock, 60.0)
        assert gov.prefill_chunk_budget(decode_live=True) == 1
        feed(rec, clock, 30.0)                   # under boost threshold
        assert gov.prefill_chunk_budget(decode_live=True) \
            == gov.max_chunks_per_step
        actions = [d.action for d in gov.decisions]
        assert actions.count("chunk_pause") == 1
        assert actions.count("chunk_resume") == 1

    def test_decode_pause_only_when_hard_over(self):
        gov, rec, clock = governed(cap=100.0, pause_s=0.001)
        feed(rec, clock, 105.0)                  # over cap, under 110
        assert gov.maybe_pause_decode() == 0.0
        feed(rec, clock, 120.0)                  # hard over
        t0 = time.perf_counter()
        assert gov.maybe_pause_decode() == pytest.approx(0.001)
        assert time.perf_counter() - t0 >= 0.001
        assert gov.pause_total_s == pytest.approx(0.001)
        assert [d.action for d in gov.decisions][-1] == "decode_pause"


class TestTenantQuota:
    def test_quota_accumulates_from_records_and_defers(self):
        gov, rec, clock = governed(cap=None, tenant_quota_j=10.0)
        ra = Request(prompt=[1], max_new_tokens=1, tenant="a")
        ra.id = 5
        gov.note_admitted(ra)
        assert gov.tenant_allowed("a")
        # whole-request record flows recorder -> governor subscriber
        rec.on_record(rec_for("serve/req5", joules=12.0))
        rec.on_record(rec_for("serve/req5/prefill", joules=7.0))  # phase
        rec.on_record(rec_for("serve/batch0", joules=99.0))       # agg
        assert gov.tenant_joules() == {"a": pytest.approx(12.0)}
        assert not gov.tenant_allowed("a")       # over quota: deprioritized
        assert gov.tenant_allowed("b")
        assert gov.tenant_allowed(None)
        assert [d.action for d in gov.decisions] == ["tenant_defer"]

    def test_per_tenant_quota_dict(self):
        gov, rec, clock = governed(cap=None,
                                   tenant_quota_j={"a": 1.0})
        ra = Request(prompt=[1], max_new_tokens=1, tenant="a")
        ra.id = 0
        rb = Request(prompt=[1], max_new_tokens=1, tenant="b")
        rb.id = 1
        gov.note_admitted(ra)
        gov.note_admitted(rb)
        rec.on_record(rec_for("serve/req0", joules=5.0))
        rec.on_record(rec_for("serve/req1", joules=5.0))
        assert not gov.tenant_allowed("a")       # 5 >= quota 1
        assert gov.tenant_allowed("b")           # no quota entry: unlimited


class TestPoolPressureAndSlotModel:
    def test_pool_veto_blocks_below_reserve(self):
        gov, rec, clock = governed(cap=None, pool_reserve_frac=0.25)
        # below reserve: vetoed even with no cap / infinite headroom
        assert not gov.admission_allowed(pool_free_frac=0.10)
        assert [d.action for d in gov.decisions] == ["pool_block"]
        assert gov.admission_allowed(pool_free_frac=0.50)
        assert [d.action for d in gov.decisions] == \
            ["pool_block", "pool_resume"]
        # transitions, not per-consultation spam
        assert gov.admission_allowed(pool_free_frac=0.50)
        assert gov.stats()["throttle_decisions"] == 2

    def test_pool_veto_disabled_by_default(self):
        gov, rec, clock = governed(cap=None)
        assert gov.admission_allowed(pool_free_frac=0.0)
        assert gov.stats()["pool_reserve_frac"] == 0.0

    def test_constructor_validation(self):
        with pytest.raises(ValueError):
            PowerGovernor(PowerRecorder(), pool_reserve_frac=1.0)

    def test_slot_model_fits_linear_power(self):
        """Feed exact watts = 50 + 15 * slots samples: the fitted slope
        replaces the EWMA step in the predictive admission gate."""
        import types
        gov, rec, clock = governed(cap=100.0)
        eng = types.SimpleNamespace(live_slots=0)
        gov._engine = eng
        for slots in (0, 1, 2, 3, 1, 2):
            eng.live_slots = slots
            feed(rec, clock, IDLE_W + SLOT_W * slots)
            gov.admission_allowed()       # samples via _settle_step
        assert gov._fitted_step() == pytest.approx(SLOT_W, abs=1e-6)
        sm = gov.stats()["slot_watts_model"]
        assert sm["slope_w_per_slot"] == pytest.approx(SLOT_W, abs=1e-6)
        assert sm["intercept_w"] == pytest.approx(IDLE_W, abs=1e-6)
        assert sm["samples"] == 6
        # 87 W is under the 90 W admit threshold, but 87 + 15 > 100 W
        # when the *fitted* step is consulted (no EWMA was ever learned)
        assert gov._step_w is None
        feed(rec, clock, 87.0)
        assert not gov.admission_allowed()
        feed(rec, clock, 70.0)
        assert gov.admission_allowed()

    def test_slot_model_needs_occupancy_spread(self):
        import types
        gov, rec, clock = governed(cap=100.0)
        gov._engine = types.SimpleNamespace(live_slots=2)
        for _ in range(6):
            feed(rec, clock, 80.0)
            gov.admission_allowed()
        assert gov._fitted_step() is None      # no slope information
        assert gov.stats()["slot_watts_model"] is None


# -- integration: real engine, load-coupled power ---------------------------

@pytest.fixture(scope="module")
def smollm():
    cfg = dataclasses.replace(configs.get_config("smollm-135m",
                                                 reduced=True),
                              dtype="float32")
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def window_max(series, window_s, ramp_s):
    if not series:
        return 0.0
    t_start = min(series[0][0] + ramp_s,
                  series[0][0] + 0.5 * (series[-1][0] - series[0][0]))
    worst = 0.0
    for i, (t_i, _w) in enumerate(series):
        if t_i < t_start:
            continue
        win = [w for t, w in series[max(0, i - 512):i + 1]
               if t >= t_i - window_s]
        worst = max(worst, sum(win) / len(win))
    return worst


def run_governed(cfg, params, cap, reqs, batch=3, max_len=48, chunk=8,
                 window_s=0.05, **gov_kw):
    eng = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                      session=None, prefill_chunk=chunk,
                      cache_dtype=jnp.float32)
    eng.generate([Request(prompt=[1] * (chunk + 1), max_new_tokens=2)])
    sensor = DummySensor(watts_fn=lambda t: IDLE_W + SLOT_W * eng.live_slots)
    with pmt.Session([sensor], pool=pmt.SensorPool(),
                     period_s=0.001) as sess:
        mem = sess.add_exporter(MemoryExporter())
        with PowerRecorder(poll_period_s=0.005).attach(
                sess, exporter=mem) as rec:
            gov = PowerGovernor(rec, cap_watts=cap, window_s=window_s,
                                **gov_kw)
            eng.session = sess
            eng.governor = gov
            done = eng.generate(reqs)
            stats = eng.stats()
            eng.session = None
            eng.governor = None
            sess.flush()
            rec.poll_once()
            series = rec.watts_series("dummy").get("dummy", [])
            gov.close()
    return done, gov, series, [r for r in mem.records], stats, eng


def test_cap_held_while_engine_stays_live(smollm):
    """The acceptance gate: a cap between the 2- and 3-slot power
    levels is held (smoothed window <= cap * 1.05 post-ramp) while every
    request still completes in full."""
    cfg, params = smollm
    cap = IDLE_W + 2.5 * SLOT_W                  # 87.5 W
    reqs = [Request(prompt=[1 + i] * 9, max_new_tokens=16)
            for i in range(5)]
    done, gov, series, records, stats, _ = run_governed(
        cfg, params, cap, reqs)
    assert all(len(r.out) == r.max_new_tokens for r in done), \
        "a request starved under the cap"
    assert series, "no watts trace recorded"
    peak = window_max(series, window_s=0.05, ramp_s=0.1)
    assert peak <= cap * 1.05, \
        f"window power {peak:.1f} W exceeded cap {cap} W (+5%)"
    assert gov.throttle_count >= 1, "cap was binding but governor idle"
    # every throttle decision also landed as a flat session span
    gov_spans = [r for r in records
                 if r.path.startswith("serve/governor/")]
    assert gov_spans, "throttle decisions produced no serve/governor spans"
    assert stats["governor"]["throttle_decisions"] == gov.throttle_count


def test_unholdable_cap_liveness_wins(smollm):
    """A cap below idle draw can never be held; the engine must force
    admissions (recorded as admit_force) rather than starve."""
    cfg, params = smollm
    reqs = [Request(prompt=[2] * 5, max_new_tokens=3) for _ in range(3)]
    done, gov, _series, _records, _stats, _ = run_governed(
        cfg, params, cap=IDLE_W * 0.5, reqs=reqs, pause_s=0.001)
    assert all(len(r.out) == r.max_new_tokens for r in done)
    actions = {d.action for d in gov.decisions}
    assert "admit_force" in actions
    assert gov.pause_total_s > 0                 # hard-over lever engaged


def test_tenant_quota_soft_priority_never_starves(smollm):
    """Tiny per-tenant quotas deprioritize but never drop: every request
    from every tenant still completes, and quota accounting sees the
    resolved per-request joules."""
    cfg, params = smollm
    reqs = [Request(prompt=[3] * 5, max_new_tokens=3,
                    tenant=f"t{i % 2}") for i in range(4)]
    done, gov, _series, _records, _stats, _ = run_governed(
        cfg, params, cap=None, reqs=reqs, tenant_quota_j=1e-6)
    assert all(len(r.out) == r.max_new_tokens for r in done)
    joules = gov.tenant_joules()
    assert set(joules) == {"t0", "t1"}
    assert all(v > 0 for v in joules.values())
    assert not gov.tenant_allowed("t0")          # over the tiny quota


def test_engine_stats_and_gauges_reset(smollm):
    cfg, params = smollm
    reqs = [Request(prompt=[4] * 5, max_new_tokens=2) for _ in range(2)]
    done, gov, _series, _records, stats, eng = run_governed(
        cfg, params, cap=None, reqs=reqs)
    assert stats["mode"] == "continuous"
    assert stats["requests_admitted"] >= len(done)
    assert "stall_p95_s" in stats and "compile_counts" in stats
    assert stats["governor"]["cap_watts"] is None
    # gauges go quiet after the run
    assert eng.live_slots == 0
    assert eng.queue_depth == 0
    assert eng.pending_prefill_chunks == 0
