"""Quantized KV caches through the serve stack: accuracy + accounting.

Gates, coarsest last:

  * model-level logit drift: serve-path decode logits with a quantized
    cache must stay within a small *relative* bound of the full-
    precision cache across the cache families (GQA smollm, sliding-
    window gemma2 ring, MLA deepseek — whose latent rows quantize once
    and serve as both key and value).  Bounds are relative to the logit
    magnitude: MoE archs amplify absolute drift through top-k routing
    flips, but the relative excursion stays small (measured: int8
    <= ~1%, fp8 <= ~3% of max |logit| on these reduced configs).
  * engine-level: ``ServeEngine(cache_dtype="int8"/"fp8_e4m3")`` serves
    requests end-to-end in both KV layouts; paged and contiguous agree
    token-for-token under the same mode.
  * stats gauges: ``stats()["kv_cache"]`` reports the cache dtype and
    bytes/token for BOTH layouts, quantized ~half the bf16 footprint.
  * energy accounting under mixed precision: ``saved_prefill_joules``
    must price prefix-cache hits at the *engine's own* learned J/token
    EWMA — a quantized engine learns from its quantized prefill spans,
    never a bf16 engine's price.
  * pool_wait (scheduler fairness): an exhausted pool with an empty
    radix tree logs a ``pool_wait`` governor decision (and bumps the
    engine gauge) instead of silently spinning at admission
    checkpoints.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine
from repro.serve.governor import PowerGovernor

MODES = ("int8", "fp8_e4m3")
B, T = 2, 32

# relative logit-drift gates (fraction of max |logit|), per mode —
# doubled headroom over the measured drift on these reduced configs
DRIFT_GATE = {"int8": 0.10, "fp8_e4m3": 0.20}


def fp32(arch):
    cfg = dataclasses.replace(configs.get_config(arch, reduced=True),
                              dtype="float32")
    if cfg.moe is not None:
        # drift gates measure quantization, not MoE token-drop noise
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    return cfg


def serve_logits(cfg, params, tokens):
    """Prefill + two decode steps (write-then-read of quantized rows)."""
    prefill, decode, _ = M.make_serve_fns(cfg, cache_dtype=jnp.float32)
    _, caches = jax.jit(lambda p, b: prefill(p, b, T + 4))(
        params, {"tokens": tokens[:, :T - 1]})
    lg, caches = jax.jit(decode)(params, caches, tokens[:, T - 1:T],
                                 jnp.asarray(T - 1, jnp.int32))
    nxt = jnp.argmax(lg, -1)[:, None].astype(tokens.dtype)
    lg2, _ = jax.jit(decode)(params, caches, nxt, jnp.asarray(T, jnp.int32))
    return np.asarray(lg), np.asarray(lg2)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("arch", ["smollm-135m", "gemma2-27b",
                                  "deepseek-v3-671b"])
def test_quant_logit_drift_gate(arch, mode):
    cfg = fp32(arch)
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)
    ref1, ref2 = serve_logits(cfg, params, tokens)
    q1, q2 = serve_logits(dataclasses.replace(cfg, kv_quant=mode), params,
                          tokens)
    assert np.isfinite(q1).all() and np.isfinite(q2).all()
    bound = DRIFT_GATE[mode] * max(float(np.max(np.abs(ref1))), 1.0)
    assert float(np.max(np.abs(q1 - ref1))) < bound
    assert float(np.max(np.abs(q2 - ref2))) < bound


@pytest.mark.parametrize("mode", MODES)
def test_quant_chunked_prefill_consistency(mode):
    # chunked prefill writes the cache chunk-by-chunk (later chunks
    # attend quantized earlier rows); decode logits must stay close to
    # the whole-prompt prefill's
    cfg = dataclasses.replace(fp32("smollm-135m"), kv_quant=mode)
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)
    prefill, decode, prefill_chunk = M.make_serve_fns(
        cfg, cache_dtype=jnp.float32)
    _, full = jax.jit(lambda p, b: prefill(p, b, T + 4))(
        params, {"tokens": tokens})
    caches = M.init_caches(cfg, B, T + 4, dtype=jnp.float32)
    h = T // 2
    for i in range(2):
        _, caches = jax.jit(prefill_chunk)(
            params, caches, tokens[:, i * h:(i + 1) * h],
            jnp.asarray(i * h, jnp.int32), jnp.asarray(h - 1, jnp.int32))
    nxt = tokens[:, :1]
    l_full, _ = jax.jit(decode)(params, full, nxt, jnp.asarray(T, jnp.int32))
    l_chunk, _ = jax.jit(decode)(params, caches, nxt,
                                 jnp.asarray(T, jnp.int32))
    d = float(np.max(np.abs(np.asarray(l_full) - np.asarray(l_chunk))))
    assert d < 0.08 * max(float(np.max(np.abs(np.asarray(l_full)))), 1.0)


# -- engine level -------------------------------------------------------------

@pytest.fixture(scope="module")
def smollm():
    cfg = fp32("smollm-135m")
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


PROMPTS = [[3, 1, 4, 1, 5, 9, 2, 6], [2, 7, 1, 8, 2, 8], [1, 1, 2, 3, 5]]


def run_engine(cfg, params, cache_dtype, kv_layout, max_new=6, **kw):
    eng = ServeEngine(cfg, params, batch_size=2, max_len=48,
                      prefill_chunk=8, kv_layout=kv_layout,
                      cache_dtype=cache_dtype, **kw)
    done = eng.generate([Request(prompt=p, max_new_tokens=max_new)
                         for p in PROMPTS])
    return [r.out for r in done], eng


@pytest.mark.parametrize("mode", MODES)
def test_engine_quant_serves_both_layouts(smollm, mode):
    cfg, params = smollm
    contig, _ = run_engine(cfg, params, mode, "contiguous")
    paged, _ = run_engine(cfg, params, mode, "paged", kv_page_size=8)
    assert all(len(o) == 6 for o in contig)
    # same mode, same rows -> same tokens in either layout
    assert contig == paged


def test_engine_cache_dtype_string_aliases(smollm):
    cfg, params = smollm
    o_arr, _ = run_engine(cfg, params, jnp.bfloat16, "contiguous")
    o_str, _ = run_engine(cfg, params, "bfloat16", "contiguous")
    assert o_arr == o_str
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, batch_size=2, max_len=48,
                    cache_dtype="int4")


def test_engine_kv_cache_gauges(smollm):
    cfg, params = smollm
    _, e_bf = run_engine(cfg, params, jnp.bfloat16, "contiguous")
    _, e_i8 = run_engine(cfg, params, "int8", "contiguous")
    _, p_i8 = run_engine(cfg, params, "int8", "paged", kv_page_size=8)
    kc_bf = e_bf.stats()["kv_cache"]
    kc_i8 = e_i8.stats()["kv_cache"]
    kp_i8 = p_i8.stats()["kv_cache"]
    assert kc_bf["cache_dtype"] == "bfloat16"
    assert kc_i8["cache_dtype"] == kp_i8["cache_dtype"] == "int8"
    # int8 codes + amortized f32 scales land well under the bf16 cache
    assert kc_i8["bytes_per_token"] < 0.6 * kc_bf["bytes_per_token"]
    assert kp_i8["bytes_per_token"] > 0
    # paged gauge carries the pool keys too
    assert kp_i8["pages_total"] > 0 and "pool_wait_events" in kp_i8


@dataclasses.dataclass
class _Rec:
    path: str
    tokens: int
    joules: float


def test_saved_joules_priced_at_own_ewma(smollm):
    # Mixed-precision fleet: a quantized engine's prefix-cache savings
    # must be priced at the J/token EWMA learned from ITS OWN prefill
    # spans, not a bf16 engine's.  Feed each engine a different
    # measured prefill price, replay the same prefix-heavy workload,
    # and check the savings split accordingly.
    cfg, params = smollm
    prices = {"bfloat16": 2.0, "int8": 0.5}
    saved = {}
    for cache_dtype, jpt in prices.items():
        eng = ServeEngine(cfg, params, batch_size=2, max_len=48,
                          prefill_chunk=8, kv_layout="paged",
                          kv_page_size=4, cache_dtype=cache_dtype)
        eng.on_record(_Rec(path="serve/req0/prefill", tokens=4,
                           joules=4 * jpt))
        assert eng._prefill_jpt == pytest.approx(jpt)
        prompt = list(range(1, 13))
        eng.generate([Request(prompt=prompt, max_new_tokens=4)])
        eng.generate([Request(prompt=prompt, max_new_tokens=4)])
        st = eng.stats()["kv_cache"]
        assert st["prefix_hit_tokens"] > 0
        assert st["saved_prefill_joules"] == pytest.approx(
            st["prefix_hit_tokens"] * jpt)
        saved[cache_dtype] = st["saved_prefill_joules"]
    assert saved["int8"] < saved["bfloat16"]


def test_pool_wait_logged_not_silent(smollm):
    # Pool exhausted + radix empty: admission defers, and the wait is
    # SURFACED — a pool_wait governor decision opens the episode and a
    # pool_ready closes it when retirement frees pages (satellite:
    # previously the scheduler spun silently through this checkpoint).
    cfg, params = smollm
    gov = PowerGovernor(recorder=None)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=48,
                      prefill_chunk=8, kv_layout="paged", kv_page_size=8,
                      kv_pool_pages=7, prefix_cache=False,
                      cache_dtype="int8", governor=gov)
    done = eng.generate([Request(prompt=p, max_new_tokens=20)
                         for p in PROMPTS])
    assert all(len(r.out) == 20 for r in done)
    assert eng.pool_wait_events >= 1
    assert eng.stats()["kv_cache"]["pool_wait_events"] >= 1
    actions = [d.action for d in gov.decisions]
    assert "pool_wait" in actions and "pool_ready" in actions
    wait = next(d for d in gov.decisions if d.action == "pool_wait")
    assert "pages" in wait.detail
    # episodes pair up: every wait eventually resolved
    assert actions.count("pool_wait") == actions.count("pool_ready")
