"""Continuous-batching serve engine: correctness and accounting gates.

The load-bearing property is *slot independence*: per-slot decoding with
mixed prompt/generation lengths must produce byte-identical outputs to
serving each request alone (same engine, batch 1), including left-padded
edge rows and slots refilled mid-run — any KV leak between sequences or
positional mixup breaks exact token equality immediately.

Accounting gates: aggregate regions count *actually generated* tokens
(never ``batch * max_steps``), per-request spans resolve with token
counts summing to the aggregate, and the decode step function never
recompiles across request mixes (prompt lengths are bucketed).
"""
import dataclasses

import jax
import numpy as np
import pytest

import repro.core as pmt
from repro import configs
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine, prompt_bucket


@pytest.fixture(scope="module")
def smollm():
    cfg = dataclasses.replace(configs.get_config("smollm-135m",
                                                 reduced=True),
                              dtype="float32")
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


MIXED = [([1, 2, 3], 8), ([4, 5], 3), ([6], 1),
         ([7, 8, 9, 10, 11, 12, 13, 14, 15], 5), ([2], 12),
         ([3, 1, 4, 1, 5], 2), ([9, 9], 7)]


def mk(reqs):
    return [Request(prompt=list(p), max_new_tokens=n) for p, n in reqs]


def test_continuous_byte_identical_to_single_request(smollm):
    """B=3 continuous decode == each request served alone (B=1), exactly.

    The mix covers: prompts shorter than the min bucket (heavy left
    padding), max_new=1 (retired at prefill), more requests than slots
    (every slot refills at least once), and interleaved retirements.
    """
    cfg, params = smollm
    eng = ServeEngine(cfg, params, batch_size=3, max_len=64)
    done = eng.generate(mk(MIXED))
    ref_eng = ServeEngine(cfg, params, batch_size=1, max_len=64)
    for i, (prompt, n) in enumerate(MIXED):
        ref = ref_eng.generate(mk([(prompt, n)]))[0]
        assert done[i].out == ref.out, (
            f"request {i} diverged from single-request reference: "
            f"{done[i].out} != {ref.out}")
        assert len(done[i].out) == n


def test_slot_refill_leaks_no_kv(smollm):
    """A request decoded in a freshly-refilled slot matches its own
    solo run regardless of which request occupied the slot before —
    run the same mix in two different queue orders."""
    cfg, params = smollm
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64)
    a = {tuple(r.prompt): r.out for r in eng.generate(mk(MIXED))}
    b = {tuple(r.prompt): r.out
         for r in eng.generate(mk(list(reversed(MIXED))))}
    assert a == b


def test_decode_never_recompiles_across_mixes(smollm):
    """Chunked admission (the default): decode AND prefill each compile
    exactly once, no matter what prompt lengths arrive — the bucket
    family is gone."""
    cfg, params = smollm
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64)
    eng.generate(mk(MIXED[:3]))
    assert eng.compile_counts == {"prefill": 0, "decode": 1,
                                  "prefill_chunk": 1}
    # different prompt lengths (crossing what used to be bucket
    # boundaries), different generation lengths, different request count
    eng.generate(mk([([5, 4, 3, 2], 6), ([1], 9), ([8, 8, 8, 8, 8, 8], 2),
                     ([2, 3], 4), (list(range(1, 17)), 2)]))
    assert eng.compile_counts == {"prefill": 0, "decode": 1,
                                  "prefill_chunk": 1}


def test_blocking_baseline_compiles_once_per_bucket(smollm):
    """The prefill_chunk=0 baseline keeps the old bucketed-jit-cache
    property: one blocking prefill compile per power-of-two bucket."""
    cfg, params = smollm
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      prefill_chunk=0)
    eng.generate(mk(MIXED[:3]))
    decode_compiles = eng.compile_counts["decode"]
    prefill_compiles = eng.compile_counts["prefill"]
    eng.generate(mk([([5, 4, 3, 2], 6), ([1], 9), ([8, 8, 8, 8, 8, 8], 2),
                     ([2, 3], 4)]))
    assert eng.compile_counts["decode"] == decode_compiles == 1
    assert eng.compile_counts["prefill"] == prefill_compiles
    assert eng.compile_counts["prefill_chunk"] == 0
    # a new bucket compiles blocking prefill exactly once more
    eng.generate(mk([(list(range(1, 17)), 2)]))
    assert eng.compile_counts["prefill"] == prefill_compiles + 1
    assert eng.compile_counts["decode"] == 1


def test_prompt_bucketing():
    assert prompt_bucket(1) == 8
    assert prompt_bucket(8) == 8
    assert prompt_bucket(9) == 16
    assert prompt_bucket(100) == 128
    assert prompt_bucket(3, min_bucket=2) == 4
    with pytest.raises(ValueError):
        prompt_bucket(0)


def test_request_validation(smollm):
    cfg, params = smollm
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16)
    # chunked admission fits what bucketing couldn't: 9 prompt tokens
    # pad to 16 (one whole-cache chunk), and 9 + 2 decode slots <= 17
    assert [len(r.out) for r in eng.generate(mk([([1] * 9, 2)]))] == [2]
    with pytest.raises(ValueError, match="cache slots"):
        eng.generate(mk([([1] * 15, 3)]))     # 15 + 3 > 17 decode slots
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.generate(mk([([1], 0)]))
    # the blocking baseline keeps the bucket-based capacity check
    eng0 = ServeEngine(cfg, params, batch_size=1, max_len=16,
                       prefill_chunk=0)
    with pytest.raises(ValueError, match="cache slots"):
        eng0.generate(mk([([1] * 9, 2)]))     # bucket 16 + 2 > 17


def test_wave_region_counts_generated_tokens(smollm):
    """Satellite fix: wave J/token divides by sum(max_new_tokens), not
    batch * max_steps (which counted idle-slot padding as work)."""
    cfg, params = smollm
    with pmt.Session(["dummy"], pool=pmt.SensorPool()) as sess:
        mem = sess.add_exporter(pmt.MemoryExporter())
        eng = ServeEngine(cfg, params, batch_size=2, max_len=32,
                          session=sess, mode="wave")
        eng.generate(mk([([1, 2], 2), ([3], 6)]))   # one wave, 6 steps
        sess.flush()
        waves = [r for r in mem.records if r.path.startswith("serve/wave")]
        assert waves and all(r.tokens == 8 for r in waves)  # not 2*6=12


def test_per_request_spans_sum_to_aggregate(smollm):
    cfg, params = smollm
    reqs = mk(MIXED[:5])
    total = sum(r.max_new_tokens for r in reqs)
    with pmt.Session(["dummy"], pool=pmt.SensorPool()) as sess:
        mem = sess.add_exporter(pmt.MemoryExporter())
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          session=sess)
        eng.generate(reqs)
        sess.flush()
        agg = [r for r in mem.records if r.path.startswith("serve/batch")]
        per_req = [r for r in mem.records
                   if r.path.startswith("serve/req")
                   and "/" not in r.path.replace("serve/", "")]
        phases = [r for r in mem.records
                  if r.path.startswith("serve/req")
                  and "/" in r.path.replace("serve/", "")]
        assert [r.tokens for r in agg] == [total]
        assert len(per_req) == len(reqs)
        assert sum(r.tokens for r in per_req) == total
        # every request gets exactly one prefill + one decode phase
        # span, tiling its request span (dummy backend: constant watts,
        # so the J split must sum to the request total up to the tiny
        # uncovered instants between back-to-back clock reads)
        for r in per_req:
            mine = [p for p in phases if p.path.startswith(r.path + "/")]
            assert sorted(p.path.rsplit("/", 1)[1] for p in mine) == \
                ["decode", "prefill"]
            split = sum(p.joules for p in mine)
            assert split == pytest.approx(r.joules, rel=0.05, abs=1e-3)
        # flat spans: no nesting path pollution, every span resolves
        assert all(r.depth == 0 for r in per_req + phases)
        assert all(r.seconds >= 0 and np.isfinite(r.joules)
                   for r in per_req + phases)
        assert sess.stats()["pending"] == 0


def test_monitor_per_request_accounting(smollm):
    cfg, params = smollm
    mon = pmt.PowerMonitor(["dummy"])
    try:
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          monitor=mon)
        reqs = mk(MIXED[:4])
        eng.generate(reqs)
        per = mon.per_request_energy()
        assert sorted(per) == [0, 1, 2, 3]
        assert [per[i]["tokens"] for i in range(4)] == \
            [n for _, n in MIXED[:4]]
        for d in per.values():
            assert d["j_per_token"] >= 0.0
        # step records (the aggregate batch region) stay separate
        assert all(r.scope == "request" for r in mon.request_records())
        steps = [r for r in mon.records() if r.scope == "step"]
        assert steps and steps[0].tokens == sum(n for _, n in MIXED[:4])
    finally:
        mon.close()


def test_vector_positions_match_scalar(smollm):
    """decode_step with a (B,) position vector of equal entries must be
    bit-identical to the scalar path it generalises."""
    import jax.numpy as jnp
    cfg, params = smollm
    B, T = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)
    prefill, decode, _ = M.make_serve_fns(cfg)
    _, caches = jax.jit(lambda p, b: prefill(p, b, T + 4))(
        params, {"tokens": tokens[:, :T - 1]})
    nxt = tokens[:, T - 1:T]
    l_s, c_s = jax.jit(decode)(params, caches, nxt,
                               jnp.asarray(T - 1, jnp.int32))
    l_v, c_v = jax.jit(decode)(params, caches, nxt,
                               jnp.full((B,), T - 1, jnp.int32))
    assert bool(jnp.array_equal(l_s, l_v))
    assert all(bool(jnp.array_equal(a, b)) for a, b in
               zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)))
