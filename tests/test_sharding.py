"""Property tests (hypothesis) on the sharding rules and MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
# Real hypothesis when installed; deterministic-grid fallback otherwise.
from strategies import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.sharding.specs import (DEFAULT_RULES, logical_to_spec)

AXES = ["batch", "seq", "d_model", "heads", "kv_heads", "head_dim", "ffn",
        "vocab", "experts", "layers", None]


@given(st.lists(st.sampled_from(AXES), min_size=1, max_size=5))
@settings(max_examples=200, deadline=None)
def test_no_mesh_axis_claimed_twice(axes):
    spec = logical_to_spec(axes, DEFAULT_RULES)
    used = []
    for entry in spec:
        if entry is None:
            continue
        entries = entry if isinstance(entry, tuple) else (entry,)
        used.extend(entries)
    assert len(used) == len(set(used)), f"{axes} -> {spec}"


@given(st.lists(st.sampled_from(AXES), min_size=1, max_size=5),
       st.lists(st.integers(min_value=1, max_value=64), min_size=1,
                max_size=5))
@settings(max_examples=200, deadline=None)
def test_divisibility_pruning(axes, dims):
    n = min(len(axes), len(dims))
    axes, dims = axes[:n], dims[:n]
    sizes = {"pod": 2, "data": 16, "model": 16}
    spec = logical_to_spec(axes, DEFAULT_RULES, shape=dims, mesh_sizes=sizes)
    for dim, entry in zip(dims, spec):
        if entry is None:
            continue
        entries = entry if isinstance(entry, tuple) else (entry,)
        prod = int(np.prod([sizes[e] for e in entries]))
        assert dim % prod == 0, f"{axes} {dims} -> {spec}"


def test_rules_spec_examples():
    spec = logical_to_spec(["batch", "seq", "d_model"], DEFAULT_RULES)
    assert spec == P(("pod", "data"), None, None)
    spec = logical_to_spec(["experts", "d_model", "ffn"], DEFAULT_RULES)
    assert spec == P("model", None, None)  # ffn degrades: model taken


# -- MoE dispatch invariants ------------------------------------------------------

from repro.configs.base import MoEConfig, ModelConfig
from repro.models import moe


def tiny_moe_cfg(num_experts=8, top_k=2, cf=1.25):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64,
        moe=MoEConfig(num_experts=num_experts, top_k=top_k, ff_dim=16,
                      capacity_factor=cf))


@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=4, max_value=16),
       st.integers(min_value=2, max_value=8),
       st.integers(min_value=1, max_value=3))
@settings(max_examples=25, deadline=None)
def test_moe_dispatch_conservation(b, s, e, k):
    """Every kept assignment lands in exactly one slot; dropped tokens
    contribute zero; gate weights are renormalized top-k probs."""
    k = min(k, e)
    cfg = tiny_moe_cfg(num_experts=e, top_k=k)
    key = jax.random.PRNGKey(b * 100 + s)
    p = moe.init_moe(key, cfg)
    from repro.sharding.specs import split_params
    p, _ = split_params(p)
    x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    out, aux = moe.apply_moe(cfg, p, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.99  # load-balance loss >= 1 at optimum


def test_moe_forced_routing_matches_dense_expert():
    """Router forced to expert 0 (huge logit column): apply_moe must equal
    running expert 0's SwiGLU FFN densely on every token."""
    cfg = tiny_moe_cfg(num_experts=4, top_k=1, cf=8.0)  # no drops
    d = cfg.d_model
    key = jax.random.PRNGKey(0)
    p0 = moe.init_moe(key, cfg)
    from repro.sharding.specs import split_params
    p, _ = split_params(p0)
    router = jnp.zeros((d, 4)).at[:, 0].set(100.0)
    p["router"] = router
    # positive inputs so the forced router column is a large POSITIVE
    # logit (100 * sum(x)) for every token
    x = jnp.abs(jax.random.normal(key, (2, 8, d), jnp.float32)) + 0.1
    out, _ = moe.apply_moe(cfg, p, x)
    w_up, w_gate, w_down = p["w_up"][0], p["w_gate"][0], p["w_down"][0]
    expected = (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(min_value=2, max_value=64),
       st.integers(min_value=2, max_value=16),
       st.integers(min_value=1, max_value=4))
@settings(max_examples=50, deadline=None)
def test_capacity_bounds(tokens, e, k):
    k = min(k, e)
    m = MoEConfig(num_experts=e, top_k=k, ff_dim=8, capacity_factor=1.25)
    c = moe.capacity(tokens, m)
    assert 1 <= c <= tokens * k
    assert c * e >= tokens * k  # capacity covers perfect balance


# -- dispatch index plan properties ------------------------------------------------

@given(st.integers(min_value=1, max_value=32),
       st.integers(min_value=2, max_value=8),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=0, max_value=10_000))
@settings(max_examples=50, deadline=None)
def test_dispatch_indices_properties(t, e, k, seed):
    k = min(k, e)
    cap = moe.capacity(t, MoEConfig(num_experts=e, top_k=k, ff_dim=8))
    top_i = jax.random.randint(jax.random.PRNGKey(seed), (t, k), 0, e)
    tfs, sft = moe._dispatch_indices(top_i, cap, e)
    tfs, sft = np.asarray(tfs), np.asarray(sft)
    # every non-sentinel slot points at a valid token
    assert ((tfs == t) | ((tfs >= 0) & (tfs < t))).all()
    # kept assignments round-trip: slot_for_tk[token, j] -> token_for_slot
    for tok in range(t):
        for j in range(k):
            slot = sft[tok, j]
            if slot < e * cap:
                assert tfs[slot] == tok
    # no expert exceeds capacity
    kept = sft[sft < e * cap]
    experts = kept // cap
    counts = np.bincount(experts, minlength=e)
    assert (counts <= cap).all()
